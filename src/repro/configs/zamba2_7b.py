"""Zamba2-7B — hybrid Mamba2 backbone with a shared attention block
[arXiv:2411.15242].

81 Mamba2 layers, d_model=3584; one SHARED attention(+MLP) block (32 heads,
d_ff=14336) is applied every ``hybrid_attn_every`` layers, reusing the same
parameters each time (Zamba's signature trick). ssm_state=64, vocab=32000.
Natively sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,            # shared block's MLP
    vocab_size=32000,
    attention_kind="gqa",  # kind of the shared block
    ffn_kind="none",       # mamba layers carry no per-layer FFN
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,   # shared block applied every 6 mamba layers
    tie_embeddings=True,
)
