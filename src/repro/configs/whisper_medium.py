"""Whisper-medium — encoder-decoder audio backbone [arXiv:2212.04356].

24 encoder + 24 decoder layers, d_model=1024, 16 heads (MHA), d_ff=4096
(GELU 2-proj MLP), vocab=51865. The mel-spectrogram + conv frontend is a
STUB: ``input_specs()`` supplies precomputed frame embeddings of shape
(batch, 1500, d_model). LayerNorm + sinusoidal positions (no RoPE).

long_500k is SKIPPED for this arch (enc-dec decoder is full-attention with a
bounded target length by construction) — see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    attention_kind="gqa",
    ffn_kind="gelu",
    norm_kind="layernorm",
    use_rope=False,
    is_encoder_decoder=True,
    num_encoder_layers=24,
    encoder_seq_len=1500,
    max_seq_len=448 * 74,  # decoder positions padded far beyond whisper's 448
)
