"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

Geometry per [hf:openbmb/MiniCPM3-4B]: 62 layers, d_model=2560, 40 heads
(kv=40 logical — MLA compresses KV into a 256-d latent), d_ff=6400,
vocab=73448. MLA ranks from the model card.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_head_dim=32,
    qk_nope_head_dim=64,
    v_head_dim=64,
    ffn_kind="swiglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    sliding_window=8192,  # enables the long_500k SWA serving variant
)
