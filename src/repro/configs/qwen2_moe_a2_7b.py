"""Qwen1.5-MoE-A2.7B — fine-grained MoE [hf:Qwen/Qwen1.5-MoE-A2.7B].

24 layers, d_model=2048, 16 heads MHA, vocab=151936. 60 routed experts
top-4 (expert d_ff=1408) + 4 shared experts always on. 60 experts are padded
to 64 for the 16-way expert shard; the router masks the padding to -inf.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    attention_kind="gqa",
    ffn_kind="swiglu",
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    sliding_window=8192,
)
