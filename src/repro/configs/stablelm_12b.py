"""StableLM-2-12B — dense decoder [hf:stabilityai/stablelm-2-1_6b family].

40 layers, d_model=5120, 32 heads GQA kv=8, d_ff=13824, vocab=100352.
(Full RoPE here; the released model uses partial rotary — noted deviation.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    attention_kind="gqa",
    ffn_kind="swiglu",
    sliding_window=8192,
)
