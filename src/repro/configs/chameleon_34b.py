"""Chameleon-34B — early-fusion VLM backbone [arXiv:2405.09818].

48 layers, d_model=8192, 64 heads GQA kv=8, d_ff=22016, vocab=65536 (text +
VQ image tokens share one vocabulary — early fusion). QK-norm as in the
paper. The VQ-VAE image tokenizer is a STUB: image tokens arrive as ids in
the shared vocab, interleaved with text by ``input_specs()``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    attention_kind="gqa",
    ffn_kind="swiglu",
    use_qk_norm=True,
    is_early_fusion_vlm=True,
    image_token_count=1024,
    sliding_window=8192,
)
