"""LLaMA2-13B — the paper's own primary evaluation model [arXiv:2307.09288].

40 layers, d_model=5120, 40 heads MHA, d_ff=13824, vocab=32000. Used by
benchmarks/table1_modules.py and the serving simulator to reproduce the
paper's Figures 6/8/10/11 and Tables 1/2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    source="arXiv:2307.09288",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=13824,
    vocab_size=32000,
    attention_kind="gqa",
    ffn_kind="swiglu",
    sliding_window=8192,
)
