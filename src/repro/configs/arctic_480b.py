"""Snowflake Arctic (480B) — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35 layers, d_model=7168, 56 heads GQA kv=8, vocab=32000. 128 experts top-2
with expert d_ff=4864, combined with a DENSE residual MLP in parallel
(Arctic's dense-MoE hybrid design). 56 heads do not divide the 16-way model
axis -> attention params replicate on `model`; experts shard 8/device.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    attention_kind="gqa",
    ffn_kind="swiglu",
    num_experts=128,
    num_experts_per_tok=2,
    moe_dense_residual=True,
    dense_residual_d_ff=4864,
    sliding_window=8192,
)
