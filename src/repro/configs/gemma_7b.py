"""Gemma-7B — dense decoder with GeGLU and wide heads [arXiv:2403.08295].

28 layers, d_model=3072, 16 heads MHA (the 2B sibling uses MQA), head_dim=256,
d_ff=24576 (GeGLU), vocab=256000, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    attention_kind="gqa",
    ffn_kind="geglu",
    tie_embeddings=True,
    scale_embed=True,
    sliding_window=8192,
)
