"""Mamba2-780M — attention-free SSM with state-space duality [arXiv:2405.21060].

48 layers, d_model=1536, ssm_state=128, head_dim=64, expand=2
(d_inner=3072, 48 SSD heads), vocab=50280. No attention, no FFN.

CoCoServe applicability (DESIGN.md §4): layer replication/migration apply
verbatim; the KV-cache-migration primitive maps to migrating the (much
smaller) SSD recurrent state instead.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention_kind="none",
    ffn_kind="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)
