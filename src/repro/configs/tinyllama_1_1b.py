"""TinyLlama-1.1B — llama2-architecture small model [arXiv:2401.02385].

22 layers, d_model=2048, 32 heads GQA kv=4, d_ff=5632, vocab=32000.
Primary correctness vehicle for the CoCoServe module-scaling path.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    source="arXiv:2401.02385",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    attention_kind="gqa",
    ffn_kind="swiglu",
    sliding_window=8192,
)
