"""Config registry: ``get_config(arch_id)`` and the assigned input shapes."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

# arch id -> module name
_REGISTRY = {
    "minicpm3-4b": "minicpm3_4b",
    "whisper-medium": "whisper_medium",
    "zamba2-7b": "zamba2_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "chameleon-34b": "chameleon_34b",
    "arctic-480b": "arctic_480b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "stablelm-12b": "stablelm_12b",
    "mamba2-780m": "mamba2_780m",
    "gemma-7b": "gemma_7b",
    # the paper's own evaluation models
    "llama2-13b": "llama2_13b",
    "llama2-70b": "llama2_70b",
}

ASSIGNED_ARCHS = [k for k in _REGISTRY if not k.startswith("llama2")]


def list_archs() -> list:
    return list(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    return mod.CONFIG


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES",
    "get_config", "list_archs", "ASSIGNED_ARCHS",
]
