"""LLaMA2-70B — the paper's large evaluation model [arXiv:2307.09288].

80 layers, d_model=8192, 64 heads GQA kv=8, d_ff=28672, vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-70b",
    family="dense",
    source="arXiv:2307.09288",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    attention_kind="gqa",
    ffn_kind="swiglu",
    sliding_window=8192,
)
