"""Base configuration system for the repro framework.

A single ``ModelConfig`` dataclass covers every assigned architecture family:
dense (GQA/MQA/MLA attention, SwiGLU/GeGLU FFN), MoE (shared + routed
experts), SSM (Mamba2/SSD), hybrid (Mamba2 + shared attention blocks),
encoder-decoder (Whisper backbone) and early-fusion VLM (Chameleon backbone).

Full-size configs are only ever *lowered* (ShapeDtypeStruct dry-runs); smoke
tests instantiate ``cfg.reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class InputShape:
    """A workload shape: (kind, seq_len, global_batch)."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation for the geometry
    # geometry -----------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    max_seq_len: int = 32_768
    # attention ----------------------------------------------------------
    attention_kind: str = "gqa"  # gqa | mla | none
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # if set, SWA for decode variants
    # MLA (minicpm3 / deepseek-style) -------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # FFN ------------------------------------------------------------------
    ffn_kind: str = "swiglu"  # swiglu | geglu | gelu (2-proj) | none
    use_rope: bool = True     # False => sinusoidal absolute positions
    use_qk_norm: bool = False
    # MoE --------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0  # qwen2-moe shared experts
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_residual_d_ff: int = 0
    # SSM (mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1  # B/C are per-group (shared across heads), as in SSD
    ssm_conv_dim: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2-style): one shared attention block applied every k layers
    hybrid_attn_every: int = 0  # 0 = not hybrid
    # encoder-decoder (whisper backbone) ---------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1_500  # whisper audio frames after conv stub
    # VLM (chameleon early fusion) ----------------------------------------------
    is_early_fusion_vlm: bool = False
    image_token_count: int = 1_024  # VQ tokens per image (stubbed frontend)
    # norms / misc -----------------------------------------------------------------
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    scale_embed: bool = False  # gemma: multiply embeddings by sqrt(d_model)
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    # ----------------------------------------------------------------- derived
    @property
    def supports_paged_kv(self) -> bool:
        """Whether the paged block-pool decode path (serving/paged_kv.py)
        can serve this arch: a GQA attention decoder. SSM/hybrid caches are
        O(1) (nothing to page); MLA latents and audio cross-KV aren't
        pooled yet (see ROADMAP)."""
        return (self.family in ("dense", "moe", "vlm")
                and self.attention_kind != "mla")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the logits shard cleanly on a 16-way model axis."""
        return _round_up(self.vocab_size, 256)

    def padded_experts(self, axis: int = 16) -> int:
        if self.num_experts == 0:
            return 0
        return _round_up(self.num_experts, axis)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        if self.ssm_state == 0:
            return 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.attention_kind == "none"

    @property
    def supports_long_decode(self) -> bool:
        """True if the arch can serve long_500k (sub-quadratic path exists)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.is_encoder_decoder:
            return False  # bounded decoder length by construction
        return True  # dense/moe/vlm via sliding-window KV variant

    # ------------------------------------------------------------ param count
    def param_count(self) -> int:
        """Analytic parameter count (unpadded vocab), used by tests/Table 1."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        per_layer = 0
        # attention (per-layer; hybrids keep attention only in the shared block)
        if self.attention_kind == "gqa" and self.hybrid_attn_every == 0:
            hd = self.resolved_head_dim
            per_layer += d * self.num_heads * hd          # Q
            per_layer += 2 * d * self.num_kv_heads * hd   # K, V
            per_layer += self.num_heads * hd * d          # O
        elif self.attention_kind == "mla":
            hd_qk = self.qk_rope_head_dim + self.qk_nope_head_dim
            q_in = self.q_lora_rank if self.q_lora_rank else d
            if self.q_lora_rank:
                per_layer += d * self.q_lora_rank
            per_layer += q_in * self.num_heads * hd_qk
            per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            per_layer += self.kv_lora_rank * self.num_heads * (
                self.qk_nope_head_dim + self.v_head_dim)
            per_layer += self.num_heads * self.v_head_dim * d
        # ffn -------------------------------------------------------------
        if self.num_experts > 0:
            expert = 3 * d * self.d_ff
            per_layer += self.num_experts * expert
            per_layer += self.num_shared_experts * expert
            per_layer += d * self.num_experts  # router
            if self.moe_dense_residual:
                per_layer += 3 * d * self.dense_residual_d_ff
        elif self.ffn_kind in ("swiglu", "geglu"):
            per_layer += 3 * d * self.d_ff  # gate/up/down
        elif self.ffn_kind == "gelu":
            per_layer += 2 * d * self.d_ff  # up/down
        # ssm ----------------------------------------------------------------
        if self.ssm_state > 0:
            di = self.ssm_d_inner
            nh, g = self.ssm_heads, self.ssm_ngroups
            per_layer += d * (2 * di + 2 * g * self.ssm_state + nh)  # in_proj(zxBCdt)
            per_layer += self.ssm_conv_dim * (di + 2 * g * self.ssm_state)
            per_layer += 2 * nh  # A_log, D
            per_layer += di * d  # out_proj
        per_layer += 2 * d  # norms
        total += self.num_layers * per_layer
        # hybrid shared attention block (zamba2): counted once (shared params)
        if self.hybrid_attn_every > 0:
            hd = self.resolved_head_dim
            total += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            total += self.num_heads * hd * d + 2 * d
            if self.d_ff:  # shared block's MLP (zamba2)
                total += 3 * d * self.d_ff
        # encoder ------------------------------------------------------------
        if self.is_encoder_decoder:
            enc_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d  # self-attn+mlp(gelu 2-proj)
            dec_extra = 4 * d * d + 2 * d                      # cross-attn per dec layer
            total += self.num_encoder_layers * enc_layer
            total += self.num_layers * dec_extra
        return total

    # ------------------------------------------------------------ tp padding
    def tp_padded(self, axis: int = 16) -> "ModelConfig":
        """Head-padded variant enabling full tensor parallelism on a
        ``axis``-way model dimension (beyond-paper optimization, EXPERIMENTS
        §Perf): Q/O heads are zero-padded to a multiple of ``axis`` (padded
        heads have zero output weight — exactly neutral) and KV heads are
        REPLICATED up to ``axis`` (each group duplicated — identical math,
        Megatron GQA style). head_dim is pinned so padding never changes it.
        """
        if self.attention_kind != "gqa" or self.num_heads == 0:
            return self
        hd = self.resolved_head_dim
        H = _round_up(self.num_heads, axis)
        KV = self.num_kv_heads
        if KV < axis and axis % KV == 0:
            KV = axis
        elif KV % axis != 0 and H % axis == 0:
            KV = _round_up(KV, axis // math.gcd(KV, axis))
        return dataclasses.replace(self, num_heads=H, num_kv_heads=KV,
                                   head_dim=hd)

    # -------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = max(1, min(self.num_kv_heads, heads)) if heads else 0
        changes = dict(
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else None,
            max_seq_len=256,
        )
        if self.num_experts:
            changes.update(num_experts=4,
                           num_experts_per_tok=min(self.num_experts_per_tok, 2),
                           num_shared_experts=min(self.num_shared_experts, 1),
                           dense_residual_d_ff=min(self.dense_residual_d_ff, 256))
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=64)
        if self.attention_kind == "mla":
            changes.update(q_lora_rank=0, kv_lora_rank=64, qk_rope_head_dim=16,
                           qk_nope_head_dim=16, v_head_dim=32, head_dim=None)
        if self.hybrid_attn_every:
            changes.update(hybrid_attn_every=2)
        if self.is_encoder_decoder:
            changes.update(num_encoder_layers=2, encoder_seq_len=32)
        if self.sliding_window:
            changes.update(sliding_window=64)
        return dataclasses.replace(self, **changes)
