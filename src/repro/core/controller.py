"""Auto-Scaling Controller (§5): the closed control loop.

Every tick it reads the Monitor and
* triggers **scale-up** (Alg. 1) when the resource vacancy rate exceeds T_up,
* triggers **scale-down** (Alg. 2) when the SLO violation rate exceeds
  T_down (or an OOM / pool-pressure preemption was observed),
then pushes the updated plan to the Scheduler via ``on_plan_change``.

Live-telemetry interface: ``observe()`` feeds a snapshot straight into the
monitor, and after a scale-down tick ``last_scale_down`` holds the full
:class:`ScaleDownResult` — including structured ``migrations`` tuples — so
a live executor (serving/orchestrator.py) can turn kv_cache migrations
into actual block transfers between engines instead of parsing log lines.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.cluster import Cluster
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.core import scale_up as SU
from repro.core import scale_down as SD


@dataclasses.dataclass
class ControllerConfig:
    t_up: float = 0.35            # vacancy rate above which we scale up
    t_down: float = 0.05          # SLO violation rate above which we scale down
    gamma: float = 0.02           # Eq. 4 cluster constant
    replica_size: float = 605e6   # r — one decoder layer (Table 1)
    delta_bs: int = 5
    cooldown_ticks: int = 2
    dop: int = 2                  # max replication degree (paper default)
    min_vacancy: float = 0.1      # eligibility floor for replica hosts
    # component -> bytes for scale-down destination fitting; None keeps
    # scale_down's Table-1 defaults. The live orchestrator sets these
    # from REAL footprints (its pool bytes / measured replica size).
    module_bytes: Optional[dict] = None


@dataclasses.dataclass
class PodElasticityConfig:
    """Pod-LEVEL elasticity knobs (DESIGN.md §11): beyond rebalancing a
    fixed instance set (module scaling, Table 2), the controller may
    GROW the pod (spawn a whole engine-server worker) under sustained
    pressure and SHRINK it (drain a worker through the zero-drop
    migration path, then reap) when the pod runs mostly empty —
    ScaleLLM-style whole-replica scaling driven by the same monitor
    signals Alg. 1/2 read.

    Both directions are deliberately sluggish: ``patience`` consecutive
    pressure/idle ticks before acting, a shared ``cooldown_ticks``
    between pod actions, and ``flap_guard_s`` under which a just-grown
    worker is never a shrink target (a grow immediately followed by a
    shrink must not orphan a booting worker). Shrink is additionally
    gated by the Table-2-style cost model: the estimated drain cost
    (bytes to migrate / link bandwidth) must stay under
    ``max_drain_s``."""
    min_instances: int = 1
    max_instances: int = 8
    # grow when pod-wide block vacancy falls BELOW this (pools filling)…
    t_grow_vacancy: float = 0.15
    # …or the backlog per instance exceeds this many queued requests
    t_grow_queue: float = 4.0
    # shrink when vacancy stays ABOVE this with an empty queue
    t_shrink_vacancy: float = 0.85
    patience: int = 2
    cooldown_ticks: int = 4
    flap_guard_s: float = 1.0
    max_drain_s: float = 5.0


class Controller:
    def __init__(self, cfg: ControllerConfig, cluster: Cluster,
                 plan: PlacementPlan, monitor: Monitor, *,
                 batch_size: int = 16,
                 is_violating: Optional[Callable] = None,
                 on_plan_change: Optional[Callable] = None,
                 commit_replica: Optional[Callable] = None,
                 pod_cfg: Optional[PodElasticityConfig] = None):
        self.cfg = cfg
        self.cluster = cluster
        self.plan = plan
        self.monitor = monitor
        self.batch_size = batch_size
        self.is_violating = is_violating or (lambda plan, bs: False)
        self.on_plan_change = on_plan_change or (lambda plan, bs: None)
        self.commit_replica = commit_replica
        self._cooldown = 0
        self.log: List[str] = []
        self.last_scale_down: Optional[SD.ScaleDownResult] = None
        # pod elasticity state (pod_tick): persistence votes + cooldown
        self.pod_cfg = pod_cfg
        self._grow_votes = 0
        self._shrink_votes = 0
        self._pod_cooldown = 0

    def observe(self, snap: MetricsSnapshot):
        """Live-telemetry entry point: record one snapshot (built by the
        orchestrator from real engine instrumentation) into the monitor."""
        self.monitor.record(snap)

    def tick(self, in_burst: bool = False) -> Optional[str]:
        """One control period. Returns the action taken (or None).

        ``in_burst=True`` marks a FEEDBACK iteration inside the same
        control burst (the live executor applied a remediation, fed the
        post-action snapshot back via ``observe``, and is asking whether
        Alg. 2 wants another phase): the cooldown gate is bypassed and
        not re-armed — the burst's FIRST action already armed it, and a
        burst is one remediation episode, not several."""
        if self._cooldown > 0 and not in_burst:
            self._cooldown -= 1
            return None
        snap = self.monitor.latest
        if snap is None:
            return None
        action = None
        violation = (self.monitor.slo_violation_rate() > self.cfg.t_down
                     or snap.oom_events > 0
                     or self.monitor.pool_pressure())
        if violation:
            hot = self.monitor.hottest_device() or self.plan.home_device
            res = SD.scale_down(
                self.plan, self.cluster, src_device=hot,
                is_violating=self.is_violating,
                batch_size=self.batch_size, delta_bs=self.cfg.delta_bs,
                module_bytes=self.cfg.module_bytes,
                mem_bound=self.monitor.is_memory_bound(hot))
            self.plan = res.plan
            self.batch_size = res.batch_size
            self.last_scale_down = res
            action = f"scale-down[{'+'.join(res.actions) or 'noop'}]"
        elif (self.monitor.vacancy_rate() > self.cfg.t_up
              and self.monitor.block_vacancy_rate() > self.cfg.min_vacancy):
            # live engines gate scale-up on POOL vacancy too: a layer
            # replica is pointless on instances whose KV pools are full
            # (simulator snapshots carry no block telemetry -> rate 1.0)
            before = list(self.plan.p)
            self.plan = SU.scale_up(
                self.plan, self.cluster, gamma=self.cfg.gamma,
                replica_size=self.cfg.replica_size,
                max_degree=self.cfg.dop,
                min_vacancy=self.cfg.min_vacancy,
                commit=self.commit_replica)
            if self.plan.p != before:
                action = (f"scale-up[replicated {sum(self.plan.p) - sum(before)}"
                          f" layer replicas]")
        if action:
            self.log.append(action)
            self.on_plan_change(self.plan, self.batch_size)
            if not in_burst:
                self._cooldown = self.cfg.cooldown_ticks
        return action

    # ------------------------------------------------------ pod elasticity
    def pod_tick(self, pod_size: int,
                 est_drain_s: float = 0.0) -> Optional[str]:
        """Pod-LEVEL decision (PodElasticityConfig docstring): returns
        ``"grow"``, ``"shrink"``, or None. The live executor
        (serving/orchestrator.py) calls this once per control tick with
        the current pod population and, for the shrink gate, the
        estimated drain cost of its cheapest shrink target — the same
        bytes/bandwidth cost model (core/migration.estimate_cost) the
        Table-2 module operations are priced by. Pressure votes
        (vacancy collapse, backlog, SLO violations) must persist for
        ``patience`` consecutive ticks before either action fires, and
        any firing re-arms the pod cooldown."""
        pcfg = self.pod_cfg
        snap = self.monitor.latest
        if pcfg is None or snap is None:
            return None
        if self._pod_cooldown > 0:
            self._pod_cooldown -= 1
            return None
        vac = self.monitor.block_vacancy_rate()
        backlog = snap.queue_len / max(pod_size, 1)
        pressure = (vac < pcfg.t_grow_vacancy
                    or backlog > pcfg.t_grow_queue
                    or self.monitor.slo_violation_rate() > self.cfg.t_down)
        idle = vac > pcfg.t_shrink_vacancy and snap.queue_len == 0
        if pressure and pod_size < pcfg.max_instances:
            self._shrink_votes = 0
            self._grow_votes += 1
            if self._grow_votes >= pcfg.patience:
                self._grow_votes = 0
                self._pod_cooldown = pcfg.cooldown_ticks
                self.log.append(f"grow-pod[vacancy={vac:.2f} "
                                f"backlog={backlog:.1f}]")
                return "grow"
        elif idle and pod_size > pcfg.min_instances:
            self._grow_votes = 0
            self._shrink_votes += 1
            if self._shrink_votes >= pcfg.patience:
                self._shrink_votes = 0
                if est_drain_s > pcfg.max_drain_s:
                    # Table-2 cost gate: reaping this worker would stall
                    # its streams longer than the idleness is worth
                    self.log.append(
                        f"shrink-pod-skipped[est_drain={est_drain_s:.2f}s"
                        f" > {pcfg.max_drain_s:.2f}s]")
                    return None
                self._pod_cooldown = pcfg.cooldown_ticks
                self.log.append(f"shrink-pod[vacancy={vac:.2f} "
                                f"est_drain={est_drain_s:.2f}s]")
                return "shrink"
        else:
            self._grow_votes = 0
            self._shrink_votes = 0
        return None
