"""Auto-Scaling Controller (§5): the closed control loop.

Every tick it reads the Monitor and
* triggers **scale-up** (Alg. 1) when the resource vacancy rate exceeds T_up,
* triggers **scale-down** (Alg. 2) when the SLO violation rate exceeds
  T_down (or an OOM / pool-pressure preemption was observed),
then pushes the updated plan to the Scheduler via ``on_plan_change``.

Live-telemetry interface: ``observe()`` feeds a snapshot straight into the
monitor, and after a scale-down tick ``last_scale_down`` holds the full
:class:`ScaleDownResult` — including structured ``migrations`` tuples — so
a live executor (serving/orchestrator.py) can turn kv_cache migrations
into actual block transfers between engines instead of parsing log lines.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.cluster import Cluster
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.core import scale_up as SU
from repro.core import scale_down as SD


@dataclasses.dataclass
class ControllerConfig:
    t_up: float = 0.35            # vacancy rate above which we scale up
    t_down: float = 0.05          # SLO violation rate above which we scale down
    gamma: float = 0.02           # Eq. 4 cluster constant
    replica_size: float = 605e6   # r — one decoder layer (Table 1)
    delta_bs: int = 5
    cooldown_ticks: int = 2
    dop: int = 2                  # max replication degree (paper default)
    min_vacancy: float = 0.1      # eligibility floor for replica hosts
    # component -> bytes for scale-down destination fitting; None keeps
    # scale_down's Table-1 defaults. The live orchestrator sets these
    # from REAL footprints (its pool bytes / measured replica size).
    module_bytes: Optional[dict] = None


class Controller:
    def __init__(self, cfg: ControllerConfig, cluster: Cluster,
                 plan: PlacementPlan, monitor: Monitor, *,
                 batch_size: int = 16,
                 is_violating: Optional[Callable] = None,
                 on_plan_change: Optional[Callable] = None,
                 commit_replica: Optional[Callable] = None):
        self.cfg = cfg
        self.cluster = cluster
        self.plan = plan
        self.monitor = monitor
        self.batch_size = batch_size
        self.is_violating = is_violating or (lambda plan, bs: False)
        self.on_plan_change = on_plan_change or (lambda plan, bs: None)
        self.commit_replica = commit_replica
        self._cooldown = 0
        self.log: List[str] = []
        self.last_scale_down: Optional[SD.ScaleDownResult] = None

    def observe(self, snap: MetricsSnapshot):
        """Live-telemetry entry point: record one snapshot (built by the
        orchestrator from real engine instrumentation) into the monitor."""
        self.monitor.record(snap)

    def tick(self, in_burst: bool = False) -> Optional[str]:
        """One control period. Returns the action taken (or None).

        ``in_burst=True`` marks a FEEDBACK iteration inside the same
        control burst (the live executor applied a remediation, fed the
        post-action snapshot back via ``observe``, and is asking whether
        Alg. 2 wants another phase): the cooldown gate is bypassed and
        not re-armed — the burst's FIRST action already armed it, and a
        burst is one remediation episode, not several."""
        if self._cooldown > 0 and not in_burst:
            self._cooldown -= 1
            return None
        snap = self.monitor.latest
        if snap is None:
            return None
        action = None
        violation = (self.monitor.slo_violation_rate() > self.cfg.t_down
                     or snap.oom_events > 0
                     or self.monitor.pool_pressure())
        if violation:
            hot = self.monitor.hottest_device() or self.plan.home_device
            res = SD.scale_down(
                self.plan, self.cluster, src_device=hot,
                is_violating=self.is_violating,
                batch_size=self.batch_size, delta_bs=self.cfg.delta_bs,
                module_bytes=self.cfg.module_bytes,
                mem_bound=self.monitor.is_memory_bound(hot))
            self.plan = res.plan
            self.batch_size = res.batch_size
            self.last_scale_down = res
            action = f"scale-down[{'+'.join(res.actions) or 'noop'}]"
        elif (self.monitor.vacancy_rate() > self.cfg.t_up
              and self.monitor.block_vacancy_rate() > self.cfg.min_vacancy):
            # live engines gate scale-up on POOL vacancy too: a layer
            # replica is pointless on instances whose KV pools are full
            # (simulator snapshots carry no block telemetry -> rate 1.0)
            before = list(self.plan.p)
            self.plan = SU.scale_up(
                self.plan, self.cluster, gamma=self.cfg.gamma,
                replica_size=self.cfg.replica_size,
                max_degree=self.cfg.dop,
                min_vacancy=self.cfg.min_vacancy,
                commit=self.commit_replica)
            if self.plan.p != before:
                action = (f"scale-up[replicated {sum(self.plan.p) - sum(before)}"
                          f" layer replicas]")
        if action:
            self.log.append(action)
            self.on_plan_change(self.plan, self.batch_size)
            if not in_burst:
                self._cooldown = self.cfg.cooldown_ticks
        return action
