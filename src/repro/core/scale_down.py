"""Scale-Down / Module Reduction (Algorithm 2): three graduated phases.

(a) Module Migration    — move memory/compute-heavy modules off the hot
                          device (candidates filtered per §3.3 analysis);
(b) Replica Eviction    — drop co-located layer replicas, least-impact first;
(c) Performance Reduction — shrink batch size by Δbs and offload.

Each phase re-checks the violation predicate and stops as soon as the SLO is
restored — lower-impact remediations are exhausted before costly ones.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core.cluster import Cluster, Device
from repro.core.plan import PlacementPlan

# migration preference order per the paper's §3.3 recommendations
_MIGRATION_ORDER = ("kv_cache", "ffn", "attn", "layer")


@dataclasses.dataclass
class ScaleDownResult:
    plan: PlacementPlan
    batch_size: int
    actions: List[str]
    resolved: bool
    # structured mirror of the Phase-1 entries in ``actions`` so live
    # executors (serving/orchestrator.py) don't parse strings:
    # (layer, component, src_device, dst_device)
    migrations: List[Tuple[int, str, int, int]] = dataclasses.field(
        default_factory=list)


def filter_modules(plan: PlacementPlan, cfg_profile: dict, device_id: int,
                   *, mem_bound: bool, max_candidates: int = 8
                   ) -> List[Tuple[int, str]]:
    """FilterModules(): candidate (layer, component) migrations off a device.

    Memory pressure prefers kv_cache / whole layers; compute pressure prefers
    attention and FFN projections (§3.3).
    """
    layers = plan.layers_on_device(device_id)
    order = (("kv_cache", "layer", "ffn", "attn") if mem_bound
             else ("attn", "ffn", "layer", "kv_cache"))
    out: List[Tuple[int, str]] = []
    for comp in order:
        for layer in layers:
            if (layer, comp) in plan.migrated:
                continue
            out.append((layer, comp))
            if len(out) >= max_candidates:
                return out
    return out


def find_optimal_destination(cluster: Cluster, need_bytes: float,
                             exclude: int) -> Optional[Device]:
    cands = [d for d in cluster.devices
             if d.device_id != exclude and d.free_mem >= need_bytes]
    if not cands:
        return None
    return max(cands, key=lambda d: d.vacancy_rate)


def sort_evictees(plan: PlacementPlan, device_id: int) -> List[int]:
    """Replicas on the hot device, least-performance-impact first: layers
    whose eviction removes the fewest continuity breaks (isolated replicas
    go first, long contiguous runs are kept)."""
    reps = [i for i in range(plan.n_layers)
            if device_id in plan.replicas.get(i, [])]

    def impact(layer: int) -> Tuple[int, int]:
        trial = plan.copy()
        trial.evict_replica(layer, device_id)
        # prefer evictions that REDUCE boundaries the most (isolated
        # replicas first); never prefer splitting a contiguous run
        reduction = plan.continuity_breaks() - trial.continuity_breaks()
        return (-reduction, layer)

    return sorted(reps, key=impact)


def scale_down(plan: PlacementPlan, cluster: Cluster, *, src_device: int,
               is_violating: Callable[[PlacementPlan, int], bool],
               batch_size: int, delta_bs: int = 5,
               module_bytes: Optional[dict] = None,
               mem_bound: bool = True,
               offload: Optional[Callable[[], None]] = None
               ) -> ScaleDownResult:
    """Algorithm 2. ``is_violating(plan, batch_size)`` is the SLO/OOM
    predicate (fed by the Monitor in the live system, by the cluster state in
    the simulator). ``module_bytes`` maps component -> bytes for destination
    fitting (defaults to Table-1-ish fractions of a layer)."""
    actions: List[str] = []
    migrations: List[Tuple[int, str, int, int]] = []
    cur = plan.copy()
    module_bytes = module_bytes or {
        "layer": 605e6, "attn": 200e6, "ffn": 405e6, "kv_cache": 1e9}

    # -------------------------------------------------- Phase 1: migration
    for layer, comp in filter_modules(cur, module_bytes, src_device,
                                      mem_bound=mem_bound):
        dst = find_optimal_destination(cluster, module_bytes.get(comp, 0.0),
                                       src_device)
        if dst is None:
            continue
        cur.migrate(layer, comp, dst.device_id)
        dst.used_mem += module_bytes.get(comp, 0.0)
        src = cluster.device(src_device)
        src.used_mem = max(0.0, src.used_mem - module_bytes.get(comp, 0.0))
        actions.append(f"migrate L{layer}.{comp} {src_device}->{dst.device_id}")
        migrations.append((layer, comp, src_device, dst.device_id))
        if not is_violating(cur, batch_size):
            return ScaleDownResult(cur, batch_size, actions, True, migrations)

    # --------------------------------------------- Phase 2: replica eviction
    for layer in sort_evictees(cur, src_device):
        cur.evict_replica(layer, src_device)
        actions.append(f"evict replica L{layer} on dev{src_device}")
        if not is_violating(cur, batch_size):
            return ScaleDownResult(cur, batch_size, actions, True, migrations)

    # ----------------------------------------- Phase 3: performance reduction
    bs = batch_size
    while is_violating(cur, bs) and bs >= 1:
        bs = max(1, bs - delta_bs)
        actions.append(f"reduce batch -> {bs}")
        if offload is not None:
            offload()
            actions.append("offload params/kv")
        if not is_violating(cur, bs):
            break
        if bs == 1:
            break
    return ScaleDownResult(cur, bs, actions, not is_violating(cur, bs),
                           migrations)
