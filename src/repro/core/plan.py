"""Placement plans — the paper's per-module state vector P plus device maps.

A :class:`PlacementPlan` tracks, for one LLM instance:

* ``p``        — the paper's parallelism vector P = [p_1..p_n] (replication
  degree per layer; p_i = 1 + number of replicas).
* ``replicas`` — layer -> list of device ids hosting the extra replicas.
* ``migrated`` — (layer, component) -> device id for fine-grained migrations
  (components: "layer", "attn", "ffn", "kv_cache" — §3.3 of the paper).

``continuity_breaks`` is the paper's δ driver: the number of boundaries where
the replica device-set changes between consecutive layers (each boundary
costs one scatter + one all-gather in the dataflow, §3.1/Fig. 4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

COMPONENTS = ("layer", "attn", "ffn", "kv_cache")


@dataclasses.dataclass
class PlacementPlan:
    n_layers: int
    home_device: int = 0
    replicas: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    migrated: Dict[Tuple[int, str], int] = dataclasses.field(
        default_factory=dict)

    # ------------------------------------------------------------------ P
    @property
    def p(self) -> List[int]:
        return [1 + len(self.replicas.get(i, [])) for i in range(self.n_layers)]

    def copy(self) -> "PlacementPlan":
        return PlacementPlan(
            n_layers=self.n_layers,
            home_device=self.home_device,
            replicas={k: list(v) for k, v in self.replicas.items()},
            migrated=dict(self.migrated))

    # ------------------------------------------------------------- editing
    def add_replica(self, layer: int, device: int):
        assert 0 <= layer < self.n_layers
        self.replicas.setdefault(layer, []).append(device)

    def evict_replica(self, layer: int, device: Optional[int] = None):
        reps = self.replicas.get(layer)
        if not reps:
            return False
        if device is None:
            reps.pop()
        elif device in reps:
            reps.remove(device)
        else:
            return False
        if not reps:
            del self.replicas[layer]
        return True

    def migrate(self, layer: int, component: str, device: int):
        assert component in COMPONENTS
        self.migrated[(layer, component)] = device

    # ------------------------------------------------------------- queries
    def device_set(self, layer: int) -> Tuple[int, ...]:
        home = self.migrated.get((layer, "layer"), self.home_device)
        return tuple(sorted([home] + self.replicas.get(layer, [])))

    def continuity_breaks(self) -> int:
        """Boundaries where the replica device-set changes (drives δ)."""
        breaks = 0
        prev = (self.home_device,)
        for i in range(self.n_layers):
            cur = self.device_set(i)
            if cur != prev:
                breaks += 1
            prev = cur
        if prev != (self.home_device,):
            breaks += 1  # gather back at the stack exit
        return breaks

    def replicated_layer_count(self) -> int:
        return sum(1 for i in range(self.n_layers) if len(self.device_set(i)) > 1)

    def devices_used(self) -> Tuple[int, ...]:
        devs = {self.home_device}
        for reps in self.replicas.values():
            devs.update(reps)
        devs.update(self.migrated.values())
        return tuple(sorted(devs))

    def layers_on_device(self, device: int) -> List[int]:
        """Layers with any presence (home/replica/migrated) on ``device``."""
        out = []
        for i in range(self.n_layers):
            if device in self.device_set(i):
                out.append(i)
                continue
            if any(d == device and k[0] == i for k, d in self.migrated.items()):
                out.append(i)
        return out

    @staticmethod
    def initial(n_layers: int, home_device: int = 0) -> "PlacementPlan":
        return PlacementPlan(n_layers=n_layers, home_device=home_device)
