"""Module replication on an SPMD mesh — the TPU adaptation of §3.1.

The paper replicates a layer onto extra GPUs and splits the batch between
replicas (hooks scatter inputs / all-gather outputs). Under GSPMD the same
dataflow is expressed as a *per-layer batch sharding constraint*: a layer
with parallelism degree p_i computes with its batch split p_i ways; entering
or leaving a replicated region makes XLA insert exactly the scatter /
all-gather the paper describes. Degrees are quantized to powers of two and
realized as prefixes of a factorized replication mesh (axes r0, r1, ...,
each of size 2) — DESIGN.md §2 records this assumption change.

``layer_hook_from_plan`` plugs into ``transformer.forward(unroll=True,
layer_hook=...)`` so each unrolled layer carries its own constraint. The
continuity property of Alg. 1 is therefore *observable*: plans with fewer
device-set changes lower to HLO with fewer resharding collectives
(``count_collectives`` below; asserted in tests/test_replication.py).
"""
from __future__ import annotations

import functools
import math
import re
from typing import List, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.plan import PlacementPlan

COLLECTIVE_RE = re.compile(
    r'\b(all-gather(?:-start)?|all-reduce(?:-start)?|reduce-scatter|'
    r'all-to-all|collective-permute(?:-start)?)\b')


def replication_mesh(n_devices: int, devices=None) -> Mesh:
    """Factorized mesh: axes ("r0","r1",...) each of size 2. A single
    device degenerates to one axis of size 1 (every degree quantizes to 1
    and each constraint becomes a no-op P(None)) — the shape the live
    engine uses on 1-device hosts so the hook path still compiles."""
    import numpy as np
    devs = (devices if devices is not None else jax.devices())[:n_devices]
    if n_devices == 1:
        return Mesh(np.array(devs).reshape((1,)), ("r0",))
    k = int(math.log2(n_devices))
    assert 2 ** k == n_devices, "replication mesh needs a power-of-2 devices"
    arr = np.array(devs).reshape((2,) * k)
    return Mesh(arr, tuple(f"r{i}" for i in range(k)))


@functools.lru_cache(maxsize=1)
def default_replication_mesh() -> Mesh:
    """Replication mesh over the largest power-of-two prefix of the local
    devices — what Engine.apply_plan shards the live decode step over."""
    n = 1
    while n * 2 <= jax.device_count():
        n *= 2
    return replication_mesh(n)


def quantize_degrees(p: Sequence[int], n_devices: int) -> List[int]:
    """Round each p_i down to the nearest power of two <= n_devices."""
    out = []
    for pi in p:
        q = 1
        while q * 2 <= min(pi, n_devices):
            q *= 2
        out.append(q)
    return out


def batch_spec_for_degree(degree: int, mesh: Mesh) -> P:
    """Batch axis sharded over the first log2(degree) replication axes."""
    k = int(math.log2(degree))
    if k == 0:
        return P(None)
    axes = tuple(mesh.axis_names[:k])
    return P(axes)


def layer_hook_from_degrees(degrees: Tuple[int, ...], mesh: Mesh, *,
                            extra_dims: int = 2):
    """hook(i, x) -> x constrained to layer i's batch sharding, from an
    already-quantized degree tuple. The tuple is hashable, so the LIVE
    engine passes it as a static jit argument — changing the plan recompiles
    exactly the affected decode step, nothing else (the runtime face of
    ``layer_hook_from_plan``; see serving/engine.Engine.apply_plan).

    ``extra_dims``: trailing activation dims left unsharded ([B,S,d] -> 2).
    """
    def hook(i: int, x):
        d = min(degrees[i], mesh.devices.size)
        spec = batch_spec_for_degree(d, mesh)
        full = P(*(tuple(spec) + (None,) * extra_dims))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, full))

    return hook


def layer_hook_from_plan(plan: PlacementPlan, mesh: Mesh, *,
                         extra_dims: int = 2):
    """Returns hook(i, x) -> x constrained to the layer's batch sharding.

    ``extra_dims``: trailing activation dims left unsharded ([B,S,d] -> 2).
    """
    degrees = tuple(quantize_degrees(plan.p, mesh.devices.size))
    return layer_hook_from_degrees(degrees, mesh, extra_dims=extra_dims)


def count_collectives(hlo_text: str) -> dict:
    """Histogram of collective ops in an HLO dump (lowered/compiled text)."""
    out: dict = {}
    for mword in COLLECTIVE_RE.finditer(hlo_text):
        w = mword.group(1).replace("-start", "")
        out[w] = out.get(w, 0) + 1
    return out


def replicate_params_for_plan(params, mesh: Mesh):
    """Replicate parameters across the replication mesh (layer replication
    shares weights — every replica owns a copy, matching the paper's memory
    accounting in Table 2)."""
    sh = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), params)
