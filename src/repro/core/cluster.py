"""Cluster abstraction shared by the auto-scaling algorithms and simulator.

Devices model the paper's testbed (A100-40GB) by default but take arbitrary
compute/memory/bandwidth so the same algorithms drive the TPU-pod speedup
estimates (DESIGN.md §2). Module memory/compute footprints come from the
analytic Table-1 model in :func:`module_profile`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig

GB = 1024 ** 3


@dataclasses.dataclass
class Device:
    device_id: int
    mem_capacity: float = 40 * GB          # A100-40GB
    compute_flops: float = 312e12          # A100 bf16 dense, FLOP/s
    used_mem: float = 0.0
    # instantaneous load signals fed by the Monitor
    util_compute: float = 0.0              # 0..1
    util_mem: float = 0.0                  # 0..1

    @property
    def free_mem(self) -> float:
        return max(0.0, self.mem_capacity - self.used_mem)

    @property
    def vacancy_rate(self) -> float:
        return 1.0 - max(self.util_compute, self.used_mem / self.mem_capacity)


@dataclasses.dataclass
class Cluster:
    devices: List[Device]
    link_bandwidth: float = 64 * GB        # NVLink-ish; TPU ICI ~50GB/s/link

    def eligible_nodes(self, min_vacancy: float = 0.2) -> List[Device]:
        """GetEligibleNodes(G) — filtered by resource vacancy rate (Alg. 1)."""
        return sorted((d for d in self.devices
                       if d.vacancy_rate >= min_vacancy),
                      key=lambda d: -d.vacancy_rate)

    def device(self, device_id: int) -> Device:
        return self.devices[device_id]

    @staticmethod
    def homogeneous(n: int, *, mem_gb: float = 40.0, flops: float = 312e12,
                    link_gbps: float = 64.0) -> "Cluster":
        return Cluster(
            devices=[Device(i, mem_capacity=mem_gb * GB,
                            compute_flops=flops) for i in range(n)],
            link_bandwidth=link_gbps * GB)

    @staticmethod
    def tpu_v5e(n: int) -> "Cluster":
        """The dry-run target: 197 TFLOP/s bf16, 16 GB HBM, ~50 GB/s/link."""
        return Cluster(
            devices=[Device(i, mem_capacity=16 * GB,
                            compute_flops=197e12) for i in range(n)],
            link_bandwidth=50 * GB)


# --------------------------------------------------------- module footprints
def module_profile(cfg: ModelConfig, *, batch: int = 1, seq: int = 256,
                   dtype_bytes: int = 2) -> Dict[str, Dict[str, float]]:
    """Analytic per-module memory (weight bytes) and compute (FLOPs) — the
    reproduction of the paper's Table 1 (benchmarks/table1_modules.py prints
    it for LLaMA-13B geometry and checks against the paper's numbers)."""
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    toks = batch * seq
    out: Dict[str, Dict[str, float]] = {}

    qkvo_params = d * H * hd + 2 * d * KV * hd + H * hd * d
    proj_one = d * H * hd                      # a single projection (Q or O)
    out["self_attn.q/k/v/o_proj"] = {
        "mem": proj_one * dtype_bytes,
        "flops": 2 * toks * proj_one,
    }
    attn_scores = 2 * 2 * batch * H * seq * seq * hd  # QK^T + AV
    out["self_attn"] = {
        "mem": qkvo_params * dtype_bytes,
        "flops": 2 * toks * qkvo_params,
        "extra_flops_scores": attn_scores,
    }
    # Table 1's "ffn.gate/up/down_proj" row is a SINGLE [d, d_ff] projection
    # (135 MB / 36.24 GFLOPs for LLaMA-13B), mirroring the per-projection
    # attention row.
    ffn_proj = d * ff
    out["ffn.gate/up/down_proj"] = {
        "mem": ffn_proj * dtype_bytes,
        "flops": 2 * toks * ffn_proj,
    }
    ffn_params = 3 * d * ff if cfg.ffn_kind in ("swiglu", "geglu") else 2 * d * ff
    layer_params = qkvo_params + ffn_params + 2 * d
    # activations + norms dominate the delta the paper reports for a layer
    act_mem = toks * (2 * d + ff) * dtype_bytes
    out["decoder_layer"] = {
        "mem": layer_params * dtype_bytes + act_mem,
        "flops": 2 * toks * layer_params + attn_scores,
    }
    out["kv_cache_per_token"] = {
        "mem": 2 * KV * hd * dtype_bytes * cfg.num_layers,
        "flops": 0.0,
    }
    return out


def layer_weight_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    prof = module_profile(cfg, dtype_bytes=dtype_bytes)
    d = cfg.d_model
    qkvo = prof["self_attn"]["mem"]
    n_proj = 3 if cfg.ffn_kind in ("swiglu", "geglu") else 2
    ffn = n_proj * prof["ffn.gate/up/down_proj"]["mem"]
    return qkvo + ffn + 2 * d * dtype_bytes


def layer_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    prof = module_profile(cfg, batch=batch, seq=seq)
    return prof["decoder_layer"]["flops"]
