"""Module migration — re-placement of parameters / caches (§3.1, §3.3).

On SPMD hardware "move module M from device A to device B" becomes
"re-shard/re-place M's arrays": a ``device_put`` with a new NamedSharding.
The cost model (bytes moved / link bandwidth + per-op latency) reproduces
the paper's Table 2 against our ICI constants; ``migrate_by_path`` performs
the actual re-placement for any params/cache subtree matched by regex.

Beyond dense slabs, the same cost model covers PAGED POOL SLICES — the
unit CoCoServe's live scale-down actually moves: ``migrate_blocks`` ships
one request's KV blocks between two engines' block pools (the wire format
of serving/paged_kv.export_blocks), and ``migrate_paged_pool`` re-places a
whole pool (the memory-heavy module of §3.3) under a new sharding.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_PATH_JOIN = "/"


@dataclasses.dataclass(frozen=True)
class MigrationCost:
    bytes_moved: int
    est_seconds: float          # bytes / link_bw + fixed overhead
    measured_seconds: Optional[float] = None


def tree_bytes(tree, path_regex: str = ".*") -> int:
    pat = re.compile(path_regex)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _PATH_JOIN.join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path)
        if pat.search(key):
            total += leaf.size * leaf.dtype.itemsize
    return total


def estimate_cost(bytes_moved: int, link_bandwidth: float,
                  fixed_overhead_s: float = 0.24) -> float:
    """Paper Table 2: ~0.25 s at 1 layer rising to ~0.9 s at 40 layers — a
    large fixed setup cost plus a linear bytes/bandwidth term."""
    return fixed_overhead_s + bytes_moved / link_bandwidth


def migrate_by_path(tree, path_regex: str, new_spec, mesh: Mesh, *,
                    link_bandwidth: float = 50e9, measure: bool = False):
    """Re-place every leaf whose path matches ``path_regex`` with
    NamedSharding(mesh, new_spec). Returns (new_tree, MigrationCost)."""
    pat = re.compile(path_regex)
    sh = NamedSharding(mesh, new_spec)
    moved = 0

    def maybe(path, leaf):
        nonlocal moved
        key = _PATH_JOIN.join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path)
        if pat.search(key):
            moved += leaf.size * leaf.dtype.itemsize
            return jax.device_put(leaf, sh)
        return leaf

    t0 = time.perf_counter()
    new_tree = jax.tree_util.tree_map_with_path(maybe, tree)
    if measure:
        jax.block_until_ready(new_tree)
    dt = time.perf_counter() - t0 if measure else None
    return new_tree, MigrationCost(moved, estimate_cost(moved, link_bandwidth),
                                   dt)


def migrate_kv_cache(cache, new_spec, mesh: Mesh, **kw):
    """KV-cache migration (the paper's memory-intensive module, §3.3)."""
    return migrate_by_path(cache, r"layers/", new_spec, mesh, **kw)


def migrate_paged_pool(state, new_spec, mesh: Mesh, **kw):
    """Re-place a whole paged block pool (serving/paged_kv.PagedState) —
    the pool-slice counterpart of ``migrate_kv_cache`` for engines on the
    primary decode path. Mutates ``state`` in place; returns
    (state, MigrationCost)."""
    handle = {"k": state.k, "v": state.v}
    new, cost = migrate_by_path(handle, r"^(k|v)$", new_spec, mesh, **kw)
    state.k, state.v = new["k"], new["v"]
    return state, cost


def probe_block_migration(cfg, n_tokens: int, *, block_size: int = 8,
                          repeats: int = 5, dtype="float32"):
    """Measure one request-sized block migration between two fresh pools:
    returns (median seconds, bytes moved). The micro-probe behind
    ``fit_migration_model``."""
    import numpy as np
    from repro.serving import paged_kv as PK

    times, nbytes = [], 0
    L, KVh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    n_blocks = 2 * (-(-n_tokens // block_size)) + 2
    for _ in range(repeats):
        src = PK.init_paged(cfg, 2, n_blocks, block_size=block_size,
                            dtype=dtype, max_len=n_tokens + block_size)
        dst = PK.init_paged(cfg, 2, n_blocks, block_size=block_size,
                            dtype=dtype, max_len=n_tokens + block_size)
        kv = np.zeros((L, n_tokens, KVh, hd), np.float32)
        PK.allocate(src, 0, n_tokens)
        PK.write_tokens(src, 0, kv, kv)
        jax.block_until_ready((src.k, dst.k))
        _, cost = migrate_blocks(src, dst, 0, 0, measure=True)
        times.append(cost.measured_seconds)
        nbytes = cost.bytes_moved
    times.sort()
    return times[len(times) // 2], nbytes


def fit_migration_model(cfg, *, block_size: int = 8, small_tokens: int = 16,
                        large_tokens: int = 512, repeats: int = 5):
    """Calibrate ``estimate_cost``'s two constants — fixed overhead and
    effective bandwidth — from two probe block-migrations on THIS host,
    exactly how the paper fits Table 2 to its testbed. Returns a dict
    with the fit plus the raw probes; feed the fit back into
    ``estimate_cost(bytes, bandwidth, fixed_overhead_s=overhead)`` and
    further measurements should land within 2x (asserted in tests and
    benchmarks/module_scaling_bench.py)."""
    t_small, b_small = probe_block_migration(
        cfg, small_tokens, block_size=block_size, repeats=repeats)
    t_large, b_large = probe_block_migration(
        cfg, large_tokens, block_size=block_size, repeats=repeats)
    if t_large > t_small and b_large > b_small:
        bw = (b_large - b_small) / (t_large - t_small)
    else:  # timer noise floor: overhead dominates, bandwidth unresolvable
        bw = 1e12
    overhead = max(t_small - b_small / bw, 1e-6)
    return {"fixed_overhead_s": overhead, "bandwidth_Bps": bw,
            "probe_small": {"bytes": b_small, "seconds": t_small},
            "probe_large": {"bytes": b_large, "seconds": t_large}}


def migrate_blocks(src_state, dst_state, src_slot: int, dst_slot: int, *,
                   link_bandwidth: float = 50e9,
                   fixed_overhead_s: float = 0.24,
                   measure: bool = False):
    """Block-granular migration of ONE live request between two engines'
    pools (CoCoServe scale-down / rebalance): export the request's blocks
    from ``src_state`` (serving/paged_kv.export_blocks wire format),
    release them at the source, and rebind them into ``dst_state`` at the
    same block-table columns — absolute positions, and therefore RoPE,
    window masking and counter-based sampling replay, are preserved.

    Prefix-shared (refcount > 1) source blocks are handled by the wire
    format itself: the payload MATERIALIZES their content (refcounts
    never cross pools) and carries their prefix keys, so the destination
    imports self-contained owned blocks, re-seeds its own prefix cache,
    and the source's co-holders keep their blocks (free_slot is a decref).

    Returns (payload, MigrationCost). Raises paged_kv.OutOfBlocks without
    touching the source when the destination can't hold the payload.
    """
    from repro.serving import paged_kv as PK

    t0 = time.perf_counter()
    payload = PK.export_blocks(src_state, src_slot)
    PK.import_blocks(dst_state, dst_slot, payload)   # raises before mutation
    PK.free_slot(src_state, src_slot)
    if measure:
        jax.block_until_ready((dst_state.k, dst_state.v))
    dt = time.perf_counter() - t0 if measure else None
    cost = MigrationCost(payload["nbytes"],
                         estimate_cost(payload["nbytes"], link_bandwidth,
                                       fixed_overhead_s),
                         dt)
    return payload, cost
