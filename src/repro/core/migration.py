"""Module migration — re-placement of parameters / caches (§3.1, §3.3).

On SPMD hardware "move module M from device A to device B" becomes
"re-shard/re-place M's arrays": a ``device_put`` with a new NamedSharding.
The cost model (bytes moved / link bandwidth + per-op latency) reproduces
the paper's Table 2 against our ICI constants; ``migrate_by_path`` performs
the actual re-placement for any params/cache subtree matched by regex.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_PATH_JOIN = "/"


@dataclasses.dataclass(frozen=True)
class MigrationCost:
    bytes_moved: int
    est_seconds: float          # bytes / link_bw + fixed overhead
    measured_seconds: Optional[float] = None


def tree_bytes(tree, path_regex: str = ".*") -> int:
    pat = re.compile(path_regex)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _PATH_JOIN.join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path)
        if pat.search(key):
            total += leaf.size * leaf.dtype.itemsize
    return total


def estimate_cost(bytes_moved: int, link_bandwidth: float,
                  fixed_overhead_s: float = 0.24) -> float:
    """Paper Table 2: ~0.25 s at 1 layer rising to ~0.9 s at 40 layers — a
    large fixed setup cost plus a linear bytes/bandwidth term."""
    return fixed_overhead_s + bytes_moved / link_bandwidth


def migrate_by_path(tree, path_regex: str, new_spec, mesh: Mesh, *,
                    link_bandwidth: float = 50e9, measure: bool = False):
    """Re-place every leaf whose path matches ``path_regex`` with
    NamedSharding(mesh, new_spec). Returns (new_tree, MigrationCost)."""
    pat = re.compile(path_regex)
    sh = NamedSharding(mesh, new_spec)
    moved = 0

    def maybe(path, leaf):
        nonlocal moved
        key = _PATH_JOIN.join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path)
        if pat.search(key):
            moved += leaf.size * leaf.dtype.itemsize
            return jax.device_put(leaf, sh)
        return leaf

    t0 = time.perf_counter()
    new_tree = jax.tree_util.tree_map_with_path(maybe, tree)
    if measure:
        jax.block_until_ready(new_tree)
    dt = time.perf_counter() - t0 if measure else None
    return new_tree, MigrationCost(moved, estimate_cost(moved, link_bandwidth),
                                   dt)


def migrate_kv_cache(cache, new_spec, mesh: Mesh, **kw):
    """KV-cache migration (the paper's memory-intensive module, §3.3)."""
    return migrate_by_path(cache, r"layers/", new_spec, mesh, **kw)
