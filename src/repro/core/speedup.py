"""The paper's speedup model (§4.1, Eqs. 1-4) — modified Amdahl's law.

* Eq. 1  W(P)  — accumulated per-layer compute, max over replicas.
* Eq. 2  T(P)  — replication communication, charged per replica entry and
  weighted by δ, the count of non-consecutive layer transitions.
* Eq. 3  S(P)  = W(P0) / (W(P) + T(P)).
* Eq. 4  S_homo(P) = 1 / (γ + (1-γ)/n · Σ_i 1/p_i), γ = δ·C/(d·B).

W and T are *proxies* positively correlated with real times (the paper says
so explicitly); only ratios are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.cluster import Cluster
from repro.core.plan import PlacementPlan


@dataclasses.dataclass(frozen=True)
class SpeedupModelConfig:
    d_model: int
    seq_len: int
    batch_size: int
    delta: float = 1.0            # per-boundary communication weight (δ)
    elem_bytes: int = 2           # bf16 activations on the wire
    flops_per_token_scale: float = 2.0  # 2 FLOPs per MAC


def even_batch_split(bs: int, p: int) -> List[int]:
    """The paper's even split (7/8 for bs=15, p=2 in Fig. 4)."""
    base, rem = divmod(bs, p)
    return [base + (1 if j < rem else 0) for j in range(p)]


def w_of(plan: PlacementPlan, m: SpeedupModelConfig,
         cluster: Cluster) -> float:
    """Eq. 1: W(P) = Σ_i max_j d² · bs_ij · l / C_ij."""
    total = 0.0
    for i in range(plan.n_layers):
        devs = plan.device_set(i)
        shares = even_batch_split(m.batch_size, len(devs))
        total += max(
            m.flops_per_token_scale * (m.d_model ** 2) * bs_ij * m.seq_len /
            cluster.device(dev).compute_flops
            for bs_ij, dev in zip(shares, devs))
    return total


def t_of(plan: PlacementPlan, m: SpeedupModelConfig,
         cluster: Cluster) -> float:
    """Eq. 2: T(P) = δ · Σ_i Σ_{j=1}^{p_i-1} d · bs_ij · l / B_ij.

    δ is realised as the plan's actual continuity-break count divided by the
    number of replicated layers (a uniform per-boundary weight): contiguous
    replica runs communicate only at their end points (§3.1).
    """
    breaks = plan.continuity_breaks()
    if breaks == 0:
        return 0.0
    rep_layers = max(plan.replicated_layer_count(), 1)
    delta_eff = m.delta * breaks / rep_layers
    total = 0.0
    for i in range(plan.n_layers):
        devs = plan.device_set(i)
        if len(devs) == 1:
            continue
        shares = even_batch_split(m.batch_size, len(devs))
        for bs_ij in shares[1:]:
            total += (m.elem_bytes * m.d_model * bs_ij * m.seq_len
                      / cluster.link_bandwidth)
    return delta_eff * total


def speedup(plan: PlacementPlan, m: SpeedupModelConfig,
            cluster: Cluster) -> float:
    """Eq. 3 for arbitrary (heterogeneous) clusters."""
    base = PlacementPlan.initial(plan.n_layers, plan.home_device)
    w0 = w_of(base, m, cluster)
    return w0 / (w_of(plan, m, cluster) + t_of(plan, m, cluster))


def gamma_of(cluster: Cluster, m: SpeedupModelConfig,
             breaks_per_layer: float = 0.05) -> float:
    """γ = δ·C/(d·B) — the homogeneous-cluster configuration constant.

    ``breaks_per_layer`` amortizes the boundary count over the stack (the
    paper's continuity-sorted plans keep replicas contiguous, so a handful of
    scatter/gather boundaries is spread over n layers).  C in FLOP/s, B in
    elements/s; the per-MAC factor cancels between W and T only partially,
    hence the explicit flops/elem scales.
    """
    c = cluster.devices[0].compute_flops / m.flops_per_token_scale
    b = cluster.link_bandwidth / m.elem_bytes
    return m.delta * breaks_per_layer * c / (m.d_model * b)


def speedup_homo(p: Sequence[int], gamma: float) -> float:
    """Eq. 4: S_homo(P) = 1 / (γ·[any replication] + (1-γ)/n · Σ 1/p_i).

    With P = P0 (all ones) the sum is n, so S = 1/(γ+(1-γ)) = 1 exactly when
    γ is charged; the paper's convention charges γ only once replication
    exists — we follow the formula literally (Σ 1/p_i handles P0: the γ term
    is constant, so S(P0)=1 requires γ + (1-γ) = 1, which holds).
    """
    n = len(p)
    inv = sum(1.0 / pi for pi in p)
    return 1.0 / (gamma + (1.0 - gamma) / n * inv)
