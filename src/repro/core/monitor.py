"""Metrics Monitor (§5): rolling-window metric collection feeding the
Controller. In the paper this reads NVML + engine timers; here it is fed
by the serving simulator and/or — through the live-telemetry interface —
the real paged Engine fleet (serving/orchestrator.py builds snapshots out
of serving/instrument.EngineTelemetry: block-pool vacancy, queue depth,
per-step wall latency, SLO violations)."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional


@dataclasses.dataclass
class MetricsSnapshot:
    t: float
    rps: float = 0.0
    tokens_per_s: float = 0.0
    p50_latency: float = 0.0
    p95_latency: float = 0.0
    slo_violation_rate: float = 0.0
    oom_events: int = 0
    queue_len: int = 0
    device_util: Optional[List[float]] = None       # 0..1 compute per device
    device_mem_frac: Optional[List[float]] = None   # 0..1 memory per device
    # --- live paged-engine telemetry (None when fed by the simulator) ---
    block_vacancy: Optional[List[float]] = None     # 0..1 free pool fraction
    step_seconds: float = 0.0                       # mean wall s per step
    preemptions: int = 0                            # pool-pressure evictions
    # --- prefix sharing (the vacancy signal already reflects sharing:
    # aliased blocks never leave the free count; these gauges say how much
    # of that vacancy copy-on-write sharing is buying) ---
    prefix_hit_rate: float = 0.0    # hit fraction of prompt-block lookups
    blocks_saved: int = 0           # physical blocks saved NOW by sharing
    # --- continuous batching (token-budget scheduler, DESIGN.md §10):
    # how full the per-step token budget packs, and how long requests
    # wait for their first token — the signals SLO-aware admission and
    # the controller's scale decisions act on ---
    budget_utilization: float = 0.0  # mean packed/budget over the window
    ttft_p50: float = 0.0            # engine-clock time-to-first-token
    ttft_p95: float = 0.0
    queue_delay_p95: float = 0.0     # submit -> first prefill chunk
    # --- failure domain (DESIGN.md §9): cumulative plane-wide counters,
    # all 0 outside chaos runs / real incidents ---
    faults_injected: int = 0        # transport faults the harness injected
    rpc_timeouts: int = 0           # calls that missed their deadline
    quarantines: int = 0            # hung peers severed + killed
    respawns: int = 0               # supervised restarts re-admitted
    # --- pod elasticity (DESIGN.md §11): how many serving instances the
    # pod currently has (alive, non-retired) — the population the
    # controller's grow/shrink decisions act on ---
    pod_size: int = 0


class Monitor:
    def __init__(self, window: int = 16):
        self.history: Deque[MetricsSnapshot] = deque(maxlen=window)

    def record(self, snap: MetricsSnapshot):
        self.history.append(snap)

    @property
    def latest(self) -> Optional[MetricsSnapshot]:
        return self.history[-1] if self.history else None

    def mean(self, field: str) -> float:
        vals = [getattr(s, field) for s in self.history
                if getattr(s, field) is not None]
        return sum(vals) / len(vals) if vals else 0.0

    def vacancy_rate(self) -> float:
        """Cluster-wide COMPUTE vacancy (drives T_up in §5).

        Deliberately compute-only: the paper's motivating waste is idle
        computational fragments on memory-full devices (a 70B instance
        spanning 4 GPUs leaves compute idle at low RPS) — replication can
        still exploit them as long as a layer replica fits (per-device
        free_mem gates that separately in Alg. 1).
        """
        snap = self.latest
        if snap is None or not snap.device_util:
            return 1.0
        # None entries are RETIRED pod slots (index kept for alignment,
        # instance reaped): they are not capacity, so they are excluded
        # from the average rather than counted busy or idle
        per_dev = [1.0 - u for u in snap.device_util if u is not None]
        return sum(per_dev) / len(per_dev) if per_dev else 1.0

    def slo_violation_rate(self) -> float:
        return self.mean("slo_violation_rate")

    def block_vacancy_rate(self) -> float:
        """Mean free fraction of the engines' block pools — the MEMORY
        vacancy signal of the live loop (what replication's KV blocks and
        scale-down migrations compete for)."""
        snap = self.latest
        if snap is None or not snap.block_vacancy:
            return 1.0
        vals = [v for v in snap.block_vacancy if v is not None]
        return sum(vals) / len(vals) if vals else 1.0

    def prefix_hit_rate(self) -> float:
        """Latest prompt-prefix cache hit rate across the fleet — how
        much of the admission load the block pool absorbs by aliasing
        instead of re-prefilling (0 when sharing is off or unexercised)."""
        snap = self.latest
        return snap.prefix_hit_rate if snap is not None else 0.0

    def blocks_saved_by_sharing(self) -> int:
        """Physical pool blocks currently saved by copy-on-write sharing
        (summed over instances) — the headroom sharing adds to the
        vacancy signal the §5 controller scales on."""
        snap = self.latest
        return snap.blocks_saved if snap is not None else 0

    def pool_pressure(self) -> bool:
        """OOM-analogue of the live loop: a preemption (a request evicted
        back to the queue for pool room) is the paged engine's recoverable
        out-of-memory event."""
        snap = self.latest
        return snap is not None and snap.preemptions > 0

    def hottest_device(self) -> Optional[int]:
        snap = self.latest
        if snap is None or not snap.device_util:
            return None
        load = [(-1.0 if u is None            # retired slot: never hot
                 else max(u, m if m is not None else 0.0))
                for u, m in zip(snap.device_util, snap.device_mem_frac
                                or [0.0] * len(snap.device_util))]
        if max(load) < 0:
            return None
        return max(range(len(load)), key=load.__getitem__)

    def is_memory_bound(self, device_id: int) -> bool:
        snap = self.latest
        if snap is None or not snap.device_mem_frac:
            return True
        mem = snap.device_mem_frac[device_id]
        util = (snap.device_util
                or [0.0] * len(snap.device_mem_frac))[device_id]
        if mem is None or util is None:
            return True
        return mem >= util
