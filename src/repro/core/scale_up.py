"""Scale-Up (Algorithm 1): greedy layer replication maximizing modeled
speedup, candidates sorted by layer continuity to minimize scatter/gather
boundaries.

Faithful to the paper: computes the current speedup via Eq. 4 (``1/(γ +
(1-γ)/n · ‖1 ⊘ P‖₁)``), iterates eligible nodes (by vacancy), derives
``max_replicas`` from free capacity / replica size r, sorts candidates by
continuity, simulates each replica addition and commits it only on speedup
improvement — guaranteeing monotone improvement.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.cluster import Cluster
from repro.core.plan import PlacementPlan
from repro.core.speedup import speedup_homo


def _inv_norm(p: List[int]) -> float:
    """‖1 ⊘ P‖₁ — L1 norm of the Hadamard quotient (paper's notation)."""
    return sum(1.0 / pi for pi in p)


def sort_candidates_by_continuity(plan: PlacementPlan, device_id: int,
                                  max_replicas: int) -> List[int]:
    """Priority: extend the longest contiguous replica run on this device;
    ties (and the no-replica-yet case) fall back to ascending layer id.

    Returns up to ``max_replicas`` candidate layer ids not yet replicated on
    the device.
    """
    on_dev = {i for i in range(plan.n_layers)
              if device_id in plan.replicas.get(i, [])}
    candidates = [i for i in range(plan.n_layers) if i not in on_dev]

    # longest contiguous run of already-replicated layers on this device
    runs = []  # (start, end) inclusive
    start = None
    for i in range(plan.n_layers + 1):
        if i < plan.n_layers and i in on_dev:
            if start is None:
                start = i
        else:
            if start is not None:
                runs.append((start, i - 1))
                start = None
    runs.sort(key=lambda r: -(r[1] - r[0] + 1))

    def priority(layer: int):
        # adjacency to the longest runs first, then layer index
        for rank, (s, e) in enumerate(runs):
            if layer == s - 1 or layer == e + 1:
                return (0, rank, layer)
        return (1, 0, layer)

    candidates.sort(key=priority)
    return candidates[:max_replicas]


def scale_up(plan: PlacementPlan, cluster: Cluster, *, gamma: float,
             replica_size: float,
             min_vacancy: float = 0.2,
             include_home: bool = False,
             max_degree: int = 2,
             commit: Optional[Callable[[int, int], None]] = None
             ) -> PlacementPlan:
    """Algorithm 1. ``replica_size`` is r (bytes+compute footprint of one
    layer replica); ``commit(layer, device)`` is the side-effecting
    ``replicate(model, layer_id, g_dst)`` hook (e.g. core/replication.py or
    the simulator's deployment table).
    Returns the improved plan P*.
    """
    best = plan.copy()
    sp_best = speedup_homo(best.p, gamma)
    for dev in cluster.eligible_nodes(min_vacancy):
        if dev.device_id == plan.home_device and not include_home:
            continue  # a replica co-located with its source adds no speedup
        max_replicas = int(dev.free_mem // replica_size)
        if max_replicas <= 0:
            continue
        candidates = sort_candidates_by_continuity(best, dev.device_id,
                                                   max_replicas)
        for layer_id in candidates:
            if best.p[layer_id] >= max_degree:  # paper's dop cap (Fig. 6c/d)
                continue
            trial = best.copy()
            trial.add_replica(layer_id, dev.device_id)
            sp = speedup_homo(trial.p, gamma)
            if sp > sp_best:
                best = trial
                sp_best = sp
                dev.used_mem += replica_size
                if commit is not None:
                    commit(layer_id, dev.device_id)
    return best


def scale_up_hetero(plan: PlacementPlan, cluster: Cluster, *,
                    model: "object", replica_size: float,
                    min_vacancy: float = 0.2, max_degree: int = 4,
                    commit: Optional[Callable[[int, int], None]] = None
                    ) -> PlacementPlan:
    """Heterogeneous-cluster variant of Algorithm 1 (paper §8): scores
    candidate replicas with the EXACT Eq. 3 speedup (per-device compute
    capacities and link bandwidths) instead of the homogeneous Eq. 4 closed
    form. ``model`` is a SpeedupModelConfig.
    """
    from repro.core.speedup import speedup

    best = plan.copy()
    sp_best = speedup(best, model, cluster)
    for dev in cluster.eligible_nodes(min_vacancy):
        if dev.device_id == plan.home_device:
            continue
        max_replicas = int(dev.free_mem // replica_size)
        if max_replicas <= 0:
            continue
        for layer_id in sort_candidates_by_continuity(best, dev.device_id,
                                                      max_replicas):
            if best.p[layer_id] >= max_degree:
                continue
            trial = best.copy()
            trial.add_replica(layer_id, dev.device_id)
            sp = speedup(trial, model, cluster)
            if sp > sp_best:
                best = trial
                sp_best = sp
                dev.used_mem += replica_size
                if commit is not None:
                    commit(layer_id, dev.device_id)
    return best
