"""InstanceHandle — the orchestrator's transport-agnostic view of one
serving instance.

The §5 control loop (serving/orchestrator.py) composes N model replicas.
Before the distributed plane, those were N in-process ``Engine`` objects
and the orchestrator reached straight into their attributes; now an
instance may equally be a real paged Engine living in ANOTHER PROCESS
behind the RPC wire protocol (serving/transport.py +
serving/remote_engine.py). This module defines the one interface both
sides present, so the orchestrator contains no transport knowledge at
all:

* **serving ops** — ``submit`` / ``step`` / ``apply_plan`` and the queue
  surgery the zero-drop paths need (``requeue_front``, ``push_queue``,
  ``drain_queue``);
* **telemetry** — every handle owns an ``EngineTelemetry`` (local:
  recorded around the direct call; remote: a mirror refreshed from the
  server's serialized snapshot piggybacked on each step reply) plus the
  point gauges ``free_blocks`` / ``blocks_in_use`` / ``queue_len`` /
  ``active_rids`` / ``clock`` / ``preempt_count`` / ``prefix_stats``
  the orchestrator folds into ``core.monitor.MetricsSnapshot``;
* **migration** — the stop-the-world pair (``pause_request`` /
  ``resume_request``) and the two-phase overlapped quartet
  (``snapshot_request`` → ``prepare_resume`` → ``pause_request(...,
  since_epoch)`` → ``commit_resume`` | ``abort_resume``).
  ``prepare_resume_async`` returns a waitable so the orchestrator can
  keep the bulk phase-1 import in flight on the destination while it
  keeps STEPPING the source — the overlap that bounds the victim
  stream's stall to the phase-2 delta;
* **liveness** — ``alive`` / ``close``; a dead remote raises
  ``transport.TransportClosed`` from any op, which the orchestrator's
  crash recovery turns into re-queue + deterministic replay of the
  handle's ``inflight_requests`` mirror.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax

from repro.serving import instrument as INS
from repro.serving.engine import Engine, Request
from repro.serving.instrument import EngineTelemetry
from repro.serving.request import RequestSpec


def pristine(req: Request) -> Request:
    """A replayable clone: same identity/sampling state (rid, prompt,
    seed, counters restart at 0), all per-run mutable state reset.
    Counter-based sampling keys make re-running it from scratch
    reproduce the original stream token-for-token — the zero-drop
    recovery primitive."""
    return dataclasses.replace(
        req, generated=[], slot=None, submit_time=0.0,
        first_token_time=None, finish_time=None, preemptions=0,
        prefill_pos=0, prefill_start_time=None)


class Completed:
    """Already-resolved stand-in for a transport ``Pending`` (local
    handles execute synchronously)."""

    def __init__(self, value):
        self._value = value

    def wait(self):
        return self._value


class InstanceHandle:
    """Abstract control surface of one serving instance (see module
    docstring). Concrete: ``LocalInstance`` below,
    ``remote_engine.EngineProxy`` for the multi-process plane."""

    telemetry: EngineTelemetry

    # ------------------------------------------------------ serving ops
    def submit(self, spec: RequestSpec, trace: Optional[dict] = None):
        """Enqueue one request, described by its construction-time
        ``RequestSpec`` (serving/request.py — the engine mints the
        mutable ``Request``). ``trace`` is an optional observe.Tracer
        propagation context ({"trace_id", "rid"}) that makes the
        instance record engine-side spans for this request."""
        raise NotImplementedError

    def set_token_budget(self, budget: int) -> int:
        """Retarget the engine's per-step token budget (the ingress
        budget governor's knob). Returns the budget now in force; 0
        means the instance has no budgeted scheduler to govern."""
        return 0

    def step(self) -> List[Request]:
        raise NotImplementedError

    def step_async(self):
        """Fan-out half of the orchestrator's batched control-plane
        poll: return a waitable (``transport.Pending`` for a remote
        instance, ``Completed`` here) whose resolution is the opaque
        step reply ``finish_step`` consumes. The default executes the
        step synchronously — a local engine shares the orchestrator's
        process, so there is nothing to overlap."""
        return Completed(self.step())

    def finish_step(self, reply) -> List[Request]:
        """Consume one resolved ``step_async`` reply, returning the
        finished requests. Local steps already ARE the finished list."""
        return reply

    def mark_dead(self):
        """Record a transport death observed outside a direct call
        (e.g. a ``closed`` entry from the batched poll). Local
        instances cannot outlive the orchestrator: no-op."""

    def apply_plan(self, p: List[int]):
        raise NotImplementedError

    def requeue_front(self, req: Request):
        raise NotImplementedError

    def push_queue(self, req: Request):
        raise NotImplementedError

    def drain_queue(self) -> List[Request]:
        raise NotImplementedError

    # -------------------------------------------------------- telemetry
    def queue_len(self) -> int:
        raise NotImplementedError

    def active_rids(self) -> Dict[int, int]:
        """slot -> rid of every request HOLDING a slot — decoding or
        mid-prefill (chunked prefill makes partially-prefilled state
        first-class: such slots hold blocks and are migratable)."""
        raise NotImplementedError

    def active_count(self) -> int:
        return len(self.active_rids())

    def free_blocks(self) -> int:
        raise NotImplementedError

    def blocks_in_use(self) -> int:
        raise NotImplementedError

    @property
    def n_blocks(self) -> int:
        raise NotImplementedError

    @property
    def max_batch(self) -> int:
        raise NotImplementedError

    def pool_bytes(self) -> int:
        raise NotImplementedError

    def clock(self) -> float:
        raise NotImplementedError

    def preempt_count(self) -> int:
        raise NotImplementedError

    def prefix_stats(self) -> dict:
        raise NotImplementedError

    @property
    def block_size(self) -> int:
        """Pool block granularity (0 = dense/no pool) — what the pod
        router hashes incoming prompts by (serving/router.py)."""
        return 0

    def prefix_keys(self) -> set:
        """Hex content-chain keys resident in this instance's prefix
        cache, as of the last observation — the router's affinity
        signal. May be one step stale for a remote instance (costs a
        routing miss, never correctness)."""
        return set()

    def stream_view(self) -> Dict[int, List[int]]:
        """rid -> tokens generated so far by every slot-holding request,
        as of the last completed step — the ingress streaming feed.
        Full token lists (idempotent under migration/replay), not
        deltas; consumers keep a high-water mark."""
        return {}

    # ---------------------------------------------------------- tracing
    def register_trace(self, ctx: dict):
        """Associate a trace context with its rid on this instance so
        engine-side spans record for it — the explicit path migration /
        replay continuations use (a fresh submit carries the context on
        the frame instead). Default: tracing not wired, no-op."""

    def drain_spans(self) -> List[dict]:
        """Engine-recorded spans closed since the last drain, already
        on the ORCHESTRATOR's clock (remote handles skew-correct before
        buffering). The orchestrator feeds these to the Tracer each
        step."""
        return []

    # -------------------------------------------------------- migration
    def pause_request(self, slot: int,
                      since_epoch: Optional[int] = None) -> dict:
        raise NotImplementedError

    def resume_request(self, payload: dict) -> bool:
        raise NotImplementedError

    def snapshot_request(self, slot: int) -> dict:
        raise NotImplementedError

    def prepare_resume(self, snap: dict) -> Optional[int]:
        return self.prepare_resume_async(snap).wait()

    def prepare_resume_async(self, snap: dict):
        raise NotImplementedError

    def commit_resume(self, slot: int, payload: dict) -> bool:
        raise NotImplementedError

    def abort_resume(self, slot: int):
        raise NotImplementedError

    # --------------------------------------------------------- liveness
    #: can the orchestrator's supervisor restart this instance after it
    #: dies? True only for remote handles whose server process we own
    #: (EngineProxy overrides with a property).
    respawnable: bool = False

    def alive(self) -> bool:
        return True

    def set_rpc_deadline(self, seconds: Optional[float]):
        """Per-call deadline for remote handles; a local call cannot
        hang independently of the orchestrator — no-op."""

    def probe(self, timeout: float = 1.0) -> str:
        """Hung-vs-dead classification after a missed deadline
        (``"alive"`` / ``"hung"`` / ``"dead"``). A local instance is
        exactly as alive as its ``alive()``."""
        return "alive" if self.alive() else "dead"

    def quarantine(self):
        """Permanently remove a hung peer from the plane (close
        transport, kill an owned process). Local instances share our
        process: nothing to sever."""

    def inflight_requests(self) -> List[Request]:
        """Replayable clones of every request this instance currently
        holds (queued or active) — the crash-recovery worklist. Local
        instances die with the orchestrator, so theirs is empty."""
        return []

    def close(self):
        pass


class LocalInstance(InstanceHandle):
    """An Engine in this process behind the handle interface — the
    degenerate transport. Telemetry is recorded around the direct call
    (mirroring what a remote engine server does around its)."""

    def __init__(self, engine: Engine,
                 telemetry: Optional[EngineTelemetry] = None):
        self.engine = engine
        self.telemetry = telemetry or EngineTelemetry()
        self._recorder = None   # lazy observe.EngineSpanRecorder

    # ------------------------------------------------------ serving ops
    def submit(self, spec: RequestSpec, trace: Optional[dict] = None):
        if trace is not None:
            self.register_trace(trace)
        self.engine.submit(spec)

    def set_token_budget(self, budget: int) -> int:
        return self.engine.set_token_budget(budget)

    # ---------------------------------------------------------- tracing
    def register_trace(self, ctx: dict):
        if self._recorder is None:
            from repro.serving import observe as OBS
            self._recorder = OBS.EngineSpanRecorder(origin="local")
            self.engine.span_hook = self._recorder
        self._recorder.register(int(ctx["rid"]), ctx["trace_id"])

    def drain_spans(self) -> List[dict]:
        return self._recorder.drain() if self._recorder else []

    def step(self) -> List[Request]:
        return INS.timed_step(self.engine, self.telemetry)

    def apply_plan(self, p):
        self.engine.apply_plan(p)

    def requeue_front(self, req: Request):
        self.engine.queue.appendleft(req)

    def push_queue(self, req: Request):
        self.engine.queue.append(req)

    def drain_queue(self) -> List[Request]:
        out = []
        while self.engine.queue:
            out.append(self.engine.queue.popleft())
        return out

    # -------------------------------------------------------- telemetry
    def queue_len(self) -> int:
        return len(self.engine.queue)

    def active_rids(self) -> Dict[int, int]:
        return self.engine.slot_rids()

    def free_blocks(self) -> int:
        return self.engine.pstate.free_block_count()

    def blocks_in_use(self) -> int:
        return self.engine.pstate.blocks_in_use()

    @property
    def n_blocks(self) -> int:
        return self.engine.pstate.n_blocks

    @property
    def max_batch(self) -> int:
        return self.engine.max_batch

    def pool_bytes(self) -> int:
        return self.engine.pstate.pool_bytes()

    def clock(self) -> float:
        return self.engine.clock

    def preempt_count(self) -> int:
        return self.engine.preempt_count

    def prefix_stats(self) -> dict:
        return self.engine.prefix_stats()

    @property
    def block_size(self) -> int:
        return self.engine.block_size

    def prefix_keys(self) -> set:
        return self.engine.prefix_keys()

    def stream_view(self) -> Dict[int, List[int]]:
        return self.engine.stream_progress()

    # -------------------------------------------------------- migration
    def pause_request(self, slot: int,
                      since_epoch: Optional[int] = None) -> dict:
        return self.engine.pause_request(slot, since_epoch=since_epoch)

    def resume_request(self, payload: dict) -> bool:
        ok = self.engine.resume_request(payload)
        jax.block_until_ready((self.engine.pstate.k,
                               self.engine.pstate.v))
        return ok

    def snapshot_request(self, slot: int) -> dict:
        return self.engine.snapshot_request(slot)

    def prepare_resume_async(self, snap: dict) -> Completed:
        return Completed(self.engine.prepare_resume(snap))

    def commit_resume(self, slot: int, payload: dict) -> bool:
        ok = self.engine.commit_resume(slot, payload)
        jax.block_until_ready((self.engine.pstate.k,
                               self.engine.pstate.v))
        return ok

    def abort_resume(self, slot: int):
        self.engine.abort_resume(slot)
