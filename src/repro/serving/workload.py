"""Workload generation: Poisson arrivals with Alpaca-like length profiles.

The paper evaluates with the Alpaca dataset, max generation length 256, at
request rates 3-55 RPS. We reproduce the shape statistically: prompt lengths
lognormal around ~64 tokens, output lengths capped at 256.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class SimRequest:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int
    # filled by the simulator
    first_token: float = -1.0
    finish: float = -1.0
    generated: int = 0
    dropped: bool = False

    @property
    def latency(self) -> float:
        return self.finish - self.arrival if self.finish >= 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    rps: float = 10.0
    duration_s: float = 60.0
    seed: int = 0
    mean_prompt: float = 64.0
    max_output: int = 256
    mean_output: float = 64.0   # Alpaca-like outputs, capped at 256


def generate(cfg: WorkloadConfig) -> List[SimRequest]:
    rng = np.random.default_rng(cfg.seed)
    t = 0.0
    out: List[SimRequest] = []
    rid = 0
    while True:
        t += rng.exponential(1.0 / cfg.rps)
        if t > cfg.duration_s:
            break
        plen = int(np.clip(rng.lognormal(np.log(cfg.mean_prompt), 0.6), 8, 512))
        olen = int(np.clip(rng.exponential(cfg.mean_output), 4, cfg.max_output))
        out.append(SimRequest(rid=rid, arrival=t, prompt_len=plen,
                              output_len=olen))
        rid += 1
    return out


def generate_trace(cfg: WorkloadConfig, pattern: str = "burst",
                   burst_factor: float = 4.0) -> List[SimRequest]:
    """Non-stationary traffic (the paper's 'unpredictable traffic patterns'):

    * ``burst``   — baseline RPS with a burst_factor spike in the middle
      third of the run (tests scale-down reactions);
    * ``diurnal`` — sinusoidal rate between 0.25x and 1.75x of cfg.rps
      (tests scale-up re-use of freed capacity).
    """
    rng = np.random.default_rng(cfg.seed)
    t, rid = 0.0, 0
    out: List[SimRequest] = []
    while t < cfg.duration_s:
        frac = t / cfg.duration_s
        if pattern == "burst":
            rate = cfg.rps * (burst_factor if 1 / 3 <= frac <= 2 / 3 else 1.0)
        else:  # diurnal
            rate = cfg.rps * (1.0 + 0.75 * np.sin(2 * np.pi * frac))
            rate = max(rate, 0.25 * cfg.rps)
        t += rng.exponential(1.0 / rate)
        if t > cfg.duration_s:
            break
        plen = int(np.clip(rng.lognormal(np.log(cfg.mean_prompt), 0.6), 8, 512))
        olen = int(np.clip(rng.exponential(cfg.mean_output), 4, cfg.max_output))
        out.append(SimRequest(rid=rid, arrival=t, prompt_len=plen,
                              output_len=olen))
        rid += 1
    return out
