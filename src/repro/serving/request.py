"""The one request representation (``RequestSpec``) and its validation.

Before this module, a request's parameters lived in three divergent
ad-hoc shapes: the ingress parsed JSON into a loose ``spec`` dict,
``Engine.submit`` took a fully-formed mutable ``Request``, and the
migration pause/resume path shipped yet another raw dict. There was no
principled place to thread an SLO class or a deadline through the
stack — each new field had to be added to every shape by hand and
silently fell off whichever path forgot it.

``RequestSpec`` is the construction-time contract everywhere now:

* the HTTP ingress parses a completion body straight into a spec
  (unknown fields, bad SLO classes and non-positive deadlines are
  rejected with distinct 400 bodies — see ``SpecError.code``);
* ``Engine.submit`` accepts ONLY a spec and mints the engine-internal
  mutable ``Request`` from it (``to_request``), so runtime bookkeeping
  (generated tokens, slot, timestamps, preemption counters) can never
  leak into the submission API;
* the router's admission decision sees the spec (``slo_class`` decides
  how much queue headroom a request may consume);
* replay and oracle re-runs rebuild a pristine spec from a live request
  (``from_request``) instead of hand-rolling ``dataclasses.replace``
  field lists that rot whenever ``Request`` grows a field.

The spec is immutable (frozen): submitting the same spec to two engines
can never alias state, which is what makes the crash-replay and
token-identity oracles trivially safe.

``MIGRATION_WIRE_VERSION`` stamps every pause/snapshot payload the
engine exports. Resume-side checks reject an old or missing version
with a clear ``ValueError`` (surfaced as ``RemoteError`` over RPC)
instead of a ``KeyError`` deep inside ``_bind_resumed``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

# SLO classes, highest priority first. ``interactive`` streams are
# latency-sensitive (chat turns); ``standard`` is the default;
# ``batch`` is throughput traffic that may be arbitrarily delayed and
# is always the first preemption victim.
SLO_CLASSES = ("interactive", "standard", "batch")

# Version stamped into pause_request / snapshot_request payloads.
# Bump when the payload shape changes; resume-side ops reject any
# mismatch so a rolling upgrade fails loudly, not with a KeyError.
MIGRATION_WIRE_VERSION = 2


class SpecError(ValueError):
    """A request spec failed validation. ``code`` is a stable
    machine-readable discriminator the ingress maps to its 400
    taxonomy; ``detail`` is the human sentence."""

    def __init__(self, code: str, detail: str):
        super().__init__(detail)
        self.code = code
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How to sample the continuation — separated from the spec so the
    knobs travel (and default) as one unit."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def validate(self):
        if self.temperature < 0.0:
            raise SpecError("malformed", f"temperature < 0: {self.temperature}")
        if self.top_k < 0:
            raise SpecError("malformed", f"top_k < 0: {self.top_k}")


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """Everything the caller gets to say about one generation request.

    ``rid`` is the caller-assigned stream id (the ingress and serve
    loops mint them); ``prompt`` is a 1-D int token array. ``deadline_ms``
    is a wall-clock completion target used for ordering within an SLO
    class and for attainment accounting — it is not an enforcement
    mechanism (a missed deadline finishes late, it is not killed)."""
    rid: int
    prompt: Union[np.ndarray, Sequence[int]]
    max_tokens: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None
    slo_class: str = "standard"
    deadline_ms: Optional[float] = None

    def validate(self):
        """Raise ``SpecError`` on any out-of-contract field. Called by
        ``Engine.submit`` (and by the ingress before routing, so the
        client sees a typed 400 instead of an engine assertion)."""
        if len(self.prompt) == 0:
            raise SpecError("malformed", "empty prompt")
        if self.max_tokens < 1:
            raise SpecError("malformed", f"max_tokens < 1: {self.max_tokens}")
        if self.slo_class not in SLO_CLASSES:
            raise SpecError(
                "unknown_slo_class",
                f"unknown slo_class {self.slo_class!r} "
                f"(allowed: {', '.join(SLO_CLASSES)})")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise SpecError(
                "bad_deadline",
                f"deadline_ms must be positive, got {self.deadline_ms}")
        self.sampling.validate()

    def to_request(self):
        """Mint the engine-internal mutable ``Request``. Fresh every
        call — two engines fed the same spec never share state."""
        from repro.serving.engine import Request
        return Request(
            rid=self.rid,
            prompt=self.prompt,
            max_new_tokens=self.max_tokens,
            eos_id=self.eos_id,
            temperature=self.sampling.temperature,
            top_k=self.sampling.top_k,
            seed=self.sampling.seed,
            slo_class=self.slo_class,
            deadline_ms=self.deadline_ms,
        )

    @classmethod
    def from_request(cls, req) -> "RequestSpec":
        """Recover the construction-time spec from a live (possibly
        finished) ``Request`` — the principled pristine clone used by
        crash replay and token-identity oracles. A spec passes through
        unchanged (it is already pristine), so replay worklists may mix
        live requests and mirrored specs."""
        if isinstance(req, cls):
            return req
        return cls(
            rid=req.rid,
            prompt=req.prompt,
            max_tokens=req.max_new_tokens,
            sampling=SamplingParams(temperature=req.temperature,
                                    top_k=req.top_k, seed=req.seed),
            eos_id=req.eos_id,
            slo_class=getattr(req, "slo_class", "standard"),
            deadline_ms=getattr(req, "deadline_ms", None),
        )
