"""Message-framed RPC wire protocol for the distributed serving plane.

The multi-process deployment (serving/remote_engine.py) runs each paged
``Engine`` in its own OS process behind an engine-server loop; everything
the orchestrator exchanges with it — admissions, per-step telemetry,
controller plans, and the column-keyed block-migration payloads of
``serving/paged_kv.export_blocks`` — travels through THIS module as
length-prefixed frames over a stream socket (AF_UNIX on the same host;
the same framing works unchanged over TCP between hosts). No shared
memory anywhere: a frame is the only way state crosses a process
boundary, which is what makes the plane deployable across machines
(FlexPipe's "explicit wire protocol" requirement).

Frame layout (all integers big-endian)::

    +--------+-----------+----------------------+
    | u32    | u8        | payload              |
    | length | codec tag | ``length - 1`` bytes |
    +--------+-----------+----------------------+

Codec tag ``M`` is msgpack with two extension conventions — numpy
arrays as ``{b"__nd__": (dtype str, shape, C-bytes)}`` and
``serving.engine.Request`` as ``{b"__req__": field dict}`` — so the hot
payloads (block data, token arrays) move as raw bytes with zero pickle
overhead. Tag ``P`` is a pickle fallback for messages msgpack cannot
express (configs, arbitrary trees: the one-time ``init`` message). The
receiver dispatches on the tag, so both ends can mix codecs freely and
a container without msgpack still interoperates.

RPC on top of frames is deliberately minimal: requests are
``{"id": n, "op": name, "args": [...], "kw": {...}}``, replies are
``{"id": n, "ok": True, "result": ...}`` or ``{"id": n, "ok": False,
"error": repr, "kind": exception-class-name}``. ``Rpc.call`` blocks for
the matching reply; ``Rpc.call_async`` pipelines — the server processes
in order, so a caller can keep a slow operation (a phase-1 block
import) in flight on one peer while it keeps stepping another: that is
the overlap in "overlapped migration".
"""
from __future__ import annotations

import io
import os
import pickle
import socket
import struct
import tempfile
import uuid
from typing import Any, Callable, Dict, Optional

import numpy as np

try:  # optional: the frame format downgrades to pickle without it
    import msgpack  # type: ignore
except ImportError:  # pragma: no cover - CI bakes msgpack in
    msgpack = None

_LEN = struct.Struct(">I")
TAG_MSGPACK = b"M"
TAG_PICKLE = b"P"
MAX_FRAME = 1 << 31  # sanity bound: a corrupt length prefix fails loudly


class TransportError(RuntimeError):
    """Framing/codec violation on a live connection."""


class TransportClosed(TransportError):
    """Peer hung up (EOF mid-frame or closed socket) — the signal the
    orchestrator's crash recovery (re-queue + replay) keys on."""


class RemoteError(RuntimeError):
    """An exception raised INSIDE the peer's handler, re-raised at the
    caller with the remote repr. ``kind`` preserves the remote class
    name so callers can branch (e.g. on ``OutOfBlocks``) without
    importing anything."""

    def __init__(self, kind: str, message: str):
        super().__init__(f"{kind}: {message}")
        self.kind = kind


# ------------------------------------------------------------------ codecs
def _np_encode(arr: np.ndarray):
    a = np.ascontiguousarray(arr)
    return {b"__nd__": (str(a.dtype), list(a.shape), a.tobytes())}


def _msgpack_default(obj):
    # jnp arrays arrive here too (they fail the isinstance below only if
    # jax is absent, which cannot happen in this repo) — np.asarray is a
    # host copy either way, which the wire format needs regardless.
    if isinstance(obj, np.ndarray):
        return _np_encode(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if type(obj).__name__ == "ArrayImpl":  # jax array without importing jax
        return _np_encode(np.asarray(obj))
    if type(obj).__name__ == "Request":
        import dataclasses
        return {b"__req__": dataclasses.asdict(obj)}
    raise TypeError(f"not msgpack-encodable: {type(obj)!r}")


def _msgpack_object_hook(obj: dict):
    if b"__nd__" in obj and len(obj) == 1:
        dtype, shape, buf = obj[b"__nd__"]
        return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
    if b"__req__" in obj and len(obj) == 1:
        from repro.serving.engine import Request
        return Request(**obj[b"__req__"])
    return obj


def encode(obj: Any, prefer: str = "msgpack") -> bytes:
    """Serialize ``obj`` to one frame body (tag byte + payload)."""
    if prefer == "msgpack" and msgpack is not None:
        try:
            body = msgpack.packb(obj, default=_msgpack_default,
                                 use_bin_type=True, strict_types=False)
            return TAG_MSGPACK + body
        except (TypeError, ValueError):
            pass  # not msgpack-shaped (configs, pytrees): pickle frame
    return TAG_PICKLE + pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def decode(frame: bytes) -> Any:
    tag, body = frame[:1], frame[1:]
    if tag == TAG_MSGPACK:
        if msgpack is None:  # pragma: no cover
            raise TransportError("msgpack frame but msgpack unavailable")
        return msgpack.unpackb(body, object_hook=_msgpack_object_hook,
                               raw=False, strict_map_key=False)
    if tag == TAG_PICKLE:
        return pickle.loads(body)
    raise TransportError(f"unknown codec tag {tag!r}")


# ------------------------------------------------------------- connections
class Connection:
    """One framed, bidirectional message stream over a socket."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._rx = sock.makefile("rb")
        self.tx_frames = 0
        self.rx_frames = 0
        self.tx_bytes = 0
        self.rx_bytes = 0

    def send(self, obj: Any):
        frame = encode(obj)
        if len(frame) >= MAX_FRAME:
            raise TransportError(f"frame too large: {len(frame)} bytes")
        try:
            self._sock.sendall(_LEN.pack(len(frame)) + frame)
        except (BrokenPipeError, ConnectionResetError, OSError) as e:
            raise TransportClosed(f"send on dead connection: {e}") from e
        self.tx_frames += 1
        self.tx_bytes += len(frame) + _LEN.size

    def _read_exact(self, n: int) -> bytes:
        buf = self._rx.read(n)
        if buf is None or len(buf) != n:
            raise TransportClosed(
                f"peer closed mid-frame (wanted {n} bytes, "
                f"got {0 if not buf else len(buf)})")
        return buf

    def recv(self) -> Any:
        try:
            (length,) = _LEN.unpack(self._read_exact(_LEN.size))
        except TransportClosed:
            raise
        except (OSError, ValueError) as e:
            raise TransportClosed(f"recv on dead connection: {e}") from e
        if not 0 < length < MAX_FRAME:
            raise TransportError(f"corrupt frame length {length}")
        frame = self._read_exact(length)
        self.rx_frames += 1
        self.rx_bytes += length + _LEN.size
        return decode(frame)

    def close(self):
        for closer in (self._rx.close, self._sock.close):
            try:
                closer()
            except OSError:
                pass


def socketpair() -> tuple:
    """In-process connected pair (tests, threads) with the same framing."""
    a, b = socket.socketpair()
    return Connection(a), Connection(b)


def listener_address() -> str:
    """Fresh AF_UNIX rendezvous path for one parent<->child connection."""
    return os.path.join(tempfile.gettempdir(),
                        f"repro-engine-{os.getpid()}-{uuid.uuid4().hex}.sock")


def listen(address: str) -> socket.socket:
    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    srv.bind(address)
    srv.listen(1)
    return srv


def accept(srv: socket.socket, timeout: Optional[float] = 60.0) -> Connection:
    srv.settimeout(timeout)
    try:
        sock, _ = srv.accept()
    except socket.timeout as e:
        raise TransportError("engine server never connected") from e
    finally:
        srv.settimeout(None)
    sock.settimeout(None)
    return Connection(sock)


def connect(address: str, timeout: float = 60.0) -> Connection:
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(timeout)
    sock.connect(address)
    sock.settimeout(None)
    return Connection(sock)


# -------------------------------------------------------------------- rpc
class Pending:
    """Handle for a pipelined ``call_async``; ``wait()`` blocks until the
    matching reply arrives (draining any earlier pipelined replies)."""

    def __init__(self, rpc: "Rpc", call_id: int):
        self._rpc = rpc
        self.call_id = call_id

    def wait(self) -> Any:
        return self._rpc._wait(self.call_id)


class Rpc:
    """Client side: request/reply (+ pipelining) over a Connection."""

    def __init__(self, conn: Connection):
        self.conn = conn
        self._next_id = 0
        self._replies: Dict[int, Any] = {}

    def call_async(self, op: str, *args, **kw) -> Pending:
        self._next_id += 1
        cid = self._next_id
        self.conn.send({"id": cid, "op": op, "args": list(args), "kw": kw})
        return Pending(self, cid)

    def call(self, op: str, *args, **kw) -> Any:
        return self.call_async(op, *args, **kw).wait()

    def _wait(self, call_id: int) -> Any:
        while call_id not in self._replies:
            reply = self.conn.recv()
            self._replies[reply["id"]] = reply
        reply = self._replies.pop(call_id)
        if not reply.get("ok"):
            raise RemoteError(reply.get("kind", "RuntimeError"),
                              reply.get("error", "remote failure"))
        return reply.get("result")

    def close(self):
        self.conn.close()


def serve(conn: Connection, dispatch: Dict[str, Callable],
          *, stop_op: str = "shutdown"):
    """Server side: dispatch loop until ``stop_op`` or peer hangup.

    Handler exceptions are caught and returned as error replies (the
    server survives an ``OutOfBlocks`` on import); transport errors end
    the loop — the parent is gone, so is our reason to exist."""
    while True:
        try:
            msg = conn.recv()
        except TransportClosed:
            return
        cid, op = msg.get("id"), msg.get("op")
        if op == stop_op:
            conn.send({"id": cid, "ok": True, "result": None})
            return
        fn = dispatch.get(op)
        try:
            if fn is None:
                raise KeyError(f"unknown op {op!r}")
            result = fn(*msg.get("args", ()), **msg.get("kw", {}))
            reply = {"id": cid, "ok": True, "result": result}
        except Exception as e:  # noqa: BLE001 - proxied to the caller
            reply = {"id": cid, "ok": False,
                     "kind": type(e).__name__, "error": str(e)}
        try:
            conn.send(reply)
        except TransportClosed:
            return


def _np_roundtrip_selftest():  # pragma: no cover - debugging aid
    buf = io.BytesIO()
    a = np.arange(6, dtype=np.int32).reshape(2, 3)
    buf.write(encode({"a": a}))
    out = decode(buf.getvalue())
    assert (out["a"] == a).all()
