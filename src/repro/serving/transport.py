"""Message-framed RPC wire protocol for the distributed serving plane.

The multi-process deployment (serving/remote_engine.py) runs each paged
``Engine`` in its own OS process behind an engine-server loop; everything
the orchestrator exchanges with it — admissions, per-step telemetry,
controller plans, and the column-keyed block-migration payloads of
``serving/paged_kv.export_blocks`` — travels through THIS module as
length-prefixed frames over a stream socket. Two endpoint families share
the one frame format:

* ``unix`` — an AF_UNIX path (same-host child processes, the PR-4
  rendezvous);
* ``tcp://host:port`` — AF_INET between hosts: the multi-host pod
  (launch/pod.py) runs engine servers as listening TCP endpoints and
  the orchestrator connects with retry/backoff (a server that is still
  booting looks exactly like a connection refused). A half-open or
  reset TCP peer surfaces as ``TransportClosed`` from the next
  send/recv — the same crash signal the AF_UNIX plane uses, so crash
  recovery is transport-blind.

No shared memory anywhere: a frame is the only way state crosses a
process boundary, which is what makes the plane deployable across
machines (FlexPipe's "explicit wire protocol" requirement).

Frame layout (all integers big-endian)::

    +--------+-----------+----------------------+
    | u32    | u8        | payload              |
    | length | codec tag | ``length - 1`` bytes |
    +--------+-----------+----------------------+

Codec tag ``M`` is msgpack with two extension conventions — numpy
arrays as ``{b"__nd__": (dtype str, shape, C-bytes)}`` and
``serving.engine.Request`` as ``{b"__req__": field dict}`` — so the hot
payloads (block data, token arrays) move as raw bytes with zero pickle
overhead. Tag ``P`` is a pickle fallback for messages msgpack cannot
express (configs, arbitrary trees: the one-time ``init`` message). The
receiver dispatches on the tag, so both ends can mix codecs freely and
a container without msgpack still interoperates.

RPC on top of frames is deliberately minimal: requests are
``{"id": n, "op": name, "args": [...], "kw": {...}}``, replies are
``{"id": n, "ok": True, "result": ...}`` or ``{"id": n, "ok": False,
"error": repr, "kind": exception-class-name}``. ``Rpc.call`` blocks for
the matching reply; ``Rpc.call_async`` pipelines — the server processes
in order, so a caller can keep a slow operation (a phase-1 block
import) in flight on one peer while it keeps stepping another: that is
the overlap in "overlapped migration".

``drain_pendings`` is the control plane's batched poll: fan a request
out to every peer with ``call_async``, then ONE ``selectors``-
multiplexed wait drains all replies as they land. The callers' wall
time is bounded by the slowest peer, not the sum of round trips, and a
peer that dies mid-poll resolves its entries to ``TransportClosed``
instead of aborting the drain — crash detection folds into the same
poll that collects results.
"""
from __future__ import annotations

import io
import os
import pickle
import select
import selectors
import socket
import struct
import tempfile
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

try:  # optional: the frame format downgrades to pickle without it
    import msgpack  # type: ignore
except ImportError:  # pragma: no cover - CI bakes msgpack in
    msgpack = None

_LEN = struct.Struct(">I")
TAG_MSGPACK = b"M"
TAG_PICKLE = b"P"
MAX_FRAME = 1 << 31  # sanity bound: a corrupt length prefix fails loudly
_RECV_CHUNK = 1 << 16

# receive-side allocation bound (satellite: a corrupt/hostile length
# prefix must fail the connection, not attempt a multi-GB bytearray).
# Block-migration payloads on real configs run to tens of MB; 256 MB
# leaves an order of magnitude of headroom while still refusing the
# 2^31-ish garbage a misframed stream produces.
DEFAULT_MAX_RECV_FRAME = int(os.environ.get("REPRO_MAX_FRAME_BYTES",
                                            str(1 << 28)))


class TransportError(RuntimeError):
    """Framing/codec violation on a live connection."""


class TransportClosed(TransportError):
    """Peer hung up (EOF mid-frame, reset, or closed socket) — the
    signal the orchestrator's crash recovery (re-queue + replay) keys
    on, identical for AF_UNIX children and TCP peers on other hosts."""


class RpcTimeout(TransportError):
    """A reply missed its per-call deadline with the socket still OPEN —
    the *hung* signal (GC pause, network blackhole, livelocked worker),
    deliberately distinct from ``TransportClosed`` (*dead*): a hung peer
    may still hold authoritative request state, so the orchestrator
    probes (heartbeat) and quarantines before replaying, instead of
    assuming the process is gone."""


class FrameTooLarge(TransportError):
    """Incoming length prefix exceeds the receive bound. The stream is
    unsynchronized at this point (the oversized frame was never read),
    so the connection is failed — callers must not retry on it."""


# Fault-injection seam (serving/faults.py): when installed, the hook is
# consulted on every labeled ``Connection.send`` and may delay the frame
# or swallow it entirely (drop / partition / half-open). ``None`` —
# the default — costs one attribute check per send. Connections without
# a ``peer_label`` (servers' child-side sockets, unlabeled tests) are
# never faulted, so a REPRO_FAULTS plan inherited through the
# environment by worker processes is inert there.
_FAULT_HOOK: Optional[Callable[["Connection"], bool]] = None


def set_fault_hook(hook: Optional[Callable[["Connection"], bool]]):
    """Install (or clear, with ``None``) the send-side fault hook. The
    hook receives the ``Connection`` and returns False to swallow the
    frame. Installed by ``repro.serving.faults`` — not called directly
    by user code."""
    global _FAULT_HOOK
    _FAULT_HOOK = hook


class RemoteError(RuntimeError):
    """An exception raised INSIDE the peer's handler, re-raised at the
    caller with the remote repr. ``kind`` preserves the remote class
    name so callers can branch (e.g. on ``OutOfBlocks``) without
    importing anything."""

    def __init__(self, kind: str, message: str):
        super().__init__(f"{kind}: {message}")
        self.kind = kind


# ------------------------------------------------------------------ codecs
def _np_encode(arr: np.ndarray):
    a = np.ascontiguousarray(arr)
    return {b"__nd__": (str(a.dtype), list(a.shape), a.tobytes())}


def _msgpack_default(obj):
    # jnp arrays arrive here too (they fail the isinstance below only if
    # jax is absent, which cannot happen in this repo) — np.asarray is a
    # host copy either way, which the wire format needs regardless.
    if isinstance(obj, np.ndarray):
        return _np_encode(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if type(obj).__name__ == "ArrayImpl":  # jax array without importing jax
        return _np_encode(np.asarray(obj))
    if type(obj).__name__ == "Request":
        import dataclasses
        return {b"__req__": dataclasses.asdict(obj)}
    if type(obj).__name__ == "RequestSpec":
        import dataclasses
        # asdict recurses into the nested SamplingParams; the decode
        # hook rebuilds it
        return {b"__spec__": dataclasses.asdict(obj)}
    raise TypeError(f"not msgpack-encodable: {type(obj)!r}")


def _msgpack_object_hook(obj: dict):
    if b"__nd__" in obj and len(obj) == 1:
        dtype, shape, buf = obj[b"__nd__"]
        return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
    if b"__req__" in obj and len(obj) == 1:
        from repro.serving.engine import Request
        return Request(**obj[b"__req__"])
    if b"__spec__" in obj and len(obj) == 1:
        from repro.serving.request import RequestSpec, SamplingParams
        d = dict(obj[b"__spec__"])
        d["sampling"] = SamplingParams(**d["sampling"])
        return RequestSpec(**d)
    return obj


def encode(obj: Any, prefer: str = "msgpack") -> bytes:
    """Serialize ``obj`` to one frame body (tag byte + payload)."""
    if prefer == "msgpack" and msgpack is not None:
        try:
            body = msgpack.packb(obj, default=_msgpack_default,
                                 use_bin_type=True, strict_types=False)
            return TAG_MSGPACK + body
        except (TypeError, ValueError):
            pass  # not msgpack-shaped (configs, pytrees): pickle frame
    return TAG_PICKLE + pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def decode(frame: bytes) -> Any:
    tag, body = frame[:1], frame[1:]
    if tag == TAG_MSGPACK:
        if msgpack is None:  # pragma: no cover
            raise TransportError("msgpack frame but msgpack unavailable")
        return msgpack.unpackb(body, object_hook=_msgpack_object_hook,
                               raw=False, strict_map_key=False)
    if tag == TAG_PICKLE:
        return pickle.loads(body)
    raise TransportError(f"unknown codec tag {tag!r}")


# --------------------------------------------------------------- endpoints
def parse_endpoint(address: str) -> Tuple[str, Any]:
    """``tcp://host:port`` -> ``("tcp", (host, port))``; ``unix://path``
    or a bare filesystem path -> ``("unix", path)``."""
    if address.startswith("tcp://"):
        host, sep, port = address[len("tcp://"):].rpartition(":")
        if not sep or not host or not port.isdigit():
            raise ValueError(f"malformed tcp endpoint {address!r} "
                             "(want tcp://host:port)")
        return "tcp", (host, int(port))
    if address.startswith("unix://"):
        return "unix", address[len("unix://"):]
    return "unix", address


def listener_address() -> str:
    """Fresh AF_UNIX rendezvous path for one parent<->child connection."""
    return os.path.join(tempfile.gettempdir(),
                        f"repro-engine-{os.getpid()}-{uuid.uuid4().hex}.sock")


def free_tcp_endpoint(host: str = "127.0.0.1") -> str:
    """A currently-free ``tcp://host:port`` (bind port 0, read it back).
    Launcher/test convenience; the port can in principle be reused by
    another process before the caller binds it."""
    probe = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        probe.bind((host, 0))
        return f"tcp://{host}:{probe.getsockname()[1]}"
    finally:
        probe.close()


def bound_endpoint(srv: socket.socket) -> str:
    """The concrete endpoint a listener bound (resolves ``port 0``)."""
    if srv.family == socket.AF_INET:
        host, port = srv.getsockname()[:2]
        return f"tcp://{host}:{port}"
    return srv.getsockname()


def _tune_tcp(sock: socket.socket):
    # frames are small and latency-critical (one RPC per control tick):
    # never Nagle-delay them; keepalive turns a silently half-open peer
    # (host died, no RST ever arrives) into an eventual TransportClosed
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)


def listen(address: str) -> socket.socket:
    """Bind + listen on a ``tcp://`` or AF_UNIX endpoint."""
    kind, target = parse_endpoint(address)
    if kind == "tcp":
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind(target)
        srv.listen(16)
    else:
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        srv.bind(target)
        srv.listen(1)
    return srv


def accept(srv: socket.socket, timeout: Optional[float] = 60.0) -> "Connection":
    srv.settimeout(timeout)
    try:
        sock, _ = srv.accept()
    except socket.timeout as e:
        raise TransportError("engine server never connected") from e
    finally:
        srv.settimeout(None)
    sock.settimeout(None)
    if sock.family == socket.AF_INET:
        _tune_tcp(sock)
    return Connection(sock)


# errors a retry can plausibly outwait: the server exists but hasn't
# bound/listened yet, or is mid-restart. Anything else (DNS failure on
# a typo'd host, EACCES, EADDRNOTAVAIL, ...) is a misconfiguration that
# every retry would reproduce — fail fast instead of eating the timeout.
_RETRYABLE_CONNECT = (ConnectionRefusedError, ConnectionResetError,
                      ConnectionAbortedError, FileNotFoundError,
                      socket.timeout)

BACKOFF_CAP = 0.5  # connect-retry ceiling: a booting server binds fast


def backoff_delays(initial: float = 0.02, cap: float = BACKOFF_CAP):
    """The connect-retry schedule: monotone doubling from ``initial``,
    capped at ``cap``. Extracted so tests can assert the schedule
    itself (capped, monotone) independently of wall time."""
    delay = initial
    while True:
        yield delay
        delay = min(delay * 2, cap)


def connect(address: str, timeout: float = 60.0,
            retry_interval: float = 0.02,
            abort: Optional[Callable[[], Optional[str]]] = None
            ) -> "Connection":
    """Connect to a listening endpoint, retrying with backoff until
    ``timeout``. A not-yet-listening peer (pod launcher spawned the
    server a moment ago; its socket isn't bound yet) raises
    ConnectionRefusedError / FileNotFoundError on each attempt — those
    retry, and only the deadline turns them into ``TransportError``;
    permanently-failing errors (unresolvable host, permissions) raise
    immediately. ``abort`` is polled between retries: returning a
    message stops the loop at once (e.g. "the spawned server process
    already exited" — no point waiting out the deadline)."""
    kind, target = parse_endpoint(address)
    family = socket.AF_INET if kind == "tcp" else socket.AF_UNIX
    deadline = time.monotonic() + timeout
    delays = backoff_delays(retry_interval)
    while True:
        sock = socket.socket(family, socket.SOCK_STREAM)
        sock.settimeout(max(0.05, deadline - time.monotonic()))
        try:
            sock.connect(target)
            break
        except _RETRYABLE_CONNECT as e:
            sock.close()
            reason = abort() if abort is not None else None
            if reason:
                raise TransportError(
                    f"connect to {address} aborted: {reason}") from e
            delay = next(delays)
            if time.monotonic() + delay >= deadline:
                raise TransportError(
                    f"connect to {address} failed within {timeout:.1f}s: "
                    f"{e}") from e
            time.sleep(delay)
        except OSError as e:
            sock.close()
            raise TransportError(
                f"connect to {address} failed ({e}); not retrying — "
                "this error does not look transient") from e
    sock.settimeout(None)
    if kind == "tcp":
        _tune_tcp(sock)
    return Connection(sock)


# ------------------------------------------------------------- connections
class Connection:
    """One framed, bidirectional message stream over a socket.

    Receive buffering is in-object (not a ``makefile`` wrapper) so the
    multiplexed poll can distinguish "kernel has data" (``select`` on
    ``fileno()``) from "bytes already sit in our buffer"
    (``has_buffered()`` — possibly a partial frame, whose tail is then
    read blocking) — buffered bytes never wake ``select``, so the poll
    must drain them explicitly before sleeping."""

    def __init__(self, sock: socket.socket,
                 max_frame: Optional[int] = None):
        self._sock = sock
        self._rxbuf = bytearray()
        self.tx_frames = 0
        self.rx_frames = 0
        self.tx_bytes = 0
        self.rx_bytes = 0
        # identity for the fault-injection seam: None (the default)
        # means "never fault this connection"
        self.peer_label: Optional[str] = None
        self.max_frame = (DEFAULT_MAX_RECV_FRAME if max_frame is None
                          else max_frame)
        self.last_rx = time.monotonic()

    def fileno(self) -> int:
        return self._sock.fileno()

    def has_buffered(self) -> bool:
        return bool(self._rxbuf)

    def wait_readable(self, timeout: float) -> bool:
        """True once bytes are available (buffered or kernel-side),
        False if ``timeout`` elapses first. The deadline clock of
        ``Rpc._wait`` sleeps here instead of in a blocking recv."""
        if self._rxbuf:
            return True
        readable, _, _ = select.select([self._sock], [], [],
                                       max(0.0, timeout))
        return bool(readable)

    def send(self, obj: Any):
        frame = encode(obj)
        if len(frame) >= MAX_FRAME:
            raise TransportError(f"frame too large: {len(frame)} bytes")
        if _FAULT_HOOK is not None and not _FAULT_HOOK(self):
            return  # injected loss: the frame never reaches the wire
        try:
            self._sock.sendall(_LEN.pack(len(frame)) + frame)
        except (BrokenPipeError, ConnectionResetError, OSError) as e:
            raise TransportClosed(f"send on dead connection: {e}") from e
        self.tx_frames += 1
        self.tx_bytes += len(frame) + _LEN.size

    def _fill(self, n: int):
        while len(self._rxbuf) < n:
            try:
                chunk = self._sock.recv(_RECV_CHUNK)
            except (ConnectionResetError, BrokenPipeError, OSError) as e:
                raise TransportClosed(f"recv on dead connection: {e}") from e
            if not chunk:
                raise TransportClosed(
                    f"peer closed mid-frame (wanted {n} bytes, "
                    f"got {len(self._rxbuf)})")
            self._rxbuf += chunk
            self.last_rx = time.monotonic()

    def _read_exact(self, n: int) -> bytes:
        self._fill(n)
        out = bytes(memoryview(self._rxbuf)[:n])
        del self._rxbuf[:n]
        return out

    def recv(self) -> Any:
        (length,) = _LEN.unpack(self._read_exact(_LEN.size))
        if not 0 < length < MAX_FRAME:
            raise TransportError(f"corrupt frame length {length}")
        if length > self.max_frame:
            # checked BEFORE any allocation; the stream is now
            # unsynchronized (we never consumed the frame), so fail the
            # connection rather than let a retry read garbage
            self.close()
            raise FrameTooLarge(
                f"incoming frame of {length} bytes exceeds the "
                f"{self.max_frame}-byte receive bound (corrupt length "
                "prefix or hostile peer); connection failed")
        frame = self._read_exact(length)
        self.rx_frames += 1
        self.rx_bytes += length + _LEN.size
        return decode(frame)

    def close(self):
        try:
            self._sock.close()
        except OSError:
            pass


def socketpair() -> tuple:
    """In-process connected pair (tests, threads) with the same framing."""
    a, b = socket.socketpair()
    return Connection(a), Connection(b)


# -------------------------------------------------------------------- rpc
class Pending:
    """Handle for a pipelined ``call_async``; ``wait()`` blocks until the
    matching reply arrives (draining any earlier pipelined replies).
    ``deadline`` (a ``time.monotonic`` instant, or None) bounds the wait:
    past it, ``wait()`` raises ``RpcTimeout`` and ``drain_pendings``
    resolves the entry to ``("hung", ...)``."""

    def __init__(self, rpc: "Rpc", call_id: int,
                 deadline: Optional[float] = None):
        self._rpc = rpc
        self.call_id = call_id
        self.deadline = deadline

    def ready(self) -> bool:
        return self.call_id in self._rpc._replies

    def wait(self) -> Any:
        return self._rpc._wait(self.call_id, deadline=self.deadline)


class Rpc:
    """Client side: request/reply (+ pipelining) over a Connection.

    ``call_timeout`` (seconds, None = unbounded) stamps a monotonic
    deadline onto every ``Pending`` this client issues — the per-call
    deadline clock the orchestrator's hung-peer detection keys on."""

    def __init__(self, conn: Connection,
                 call_timeout: Optional[float] = None):
        self.conn = conn
        self.call_timeout = call_timeout
        self._next_id = 0
        self._replies: Dict[int, Any] = {}

    def call_async(self, op: str, *args, _trace=None, **kw) -> Pending:
        self._next_id += 1
        cid = self._next_id
        msg = {"id": cid, "op": op, "args": list(args), "kw": kw}
        if _trace is not None:
            # trace-context propagation (serving/observe.py): rides the
            # existing frame, invisible to the dispatched handler — the
            # server's ``_on_trace`` dispatch hook consumes it
            msg["trace"] = _trace
        self.conn.send(msg)
        deadline = (None if self.call_timeout is None
                    else time.monotonic() + self.call_timeout)
        return Pending(self, cid, deadline=deadline)

    def call(self, op: str, *args, **kw) -> Any:
        return self.call_async(op, *args, **kw).wait()

    def call_timed(self, op: str, timeout: float, *args, **kw) -> Any:
        """One call with an explicit deadline, regardless of
        ``call_timeout`` — the heartbeat probe's entry point."""
        pending = self.call_async(op, *args, **kw)
        pending.deadline = time.monotonic() + timeout
        return pending.wait()

    def _pump_one(self):
        """Receive exactly one reply frame into the reply buffer."""
        reply = self.conn.recv()
        self._replies[reply["id"]] = reply

    def _take(self, call_id: int) -> Any:
        """Resolve an already-received reply (raises RemoteError for
        error replies). The reply MUST be present — ``_wait`` /
        ``drain_pendings`` guarantee that before calling."""
        reply = self._replies.pop(call_id)
        if not reply.get("ok"):
            raise RemoteError(reply.get("kind", "RuntimeError"),
                              reply.get("error", "remote failure"))
        return reply.get("result")

    def _wait(self, call_id: int,
              deadline: Optional[float] = None) -> Any:
        while call_id not in self._replies:
            if deadline is not None:
                budget = deadline - time.monotonic()
                if budget <= 0:
                    raise RpcTimeout(
                        f"call {call_id} ({self.conn.peer_label or 'peer'})"
                        " missed its deadline with the socket still open")
                if not self.conn.wait_readable(budget):
                    continue  # re-check the clock, then raise
            self._pump_one()
        return self._take(call_id)

    def close(self):
        self.conn.close()


def drain_pendings(pendings: List[Any],
                   timeout: Optional[float] = None) -> List[tuple]:
    """The batched control-plane poll: resolve MANY pipelined calls —
    across any number of connections — in one ``selectors`` wait.

    ``pendings`` may mix transport ``Pending``s with any already-
    resolved stand-in exposing ``wait()`` (a local instance's
    ``Completed``). Returns a list parallel to the input, each entry one
    of::

        ("ok",     result)            reply arrived, handler succeeded
        ("error",  RemoteError)       reply arrived, handler raised
        ("closed", TransportClosed)   the peer died before replying
        ("hung",   RpcTimeout)        per-call deadline passed, socket
                                      still open — the peer may be
                                      stalled, partitioned, or half-open

    A dead peer resolves ALL of its outstanding entries to ``closed``
    without disturbing other peers' entries — the caller folds crash
    detection into the same poll that collects results. Wall time is
    bounded by the slowest peer (replies are consumed as they land),
    not the sum of round trips.

    A ``Pending`` carrying a deadline (``Rpc.call_timeout``) that
    expires mid-drain resolves to ``("hung", RpcTimeout)`` — only that
    entry: the connection stays registered for its other pendings, and
    healthy peers are untouched. This is what keeps ONE blackholed
    worker from stalling the whole control tick: the poll's sleep is
    clipped to the earliest outstanding deadline.

    ``timeout`` bounds the wait for NEW data only: once a frame has
    started arriving, its remaining bytes are read with a blocking
    recv (peers are trusted engine servers that write whole frames via
    sendall — a peer that stalls mid-frame is treated as about to die,
    and its eventual reset surfaces as ``closed``)."""
    results: List[Optional[tuple]] = [None] * len(pendings)
    groups: Dict[int, list] = {}    # id(rpc) -> [rpc, [(idx, pending)]]
    for idx, p in enumerate(pendings):
        if isinstance(p, Pending):
            groups.setdefault(id(p._rpc), [p._rpc, []])[1].append((idx, p))
        else:  # synchronously-completed stand-in: resolve up front
            try:
                results[idx] = ("ok", p.wait())
            except RemoteError as e:
                results[idx] = ("error", e)
            except TransportClosed as e:
                results[idx] = ("closed", e)

    def settle(rpc, items):
        left = []
        for idx, p in items:
            if p.ready():
                try:
                    results[idx] = ("ok", rpc._take(p.call_id))
                except RemoteError as e:
                    results[idx] = ("error", e)
            else:
                left.append((idx, p))
        return left

    def pump_ready(rpc, items):
        """Settle cached replies, then keep consuming frames our own
        buffer already holds (select can't see those)."""
        items = settle(rpc, items)
        while items and rpc.conn.has_buffered():
            try:
                rpc._pump_one()
            except TransportClosed as e:
                for idx, _ in items:
                    results[idx] = ("closed", e)
                return []
            items = settle(rpc, items)
        return items

    def expire(now):
        """Resolve pendings whose per-call deadline has passed to
        ``hung`` — without disturbing the rest of their group."""
        for key in list(groups):
            rpc, items = groups[key]
            still = []
            for idx, p in items:
                if p.deadline is not None and now >= p.deadline:
                    results[idx] = ("hung", RpcTimeout(
                        f"call {p.call_id} "
                        f"({rpc.conn.peer_label or 'peer'}) missed its "
                        "deadline with the socket still open"))
                else:
                    still.append((idx, p))
            if len(still) != len(items):
                groups[key][1] = still
                if not still:
                    sel.unregister(rpc.conn)
                    del groups[key]

    def earliest_deadline():
        out = None
        for _, items in groups.values():
            for _, p in items:
                if p.deadline is not None:
                    out = p.deadline if out is None else min(out, p.deadline)
        return out

    sel = selectors.DefaultSelector()
    try:
        for key in list(groups):
            rpc, items = groups[key]
            items = pump_ready(rpc, items)
            if items:
                groups[key][1] = items
                sel.register(rpc.conn, selectors.EVENT_READ, groups[key])
            else:
                del groups[key]

        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while groups:
            now = time.monotonic()
            expire(now)
            if not groups:
                break
            wake = deadline
            call_dl = earliest_deadline()
            if call_dl is not None:
                wake = call_dl if wake is None else min(wake, call_dl)
            budget = (None if wake is None
                      else max(0.0, wake - now))
            events = sel.select(budget)
            if not events:
                if deadline is not None and time.monotonic() >= deadline:
                    n = sum(len(g[1]) for g in groups.values())
                    raise TransportError(
                        f"drain_pendings timed out with {n} replies "
                        "outstanding")
                continue
            for ev_key, _ in events:
                group = ev_key.data
                rpc, items = group
                try:
                    rpc._pump_one()
                except TransportClosed as e:
                    for idx, _ in items:
                        results[idx] = ("closed", e)
                    items = []
                else:
                    items = pump_ready(rpc, items)
                group[1] = items
                if not items:
                    sel.unregister(rpc.conn)
                    del groups[id(rpc)]
    finally:
        sel.close()
    return results  # type: ignore[return-value]


def serve(conn: Connection, dispatch: Dict[str, Callable],
          *, stop_op: str = "shutdown"):
    """Server side: dispatch loop until ``stop_op`` or peer hangup.

    Handler exceptions are caught and returned as error replies (the
    server survives an ``OutOfBlocks`` on import); transport errors end
    the loop — the parent is gone, so is our reason to exist."""
    while True:
        try:
            msg = conn.recv()
        except TransportClosed:
            return
        cid, op = msg.get("id"), msg.get("op")
        if op == stop_op:
            conn.send({"id": cid, "ok": True, "result": None})
            return
        fn = dispatch.get(op)
        try:
            if fn is None:
                raise KeyError(f"unknown op {op!r}")
            trace = msg.get("trace")
            if trace is not None and "_on_trace" in dispatch:
                # piggybacked trace context: hand it to the server
                # BEFORE the op runs, so e.g. a submit records spans
                # from its very first lifecycle hook
                dispatch["_on_trace"](trace)
            result = fn(*msg.get("args", ()), **msg.get("kw", {}))
            reply = {"id": cid, "ok": True, "result": result}
        except Exception as e:  # noqa: BLE001 - proxied to the caller
            reply = {"id": cid, "ok": False,
                     "kind": type(e).__name__, "error": str(e)}
        try:
            conn.send(reply)
        except TransportClosed:
            return


def _install_env_faults():
    """``REPRO_FAULTS=<plan.json>``: auto-install a serialized FaultPlan
    so chaos runs are reproducible from the environment alone (the CLI,
    the benchmarks, and CI all pick it up without code changes). Worker
    processes inherit the variable but hold only unlabeled connections,
    so the plan is inert in them."""
    path = os.environ.get("REPRO_FAULTS")
    if path:
        from repro.serving import faults
        faults.install_from_file(path)


_install_env_faults()


def _np_roundtrip_selftest():  # pragma: no cover - debugging aid
    buf = io.BytesIO()
    a = np.arange(6, dtype=np.int32).reshape(2, 3)
    buf.write(encode({"a": a}))
    out = decode(buf.getvalue())
    assert (out["a"] == a).all()
