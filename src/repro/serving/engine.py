"""Continuous-batching inference engine (real JAX execution).

Iteration-level scheduling in the Orca/vLLM style, with PAGED KV as the
primary decode path (``cache_kind="paged"``) and TOKEN-BUDGET continuous
batching as the default step loop (DESIGN.md §10):

* **Scheduling** is one budget-packed loop, not phases: every step,
  ``serving.scheduler.TokenBudgetScheduler`` charges each active decode
  slot one token, continues in-flight prefills oldest-first, and admits
  fresh prompts with whatever budget is left — at most one partial,
  block-aligned chunk per step. ``Request.prefill_pos`` is a first-class
  cursor (always equal to ``pstate.lengths[slot]`` mid-prefill), so
  preemption, migration and sliding-window reclamation compose with
  chunking. ``scheduler="phase"`` pins the legacy prefill-wave/decode-
  step alternation (identity baseline; forced for dense caches).
* **Prefill** runs over a throwaway dense cache sized to the prompt's
  POWER-OF-TWO length bucket — a whole prompt under the phase scheduler,
  a budget-sliced chunk under the default one (a chunk continuation IS a
  suffix prefill against the written span; both run the fused
  ``_chunk_prefill_fn``: pool gather → splice → decode-mode extend →
  suffix scatter, with per-row last-token gather picking each prompt's
  real logits) — then scatters each request's true-length K/V into the
  shared block pool via ``paged_kv.write_tokens_batch``. PREFIX SHARING
  (on by default, ``prefix_sharing=``): an admission whose prompt opens
  with an already-cached full-block prefix ALIASES those blocks
  (refcounted, copy-on-write — paged_kv's prefix cache) and prefills
  only its private suffix against the spliced shared context
  (``_prefill_shared_batch``, one bucketed extend per (context, suffix)
  group of hits), so a shared system prompt is stored and
  prefilled once per pool, not once per request. Block
  allocation/eviction is driven by the host-side free list — admission
  applies backpressure (requests wait in the queue) when the pool is out
  of blocks, and decode-time pressure preempts the youngest request back
  onto the queue (its re-admission replays deterministically thanks to
  counter-based sampling keys; shared blocks merely decref). Sliding-
  window archs run paged too: blocks that fall fully out of the window
  return to the pool (``paged_kv.free_out_of_window``) — prefix matching
  is gated off under a window, whose reclamation invalidates full-prefix
  residency.
* **Decode** is ONE fused jitted call per engine step: single-token
  forward against the block pool (``models.transformer.forward_paged``)
  plus batched on-device sampling (``serving.sampling``). The only
  device→host transfer per step is fetching the sampled token ids —
  host-side cached lengths/tables make everything else host-resident, so
  a step performs exactly one host sync (asserted in tests via
  ``serving.instrument.count_host_syncs``). The block-table width fed to
  the step is bucketed to powers of two, so decode compute and HBM
  traffic scale with the *actual* longest context, not ``max_len``.

The engine is also the unit CoCoServe's live module scaling operates on:
``apply_plan`` puts the plan's per-layer replication degrees on the fused
step (static jit arg -> unrolled ``forward_paged`` with batch-sharding
hooks), and ``pause_request``/``resume_request`` export/import one
request's KV blocks + position + sampling counters so an orchestrator
(serving/orchestrator.py) can migrate it mid-stream, token-identically.
The two-phase OVERLAPPED variant splits that into ``snapshot_request``
(bulk export, stream keeps decoding) -> destination ``prepare_resume``
(staged import into an admission-excluded slot) -> ``pause_request(...,
since_epoch)`` (dirty-set delta only) -> ``commit_resume`` — the stream
leaves decode rotation just for the delta copy (DESIGN.md §7).

The legacy dense path (``cache_kind="dense"``, a ``[B, max_len]`` cache)
remains for MLA/SSM/hybrid/audio families and as the parity oracle; it
shares the same fused decode+sample step shape.
Inactive slots decode garbage that is masked out — the standard
static-batch trick that keeps the jitted step shape-stable.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import kvcache as KV
from repro.serving import paged_kv as PK
from repro.serving import sampling as SMP
from repro.serving import scheduler as SCH
from repro.serving.request import MIGRATION_WIRE_VERSION, RequestSpec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full distribution
    seed: int = 0
    # SLO contract (request.RequestSpec is the construction API; these
    # ride the Request so they survive preemption, crash replay and
    # cross-instance migration exactly like the sampling state does)
    slo_class: str = "standard"
    deadline_ms: Optional[float] = None
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    preemptions: int = 0
    # chunked-prefill cursor: how many prefill tokens are already written
    # into this request's KV (continuous batching slices long prompts
    # across steps; the cursor is FIRST-CLASS state so a mid-prefill
    # request can be preempted or even MIGRATED without replaying the
    # chunks that already landed — it travels the wire with the Request)
    prefill_pos: int = 0
    prefill_start_time: Optional[float] = None   # first chunk admitted

    @property
    def done(self) -> bool:
        return self.finish_time is not None


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass(eq=False)  # identity equality: Request holds arrays
class _ChunkSpec:
    """One prefill chunk ready for execution: ``n`` tokens starting at
    ``req.prefill_pos`` in ``slot``. ``fresh`` marks a first chunk whose
    admission must be rolled back (slot freed, request requeued) if the
    chunk's block allocation fails — a continuation just retries."""
    req: "Request"
    slot: int
    n: int
    fresh: bool = False


# --------------------------------------------------------------- jitted steps
# Module-level with a STATIC (hashable, frozen) ModelConfig so the XLA
# compile cache is shared across Engine instances — restarting an engine,
# or running dense and paged engines side by side (benchmarks, parity
# tests), never recompiles an already-seen step shape.


@functools.partial(jax.jit, static_argnames=("cfg", "window"))
def _prefill_fn(params, tokens, cache, enc, last_idx, *, cfg, window):
    return T.forward(params, cfg, tokens, mode="prefill", cache=cache,
                     window=window, encoder_input=enc, last_idx=last_idx)


@functools.partial(jax.jit, static_argnames=("cfg", "window"))
def _extend_fn(params, tokens, positions, cache, *, cfg, window):
    # multi-token continuation (chunked prefill tail chunks)
    return T.forward(params, cfg, tokens, positions=positions,
                     mode="decode", cache=cache, window=window)


@functools.partial(jax.jit, static_argnames=("cfg", "window"))
def _extend_last_fn(params, tokens, positions, cache, last_idx, *, cfg,
                    window):
    # suffix prefill over an adopted shared prefix (prefix-cache hits):
    # decode-mode continuation with a per-row last-REAL-token gather so
    # padded suffix buckets return the right first-token logits
    return T.forward(params, cfg, tokens, positions=positions,
                     mode="decode", cache=cache, window=window,
                     last_idx=last_idx)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "window", "cache_len", "dtype"),
                   donate_argnums=(1, 2))
def _chunk_prefill_fn(params, pool_k, pool_v, tbl, suffix, spos, pos,
                      last_idx, bidx, oidx, *, cfg, window, cache_len,
                      dtype):
    """FUSED chunk/suffix prefill: pool context gather -> throwaway dense
    cache splice -> decode-mode extension over the suffix bucket ->
    suffix K/V scatter back into the (donated) pool — one executable per
    power-of-two (group, context, suffix) bucket instead of ~15 eager
    dispatches and four whole-buffer copies. This is what makes a chunk
    step cost like a decode step on the host side, which is the whole
    point of slicing prefills under the token budget (DESIGN.md §10)."""
    L, _, KV, bs, hd = pool_k.shape
    G, n_blk = tbl.shape
    cb = n_blk * bs
    ctx_k = pool_k[:, tbl].transpose(0, 1, 2, 4, 3, 5).reshape(
        L, G, cb, KV, hd)
    ctx_v = pool_v[:, tbl].transpose(0, 1, 2, 4, 3, 5).reshape(
        L, G, cb, KV, hd)
    cache = T.init_cache(cfg, G, cache_len, dtype)
    kd = cache["layers"]["k"].dtype
    cache["layers"]["k"] = cache["layers"]["k"].at[:, :, :cb].set(
        ctx_k.astype(kd))
    cache["layers"]["v"] = cache["layers"]["v"].at[:, :, :cb].set(
        ctx_v.astype(kd))
    cache["positions"] = pos
    logits, cache, _ = T.forward(params, cfg, suffix, positions=spos,
                                 mode="decode", cache=cache, window=window,
                                 last_idx=last_idx)
    idx = spos[None, :, :, None, None]
    k_sfx = jnp.take_along_axis(cache["layers"]["k"], idx, axis=2)
    v_sfx = jnp.take_along_axis(cache["layers"]["v"], idx, axis=2)
    Sb = suffix.shape[1]
    kf = k_sfx.reshape(L, G * Sb, KV, hd).transpose(1, 0, 2, 3)
    vf = v_sfx.reshape(L, G * Sb, KV, hd).transpose(1, 0, 2, 3)
    pool_k = pool_k.at[:, bidx, :, oidx].set(kf.astype(pool_k.dtype),
                                             mode="drop")
    pool_v = pool_v.at[:, bidx, :, oidx].set(vf.astype(pool_v.dtype),
                                             mode="drop")
    return logits, pool_k, pool_v


def _dense_step_impl(params, cache, tokens, positions, temps, topks, seeds,
                     counters, *, cfg, window, stochastic, max_top_k):
    logits, nc, _ = T.forward(params, cfg, tokens, positions=positions,
                              mode="decode", cache=cache, window=window)
    toks = SMP.sample_tokens(logits, temps, topks, seeds, counters,
                             cfg.vocab_size, stochastic=stochastic,
                             max_top_k=max_top_k)
    return toks, nc


def _paged_step_impl(params, k, v, tables, lengths, active, tokens, temps,
                     topks, seeds, counters, *, cfg, window, impl, interp,
                     stochastic, max_top_k, degrees=None):
    handle = {"k": k, "v": v, "block_tables": tables,
              "lengths": lengths, "active": active}
    hook = None
    if degrees is not None:
        # live module replication: the (hashable, static) per-layer degree
        # tuple unrolls the stack with one batch-sharding constraint per
        # layer — a plan change recompiles exactly this step, nothing else
        from repro.core import replication as R
        hook = R.layer_hook_from_degrees(degrees,
                                         R.default_replication_mesh())
    logits, nc, _ = T.forward_paged(params, cfg, tokens[:, None], handle,
                                    window=window, attn_impl=impl,
                                    interpret=interp, layer_hook=hook)
    toks = SMP.sample_tokens(logits, temps, topks, seeds, counters,
                             cfg.vocab_size, stochastic=stochastic,
                             max_top_k=max_top_k)
    return toks, nc["k"], nc["v"]


@functools.lru_cache(maxsize=1)
def _jitted_steps():
    """Buffer donation (in-place KV update) needs the backend, and probing
    it at import time would freeze JAX's platform before callers like
    launch/dryrun.py set their XLA flags — so the donating jits are built
    lazily at first step."""
    can_donate = jax.default_backend() != "cpu"
    dense = jax.jit(_dense_step_impl,
                    static_argnames=("cfg", "window", "stochastic",
                                     "max_top_k"),
                    donate_argnums=(1,) if can_donate else ())
    paged = jax.jit(_paged_step_impl,
                    static_argnames=("cfg", "window", "impl", "interp",
                                     "stochastic", "max_top_k", "degrees"),
                    donate_argnums=(1, 2) if can_donate else ())
    return dense, paged


@functools.partial(jax.jit, static_argnames=("vocab_size", "stochastic",
                                             "max_top_k"))
def _sample_fn(logits, temps, topks, seeds, counters, *, vocab_size,
               stochastic, max_top_k):
    return SMP.sample_tokens(logits, temps, topks, seeds, counters,
                             vocab_size, stochastic=stochastic,
                             max_top_k=max_top_k)


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, dtype="float32", swa: bool = False,
                 encoder_input_fn: Optional[Callable] = None,
                 prefill_chunk: int = 0,
                 cache_kind: str = "dense", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 paged_attn_impl: str = "gather", interpret: bool = False,
                 prefix_sharing: Optional[bool] = None,
                 scheduler: Optional[str] = None, token_budget: int = 128):
        assert cache_kind in ("dense", "paged"), cache_kind
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        # dense SWA ring-buffers down to the window; the PAGED path keeps
        # the logical length (block-table columns are absolute positions)
        # and instead FREES leading blocks as they leave the window
        self.max_len = (max_len if cache_kind == "paged"
                        else KV.cache_capacity(cfg, max_len, swa=swa))
        self.logical_max = max_len
        self.window = cfg.sliding_window if swa else None
        self.dtype = dtype
        self.encoder_input_fn = encoder_input_fn
        self.prefill_chunk = prefill_chunk  # 0 = one-shot prefill
        self.cache_kind = cache_kind
        self.active: Dict[int, Request] = {}   # slot -> request
        # slots whose prompt is only PARTIALLY written (chunked prefill
        # under the token-budget scheduler): they hold blocks and an
        # admission-order position but do not decode yet
        self.prefilling: Dict[int, Request] = {}
        self._prefill_matched: Dict[int, list] = {}  # slot -> prefix hit
        # slots holding a phase-1 migration import awaiting its delta
        # (commit_resume / abort_resume); excluded from admission
        self._staged: Dict[int, int] = {}      # slot -> rid
        self.queue: Deque[Request] = collections.deque()
        self.clock = 0.0
        self._step_count = 0
        self.preempt_count = 0   # pool-pressure evictions (live OOM signal)
        # host mirror of per-slot cache lengths for the DENSE path (the
        # paged path's canonical host lengths live in pstate.lengths) —
        # this is what lets a decode step avoid reading device state.
        self._host_lengths = np.zeros((max_batch,), np.int64)
        self._admit_order: List[int] = []      # slots, oldest first
        self._admit_finished: List[Request] = []  # done at admission

        # prompt-prefix sharing (paged_kv prefix cache + copy-on-write):
        # ON by default for the paged path; matching/registration are
        # additionally gated off per-admission under a sliding window
        # (whose block reclamation invalidates full-prefix residency)
        self.prefix_sharing = ((cache_kind == "paged")
                               if prefix_sharing is None
                               else bool(prefix_sharing))

        if cache_kind == "paged":
            if not cfg.supports_paged_kv:
                raise ValueError(
                    f"cache_kind='paged' needs a GQA attention decoder "
                    f"(family={cfg.family}, attn={cfg.attention_kind})")
            if n_blocks is None:
                # SWA pools only need the live window (+1 block of write
                # headroom per slot); the table still spans max_len columns
                live = KV.cache_capacity(cfg, max_len, swa=swa)
                n_blocks = -(-max_batch * live // block_size)
                if self.window:
                    n_blocks += max_batch
            self.pstate = PK.init_paged(cfg, max_batch, n_blocks,
                                        block_size=block_size, dtype=dtype,
                                        max_len=self.max_len,
                                        prefix_cache=self.prefix_sharing)
            self.cache = None
        else:
            self.cache = T.init_cache(cfg, max_batch, self.max_len, dtype)
            self.pstate = None

        # scheduler: resolved through the policy registry
        # (serving/scheduler.py). TOKEN-BUDGET continuous batching is the
        # default paged path (one step loop packs decode tokens + bounded
        # prefill chunks — long prompts never stall decodes); "slo" adds
        # class-aware splits of the same budget; "phase" keeps the
        # original prefill-wave/decode-step alternation as the parity
        # oracle and the bench baseline. Dense engines are always phase
        # (chunking targets the block pool's progressive allocation).
        if scheduler is None:
            scheduler = "budget" if cache_kind == "paged" else "phase"
        if cache_kind != "paged":
            scheduler = "phase"
        self.sched = SCH.make_scheduler(scheduler, token_budget=token_budget,
                                        chunk_align=block_size)
        self.scheduler_kind = self.sched.name
        self.token_budget = token_budget if self.sched.budgeted else 0
        self.last_step_packed: Optional[int] = None  # telemetry, per step

        self._paged_impl = paged_attn_impl
        self._interpret = interpret
        # optional observe.EngineSpanRecorder: lifecycle span hooks
        # (queue / prefill chunks / first token / decode / finish).
        # None (the default) keeps every hook site a falsy check —
        # tracing off costs nothing in the step loop.
        self.span_hook = None
        # live module-scaling state (Engine.apply_plan)
        self.replication_degrees: Optional[tuple] = None  # plan intent
        self._step_degrees: Optional[tuple] = None        # quantized/static
        self._prefill_shapes = set()  # (G, S) executables admitted so far

    # ------------------------------------------------------------- sampling
    def _sample_batch(self, logits, reqs) -> np.ndarray:
        """Sample one token per request from [len(reqs), Vpad] logits —
        one fused device call + one device_get for the whole batch."""
        temps = np.asarray([r.temperature for r in reqs], np.float32)
        topks = np.asarray([r.top_k for r in reqs], np.int32)
        seeds = np.asarray([r.seed for r in reqs], np.uint32)
        ctrs = np.asarray([len(r.generated) for r in reqs], np.uint32)
        return jax.device_get(_sample_fn(
            logits, temps, topks, seeds, ctrs,
            vocab_size=self.cfg.vocab_size,
            stochastic=bool((temps > 0).any()),
            max_top_k=int(topks.max())))

    def _sampling_arrays(self):
        B = self.max_batch
        temps = np.zeros((B,), np.float32)
        topks = np.zeros((B,), np.int32)
        seeds = np.zeros((B,), np.uint32)
        ctrs = np.zeros((B,), np.uint32)
        for slot, req in self.active.items():
            temps[slot] = req.temperature
            topks[slot] = req.top_k
            seeds[slot] = req.seed
            ctrs[slot] = len(req.generated)
        return temps, topks, seeds, ctrs

    # ------------------------------------------------------------- lifecycle
    def submit(self, spec: RequestSpec) -> Request:
        """Admit one request. Accepts ONLY a ``RequestSpec`` (the
        construction-time contract — serving/request.py); the engine
        mints and returns the mutable ``Request`` it will drive.
        Already-minted Requests re-enter through queue surgery
        (``queue.appendleft`` on preemption, ``resume_request`` on
        migration, push/requeue handle hooks on replay), never through
        ``submit``."""
        if not isinstance(spec, RequestSpec):
            raise TypeError(
                f"Engine.submit takes a RequestSpec, got "
                f"{type(spec).__name__} (build one via "
                "repro.serving.request, or RequestSpec.from_request "
                "for replays)")
        spec.validate()
        req = spec.to_request()
        req.submit_time = self.clock
        if self.span_hook:
            self.span_hook.on_submit(req)
        self.queue.append(req)
        return req

    def _queue_remove(self, req: Request):
        """Pop ``req`` from the waiting queue by IDENTITY (Request's
        field-wise __eq__ would compare prompt arrays)."""
        for i, r in enumerate(self.queue):
            if r is req:
                del self.queue[i]
                return
        raise ValueError(f"rid={req.rid} is not in the waiting queue")

    def set_token_budget(self, budget: int) -> int:
        """Retarget the per-step token budget LIVE (the ingress budget
        governor's knob). No-op on phase/dense engines — there is no
        budget to govern. Returns the budget now in force."""
        if self.sched.budgeted:
            budget = max(int(budget), 1)
            self.sched.token_budget = budget
            self.token_budget = budget
        return self.token_budget

    def _free_slots(self):
        return [s for s in range(self.max_batch)
                if s not in self.active and s not in self.prefilling
                and s not in self._staged]

    def slot_rids(self) -> Dict[int, int]:
        """slot -> rid of every request holding a slot — decoding OR
        mid-prefill. This is the enumeration migration and drain
        operate on (a mid-prefill request is pausable/migratable)."""
        out = {s: r.rid for s, r in self.active.items()}
        out.update({s: r.rid for s, r in self.prefilling.items()})
        return out

    def prefill_total(self, req: Request) -> int:
        """Tokens the cache must hold before the request can decode —
        the scheduler's unit of prefill work (see _prefill_tokens)."""
        n = len(req.prompt)
        if req.generated:
            n += len(req.generated) - 1
        return n

    @staticmethod
    def _prefill_tokens(req: Request) -> np.ndarray:
        """Tokens the cache must hold before the next decode step: the
        prompt, plus — for a preempted/resumed request — every generated
        token except the last (which the next step feeds in)."""
        if req.generated:
            return np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated[:-1], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _run_prefill(self, tokens_2d, cache_len: Optional[int] = None,
                     enc=None, last_idx=None):
        """Batched (possibly chunked) prefill over a throwaway cache.

        The paged path sizes the cache exactly to the (bucket-padded)
        prompt — its K/V is immediately scattered into the block pool; the
        dense path keeps ``max_len`` so ``kvcache.insert_request`` shapes
        line up. ``last_idx`` [G] selects each row's last REAL token for
        the returned logits (power-of-two prefill buckets; incompatible
        with chunking). Returns (last-token logits, cache)."""
        G, S = tokens_2d.shape
        self._prefill_shapes.add((G, S))
        rcache = T.init_cache(self.cfg, G, cache_len or S, self.dtype)
        if enc is None and self.cfg.family == "audio":
            enc = jnp.zeros((G, self.cfg.encoder_seq_len,
                             self.cfg.d_model), jnp.float32)
        chunk = self.prefill_chunk or S
        assert last_idx is None or chunk >= S, \
            "per-row last-token gather needs one-shot prefill"
        first = min(chunk, S)
        logits, rcache, _ = _prefill_fn(
            self.params, jnp.asarray(tokens_2d[:, :first]), rcache, enc,
            last_idx, cfg=self.cfg, window=self.window)
        off = first
        while off < S:  # chunked prefill: bound per-iteration work
            n = min(chunk, S - off)
            toks = jnp.asarray(tokens_2d[:, off:off + n])
            pos = jnp.broadcast_to(
                jnp.arange(off, off + n, dtype=jnp.int32), (G, n))
            logits, rcache, _ = _extend_fn(self.params, toks, pos, rcache,
                                           cfg=self.cfg, window=self.window)
            off += n
        return logits, rcache

    def _activate(self, req: Request, slot: int, length: int,
                  first_tok: Optional[int]):
        fresh_first = first_tok is not None and not req.generated
        req.prefill_pos = length
        if req.prefill_start_time is None:
            req.prefill_start_time = self.clock
        if first_tok is not None:
            req.generated.append(int(first_tok))
        if req.first_token_time is None:
            req.first_token_time = self.clock
        if self.span_hook:
            self.span_hook.on_activate(req, fresh_first)
        # the admission-sampled token can already satisfy a finish
        # condition (eos on the first token, max_new_tokens == 1): retire
        # without ever occupying a decode slot
        hit_eos = (req.eos_id is not None and req.generated
                   and req.generated[-1] == req.eos_id)
        if hit_eos or len(req.generated) >= req.max_new_tokens:
            req.finish_time = self.clock
            req.slot = None
            if self.cache_kind == "paged":
                PK.free_slot(self.pstate, slot)
            if slot in self._admit_order:   # was mid-prefill (chunked)
                self._admit_order.remove(slot)
            if self.span_hook:
                self.span_hook.on_finish(req)
            self._admit_finished.append(req)
            return
        req.slot = slot
        self.active[slot] = req
        if slot not in self._admit_order:   # chunked slots already queued
            self._admit_order.append(slot)
        if self.cache_kind == "dense":
            self._host_lengths[slot] = length

    # ---------------------------------------------------------- dense admit
    def _admit_dense(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            toks = self._prefill_tokens(req)
            enc = (self.encoder_input_fn(req)
                   if self.cfg.family == "audio" and self.encoder_input_fn
                   else None)
            logits, rcache = self._run_prefill(toks[None, :],
                                               cache_len=self.max_len,
                                               enc=enc)
            first = None
            if not req.generated:
                first = self._sample_batch(logits, [req])[0]
            self.cache = KV.insert_request(self.cache, slot, rcache,
                                           len(toks))
            self._activate(req, slot, len(toks), first)

    # ---------------------------------------------------------- paged admit
    def _admit_paged(self, wave: Optional[List[Request]] = None):
        """Admit a prefill WAVE: whole prompts, one bucketed forward per
        pow2 length group (misses) / (ctx, suffix) group (prefix hits).
        Phase scheduling pops its own wave from the queue; the
        token-budget scheduler passes the full grants it popped as
        ``wave``. Returns the requests actually admitted (callers detect
        backpressure requeues by comparing against the wave)."""
        if wave is None:
            # phase mode still drains mid-prefill slots first (a
            # migrated-in chunked request must finish somewhere): grant
            # each its full remainder
            if self.prefilling:
                self._run_chunks([
                    _ChunkSpec(req, slot,
                               self.prefill_total(req) - req.prefill_pos,
                               fresh=False)
                    for slot, req in sorted(self.prefilling.items())])
        free = self._free_slots()
        if wave is None:
            if not free or not self.queue:
                return []
            taken: List[Request] = []
            while self.queue and len(taken) < len(free):
                taken.append(self.queue.popleft())
        else:
            taken = list(wave)
            if not taken:
                return []
            assert len(taken) <= len(free), (len(taken), len(free))
        bs = self.pstate.block_size
        ptoks = {id(r): self._prefill_tokens(r) for r in taken}

        def blocks_needed(req):
            # LIVE columns only: prompt + headroom for the first decode
            # write, minus the leading columns a sliding window has
            # already retired (allocate skips them — a long prompt never
            # needs transient full-length residency in a window pool)
            S = len(ptoks[id(req)])
            cols = S // bs + 1
            if self.window:
                # allocate()'s own dead-column count at prefill time —
                # never larger, so this bound never under-reserves
                cols -= min(max((S - self.window + 1) // bs, 0), cols - 1)
            return cols

        def last_col(req):
            return len(ptoks[id(req)]) // bs  # the decode write head

        # pre-pass BEFORE any allocation: a request that can never fit —
        # pool too small, or prompt >= max_len (block-table row too
        # narrow) — is rejected now rather than head-of-line blocking
        # everything behind it; the rest of the wave goes back to the
        # queue intact, nothing is lost and no block leaks.
        width = self.pstate.block_tables.shape[1]
        for req in taken:
            need = blocks_needed(req)
            if need > self.pstate.n_blocks or last_col(req) >= width:
                for r in reversed([t for t in taken if t is not req]):
                    self.queue.appendleft(r)
                req.finish_time = self.clock  # rejected: no output
                raise PK.OutOfBlocks(
                    f"request rid={req.rid} needs {need} live blocks up "
                    f"to column {last_col(req)}; pool has "
                    f"{self.pstate.n_blocks}, table rows hold {width}")

        admitted: List[Request] = []
        slot_of: Dict[int, int] = {}
        ctx_of: Dict[int, int] = {}       # id(req) -> aliased context tokens
        matched_of: Dict[int, list] = {}  # id(req) -> matched block ids
        for idx, req in enumerate(taken):
            slot = free[len(admitted)]
            toks = ptoks[id(req)]
            # prefix-cache lookup BEFORE the backpressure check: a hit
            # only needs pool capacity for its suffix (aliased blocks are
            # revived/shared in place, never popped), so a shared-prefix
            # request admits under pressure that would stall a cold one —
            # the regime sharing exists for. The adopted context is
            # capped at S-1 so at least one suffix token remains to
            # produce first-token logits (a fully-aliased aligned prompt
            # recomputes its last token — the write into the shared tail
            # block is what copy-on-write forks).
            matched = (PK.match_prefix(self.pstate, toks, record=False)
                       if self.prefix_sharing and not self.window else [])
            ctx = min(len(matched) * bs, len(toks) - 1)
            if not (matched and ctx >= 1):
                matched, ctx = [], 0
            # blocks_needed covers every prompt column + write headroom
            # (enough for the worst-case CoW fork too); aliased columns
            # need no pop, but reviving a cached-free block does consume
            # a unit of free_block_count
            revive = sum(1 for b in matched
                         if int(self.pstate.refcount[b]) == 0)
            if (blocks_needed(req) - len(matched)
                    > self.pstate.free_block_count() - revive):
                # out of blocks: backpressure — requeue IN ORDER and stop
                for r in reversed(taken[idx:]):
                    self.queue.appendleft(r)
                break
            if matched:
                try:
                    PK.adopt_prefix(self.pstate, slot, matched, ctx)
                    PK.allocate(self.pstate, slot, len(toks) - ctx)
                except PK.OutOfBlocks:
                    PK.free_slot(self.pstate, slot)   # decref the adoption
                    for r in reversed(taken[idx:]):
                        self.queue.appendleft(r)
                    break
                ctx_of[id(req)] = ctx
            else:
                PK.allocate(self.pstate, slot, len(toks),
                            window=self.window)
                if self.prefix_sharing and not self.window:
                    # publish this prompt's full blocks NOW so wave-mates
                    # behind it match them: their reads (context gather
                    # in _prefill_shared_batch) run only after this wave's
                    # prefill writes, so the content is there by the time
                    # it's read. Hit requests register AFTER their suffix
                    # prefill instead — it can still fail (CoW fork under
                    # pool pressure), and keys must never describe
                    # unwritten blocks.
                    PK.register_prefix(self.pstate, slot, toks)
            matched_of[id(req)] = matched
            slot_of[id(req)] = slot
            admitted.append(req)
        # group prompts into power-of-two LENGTH BUCKETS (pad + per-row
        # last-token gather) so admission compiles O(log max_len)
        # executables instead of one per (group, prompt-len) pair; then
        # activate in SUBMISSION order (group iteration would reorder
        # _admit_order and break youngest-first preemption). Chunked
        # prefill keeps exact lengths (chunking already bounds shapes).
        groups: Dict[int, List[Request]] = {}
        for req in admitted:
            if id(req) in ctx_of:
                continue        # prefix-cache hit: suffix-only path below
            S = len(ptoks[id(req)])
            Sb = S if self.prefill_chunk else _pow2_at_least(S)
            groups.setdefault(Sb, []).append(req)
        first_of: Dict[int, Optional[int]] = {}
        for Sb, reqs in groups.items():
            lens = [len(ptoks[id(r)]) for r in reqs]
            toks = np.zeros((len(reqs), Sb), np.int32)
            for i, r in enumerate(reqs):
                toks[i, :lens[i]] = ptoks[id(r)]
            last = (None if self.prefill_chunk
                    else jnp.asarray(np.asarray(lens, np.int32) - 1))
            logits, rcache = self._run_prefill(toks, last_idx=last)
            firsts = self._sample_batch(logits, reqs)
            self.pstate = PK.write_tokens_batch(
                self.pstate, [slot_of[id(r)] for r in reqs],
                rcache["layers"]["k"], rcache["layers"]["v"],
                lengths=lens)
            for i, req in enumerate(reqs):
                first_of[id(req)] = None if req.generated else firsts[i]
        # cache hits: prefill the suffix only — BUCKETED like the miss
        # wave: hits group by (pow2 context bucket, pow2 suffix bucket)
        # and each group runs ONE batched extend + ONE suffix scatter +
        # ONE sampling call, instead of one of each per hit request
        hit_groups: Dict[tuple, List[Request]] = {}
        for req in admitted:
            if id(req) not in ctx_of:
                continue
            ctx = ctx_of[id(req)]
            n_new = len(ptoks[id(req)]) - ctx
            key = (_pow2_at_least(max(ctx, 1)), _pow2_at_least(n_new))
            hit_groups.setdefault(key, []).append(req)
        failed: List[Request] = []
        for (cb, Sb), greqs in hit_groups.items():
            ok: List[Request] = []
            for req in greqs:
                # copy-on-write forks BEFORE the group forward: the
                # suffix write may land inside an aliased tail block. A
                # fork that finds no free block (wave-mates consumed the
                # headroom) drops just that request — nothing was
                # written or registered for it — and it retries next step
                try:
                    PK.ensure_writable(self.pstate, slot_of[id(req)],
                                       ctx_of[id(req)],
                                       len(ptoks[id(req)]) - ctx_of[id(req)])
                    ok.append(req)
                except PK.OutOfBlocks:
                    PK.free_slot(self.pstate, slot_of[id(req)])
                    failed.append(req)
            if not ok:
                continue
            # pad the GROUP dim to a power of two as well (dummy rows
            # replicate the last member; their pool writes drop, their
            # logits are discarded) so executables are keyed on
            # (pow2 G, pow2 ctx, pow2 suffix) — a warmed wave shape
            # serves every later wave regardless of its exact hit count
            Gb = _pow2_at_least(len(ok))
            pad = [ok[-1]] * (Gb - len(ok))
            logits = self._prefill_shared_batch(
                [slot_of[id(r)] for r in ok + pad],
                [ptoks[id(r)] for r in ok + pad],
                [ctx_of[id(r)] for r in ok + pad], cb, Sb,
                n_real=len(ok))
            if self.prefix_sharing and not self.window:
                for r in ok:
                    PK.register_prefix(self.pstate, slot_of[id(r)],
                                       ptoks[id(r)])
            firsts = self._sample_batch(logits, ok + pad)[:len(ok)]
            for i, r in enumerate(ok):
                first_of[id(r)] = None if r.generated else firsts[i]
        if failed:
            for r in reversed(failed):      # preserve submission order
                self.queue.appendleft(r)
            failed_ids = {id(r) for r in failed}
            admitted = [r for r in admitted if id(r) not in failed_ids]
        for req in admitted:
            if self.prefix_sharing and not self.window:
                # gauge bookkeeping once per SUCCESSFUL admission — the
                # failure exits above (backpressure, fork OutOfBlocks)
                # never reach here, so retries don't skew the hit rate
                PK.record_lookup(self.pstate, ptoks[id(req)],
                                 matched_of[id(req)])
            self._activate(req, slot_of[id(req)], len(ptoks[id(req)]),
                           first_of[id(req)])
        if self.window:
            for req in admitted:
                if req.slot is not None:  # may have retired at admission
                    PK.free_out_of_window(self.pstate, req.slot, self.window)
        return admitted

    def _prefill_shared_batch(self, slots: List[int], toks_list,
                              ctxs: List[int], cb: int, Sb: int,
                              n_real: Optional[int] = None):
        """Bucketed suffix-only prefill for a GROUP of prefix-cache hits:
        splice every hit's adopted shared-block K/V (ONE batched pool
        gather) into a shared throwaway dense cache as attention context,
        run one decode-mode continuation over the padded suffix rows, and
        scatter ONLY the suffix K/V back (one batched pool write — the
        shared spans are never re-written). Prefill compute scales with
        the unshared suffixes, and executable count with the number of
        (context, suffix) power-of-two buckets — O(log² max_len) — not
        with the number of hit requests. Per-row true context lengths
        ride in the positions array (poisoned past ctx_i: BIG_POS rows
        are masked out of attention), so one executable serves every
        member of the bucket. Callers run ``ensure_writable`` (CoW fork)
        per member beforehand."""
        G = len(slots)
        n_real = G if n_real is None else n_real
        # dummy pad rows (duplicated slots past n_real) scatter nothing:
        # their new-token count is forced to 0 below, which the scatter
        # plan drops row-wise
        n_news = [(len(t) - c) if i < n_real else 0
                  for i, (t, c) in enumerate(zip(toks_list, ctxs))]
        # cb and Sb are already pow2-bucketed, so cb+Sb takes O(log^2)
        # values — no need to round the throwaway cache up again (a late
        # 256-ctx/64-chunk call attends over 320 keys, not 512)
        cache_len = cb + Sb
        self._prefill_shapes.add((G, Sb))
        st = self.pstate
        bs = st.block_size
        n_blk = -(-cb // bs)
        tbl = st.block_tables[np.asarray(slots, np.int64), :n_blk]
        tbl = np.where(tbl >= 0, tbl, 0)   # holes gather garbage; masked
        pos = np.full((G, cache_len), int(T.BIG_POS), np.int32)
        suffix = np.zeros((G, Sb), np.int32)
        spos = np.zeros((G, Sb), np.int32)
        for i, (toks, ctx, n_new) in enumerate(zip(toks_list, ctxs,
                                                   n_news)):
            pos[i, :ctx] = np.arange(ctx)
            suffix[i, :n_new] = toks[ctx:ctx + n_new]
            spos[i] = np.arange(ctx, ctx + Sb)
        # host half of the pool append (advances lengths, stamps epoch);
        # the device half rides inside the fused executable
        bidx, oidx = PK.scatter_plan(st, slots, Sb, lengths=n_news)
        logits, st.k, st.v = _chunk_prefill_fn(
            self.params, st.k, st.v, jnp.asarray(tbl, jnp.int32),
            jnp.asarray(suffix), jnp.asarray(spos), jnp.asarray(pos),
            jnp.asarray(np.asarray(n_news, np.int32) - 1),
            jnp.asarray(bidx, jnp.int32), jnp.asarray(oidx, jnp.int32),
            cfg=self.cfg, window=self.window, cache_len=cache_len,
            dtype=self.dtype)
        return logits

    def _admit(self):
        if self.cache_kind == "paged":
            if self.sched.budgeted:
                self._admit_budget()
            else:
                self._admit_paged()
        else:
            self._admit_dense()

    # ------------------------------------- token-budget admission (CB)
    def _admit_budget(self):
        """One continuous-batching admission pass: ask the scheduler how
        this step's token budget packs, then execute the grants — whole
        prompts ride the existing bucketed wave machinery (prefix
        matching, pow2 groups, backpressure requeue all intact), chunk
        grants run through ``_run_chunks``. Decode tokens were charged
        first inside ``plan``, so admission work is bounded and a long
        prompt is sliced across steps instead of stalling the batch."""
        plan = self.sched.plan(self)
        self.last_step_packed = plan.packed
        cont = [g for g in plan.grants if g.slot is not None]
        chunks = [_ChunkSpec(g.req, g.slot, g.n_tokens) for g in cont]
        wave: List[Request] = []
        partial = None
        for g in plan.grants:
            if g.slot is not None:
                continue
            if g.final:
                # granted fresh requests need not be a queue PREFIX —
                # class-aware policies admit out of FIFO order — so pop
                # each one wherever it sits (raises if the policy granted
                # something not actually queued)
                self._queue_remove(g.req)
                wave.append(g.req)
            else:
                partial = g         # stays queued for now
        requeued = False
        if wave:
            admitted = self._admit_paged(wave)
            requeued = len(admitted) < len(wave)
        if partial is not None and not requeued:
            # pool pressure on the wave means the partial (younger) grant
            # would only add pressure — leave it queued, FIFO intact
            spec = self._begin_chunked(partial.req, partial.n_tokens)
            if spec is not None:
                chunks.append(spec)
        ran = self._run_chunks(chunks) if chunks else 0
        # forward-progress guard: nothing decoding, nothing admitted,
        # every chunk blocked on the pool -> the prefilling slots are
        # starving each other; preempt the youngest so the oldest can
        # finish (never-fits rejection guarantees a lone prefill fits)
        if (not self.active and not ran and not wave
                and len(self.prefilling) > 1):
            victims = [s for s in self._admit_order
                       if s in self.prefilling]
            if len(victims) > 1:
                self._preempt(victims[-1])

    def _begin_chunked(self, req: Request, n: int) -> Optional[_ChunkSpec]:
        """Admit a WAITING request with a partial grant: claim a slot,
        run the same never-fits rejection and prefix-cache lookup as the
        wave path, and hand back the first chunk for execution. The
        request may sit anywhere in the queue (class-aware policies
        grant out of FIFO order); it keeps its position on allocation
        failure (backpressure). Prefix hits advance the cursor for free
        (aliased context costs no compute). Returns None when nothing
        was admitted."""
        assert any(r is req for r in self.queue), req.rid
        toks = self._prefill_tokens(req)
        S = len(toks)
        bs = self.pstate.block_size
        width = self.pstate.block_tables.shape[1]
        need = S // bs + 1
        if self.window:
            need -= min(max((S - self.window + 1) // bs, 0), need - 1)
        if need > self.pstate.n_blocks or S // bs >= width:
            self._queue_remove(req)
            req.finish_time = self.clock  # rejected: no output
            if self.span_hook:
                self.span_hook.on_finish(req)
            raise PK.OutOfBlocks(
                f"request rid={req.rid} needs {need} live blocks up to "
                f"column {S // bs}; pool has {self.pstate.n_blocks}, "
                f"table rows hold {width}")
        free = self._free_slots()
        if not free:
            return None
        slot = free[0]
        matched = (PK.match_prefix(self.pstate, toks, record=False)
                   if self.prefix_sharing and not self.window else [])
        ctx = min(len(matched) * bs, S - 1)
        if not (matched and ctx >= 1):
            matched, ctx = [], 0
        if matched:
            PK.adopt_prefix(self.pstate, slot, matched, ctx)
        self._queue_remove(req)
        req.slot = slot
        req.prefill_pos = ctx
        if req.prefill_start_time is None:
            req.prefill_start_time = self.clock
        self.prefilling[slot] = req
        self._admit_order.append(slot)
        self._prefill_matched[slot] = matched
        return _ChunkSpec(req, slot, min(n, S - ctx), fresh=True)

    def _run_chunks(self, specs: List[_ChunkSpec]) -> int:
        """Execute prefill chunks: allocate each chunk's block columns
        (progressive — only the columns these tokens land in), then run
        the chunks BUCKETED exactly like the prefix-hit suffix path: a
        chunk continuation over [cursor, cursor+n) IS a suffix prefill
        against the already-written span, so both share
        ``_prefill_shared_batch`` (context splice + decode-mode extend +
        suffix scatter), grouped by (pow2 context, pow2 chunk) with the
        group dim padded to pow2 — executable count stays
        O(log max_len)^2, independent of chunk count. Final chunks
        sample the first token and move the request into decode
        rotation. Returns the number of chunks that actually ran."""
        st = self.pstate
        bs = st.block_size
        ready: List[_ChunkSpec] = []
        for sp in specs:
            if self.prefilling.get(sp.slot) is not sp.req:
                continue   # preempted by the decode-room pass: replays
            start = sp.req.prefill_pos
            assert int(st.lengths[sp.slot]) == start, \
                (sp.slot, int(st.lengths[sp.slot]), start)
            try:
                PK.allocate(st, sp.slot, sp.n, window=self.window)
                if self.prefix_sharing:
                    # the chunk may write into an adopted (shared) tail
                    # block: fork it first, copy-on-write
                    PK.ensure_writable(st, sp.slot, start, sp.n)
            except PK.OutOfBlocks:
                if sp.fresh:
                    # first chunk found no blocks: undo the admission so
                    # the request waits in the QUEUE, not in a slot
                    del self.prefilling[sp.slot]
                    self._admit_order.remove(sp.slot)
                    self._prefill_matched.pop(sp.slot, None)
                    PK.free_slot(st, sp.slot)
                    sp.req.slot = None
                    sp.req.prefill_pos = 0
                    self.queue.appendleft(sp.req)
                continue                    # continuation retries next step
            ready.append(sp)
        if not ready:
            return 0
        # ONE group per step: every chunk shares a single (pow2 context,
        # pow2 suffix) bucket — per-row true starts ride in the positions
        # array, so mixing context lengths costs padded gather width, not
        # extra executables or extra forwards
        width_tokens = st.block_tables.shape[1] * bs
        starts = [int(st.lengths[sp.slot]) for sp in ready]
        cb = min(_pow2_at_least(max(max(starts), 1)), width_tokens)
        Sb = _pow2_at_least(max(sp.n for sp in ready))
        gsp = ready
        Gb = _pow2_at_least(len(gsp))
        padded = gsp + [gsp[-1]] * (Gb - len(gsp))
        toks_list = [self._prefill_tokens(sp.req)[:sp.req.prefill_pos
                                                  + sp.n]
                     for sp in padded]
        ctxs = [sp.req.prefill_pos for sp in padded]
        t_chunk0 = self.span_hook.now() if self.span_hook else 0.0
        logits = self._prefill_shared_batch(
            [sp.slot for sp in padded], toks_list, ctxs, cb, Sb,
            n_real=len(gsp))
        if self.span_hook:
            # one batched forward ran all chunks: they honestly share a
            # wall window, recorded per request against its prefill span
            t_chunk1 = self.span_hook.now()
            for sp in gsp:
                self.span_hook.on_chunk(sp.req.rid, sp.req.prefill_pos,
                                        sp.n, t_chunk0, t_chunk1)
        finals = [sp for sp in gsp
                  if sp.req.prefill_pos + sp.n
                  >= self.prefill_total(sp.req)]
        toks = None
        if any(not sp.req.generated for sp in finals):
            # one sampling sync per step, and ONLY when some member
            # finished its prompt — intermediate chunks discard their
            # logits without touching the host
            toks = self._sample_batch(
                logits, [sp.req for sp in padded])[:len(gsp)]
        for i, sp in enumerate(gsp):
            sp.req.prefill_pos += sp.n   # mirrors pstate.lengths
            if sp in finals:
                first = (None if sp.req.generated else int(toks[i]))
                self._finish_prefill(sp.req, sp.slot, first)
        if self.window:
            for sp in gsp:
                if sp.slot in self.prefilling \
                        or sp.slot in self.active:
                    PK.free_out_of_window(st, sp.slot, self.window)
        return len(ready)

    def _finish_prefill(self, req: Request, slot: int,
                        first: Optional[int]):
        """Last chunk landed: publish the finished prompt to the prefix
        cache (never earlier — keys must not describe unwritten blocks),
        count the lookup once per successful admission, and move the
        request into decode rotation."""
        del self.prefilling[slot]
        matched = self._prefill_matched.pop(slot, [])
        if self.prefix_sharing and not self.window:
            toks = self._prefill_tokens(req)
            PK.register_prefix(self.pstate, slot, toks)
            PK.record_lookup(self.pstate, toks, matched)
        self._activate(req, slot, req.prefill_pos, first)

    # ------------------------------------------------------------ preemption
    def _preempt(self, slot: int):
        """Return the request in ``slot`` to the queue head and free its
        blocks. Counter-based sampling keys make the resumed continuation
        identical to the uninterrupted one. A MID-PREFILL slot is an
        ordinary victim: its cursor resets and the chunks replay — the
        written span lived only in the freed blocks."""
        if slot in self.active:
            req = self.active.pop(slot)
        else:
            req = self.prefilling.pop(slot)
            self._prefill_matched.pop(slot, None)
        self._admit_order.remove(slot)
        PK.free_slot(self.pstate, slot)
        req.slot = None
        req.prefill_pos = 0
        req.preemptions += 1
        self.preempt_count += 1
        if self.span_hook:
            self.span_hook.on_preempt(req.rid)
        self.queue.appendleft(req)

    def _ensure_decode_room(self):
        """Every active slot needs pool room for one more token; under
        pressure, preempt the youngest request (vLLM-style). A lone
        request that has genuinely outgrown the pool (no victim left to
        preempt, requeueing would just re-admit it) is evicted with its
        partial output before raising, so the engine stays serviceable
        for everything behind it."""
        for slot in sorted(self.active.keys()):
            while slot in self.active:
                try:
                    PK.allocate(self.pstate, slot, 1)
                    if self.prefix_sharing:
                        # copy-on-write: the fused step scatters this
                        # slot's next token into column lengths//bs — fork
                        # it now if it is still shared with another stream
                        PK.ensure_writable(self.pstate, slot,
                                           int(self.pstate.lengths[slot]), 1)
                    break
                except PK.OutOfBlocks:
                    # victim ORDER is policy (the SLO scheduler shields
                    # interactive streams by pushing batch slots to the
                    # tail); the engine just takes the tail
                    victims = self.sched.victims(self)
                    if len(victims) <= 1:
                        req = self.active[slot]
                        req.finish_time = self.clock  # truncated output
                        if self.span_hook:
                            self.span_hook.on_finish(req)
                        self._retire(slot)
                        raise PK.OutOfBlocks(
                            f"request rid={req.rid} outgrew the pool at "
                            f"{len(req.generated)} generated tokens; "
                            f"evicted with truncated output")
                    self._preempt(victims[-1])

    # ------------------------------------------------------------------ step
    def step(self, dt: float = 1.0):
        """One engine iteration: admit from queue, one fused decode+sample
        call for all active slots, retire finished requests. Exactly one
        device→host sync (the sampled-token fetch) in steady state."""
        self.clock += dt
        self.last_step_packed = None   # set by the token-budget planner
        self._admit()
        finished = self._admit_finished
        self._admit_finished = []
        if self.cache_kind == "paged" and self.active:
            # may preempt: must run BEFORE the step snapshots active slots
            self._ensure_decode_room()
        if not self.active:
            return finished or None
        B = self.max_batch
        tokens = np.zeros((B,), np.int32)
        active_mask = np.zeros((B,), bool)
        for slot, req in self.active.items():
            tokens[slot] = req.generated[-1]
            active_mask[slot] = True
        temps, topks, seeds, ctrs = self._sampling_arrays()
        # static flags: all-greedy batches skip the sampler's top-k +
        # Gumbel work inside the fused step entirely, and the batch-max
        # top_k bounds the threshold search to lax.top_k instead of a
        # full-vocab sort (a handful of compiled variants at most)
        stoch = bool((temps > 0).any())
        max_top_k = int(topks.max())

        if self.cache_kind == "paged":
            st = self.pstate
            pre_lengths = st.lengths.copy()
            bs = st.block_size
            # power-of-2 bucket of the widest needed table prefix: decode
            # cost tracks the true max context, with O(log) recompiles.
            # Derived from LENGTHS (col of the incoming write), not from
            # block counts — window-freed rows have leading holes.
            need = (int(st.lengths[active_mask].max()) // bs + 1) if \
                active_mask.any() else 1
            nb = min(_pow2_at_least(max(need, 1)),
                     st.block_tables.shape[1])
            tables = np.ascontiguousarray(st.block_tables[:, :nb])
            toks_dev, st.k, st.v = _jitted_steps()[1](
                self.params, st.k, st.v, tables,
                st.lengths.astype(np.int32), active_mask, tokens,
                temps, topks, seeds, ctrs, cfg=self.cfg,
                window=self.window, impl=self._paged_impl,
                interp=self._interpret, stochastic=stoch,
                max_top_k=max_top_k, degrees=self._step_degrees)
            toks = jax.device_get(toks_dev)     # the ONE host sync
            st.lengths[active_mask] += 1
            # dirty-set bookkeeping for overlapped migration: the fused
            # step scattered each active slot's token into the block at
            # its pre-step write head (host arithmetic only — no sync)
            PK.mark_written(st, [
                int(st.block_tables[s, int(pre_lengths[s]) // bs])
                for s in self.active])
            if self.window:
                for slot in self.active:
                    PK.free_out_of_window(st, slot, self.window)
        else:
            pre_lengths = self._host_lengths.copy()
            positions = pre_lengths[:, None].astype(np.int32)
            toks_dev, self.cache = _jitted_steps()[0](
                self.params, self.cache, tokens[:, None],
                positions, temps, topks, seeds, ctrs,
                cfg=self.cfg, window=self.window, stochastic=stoch,
                max_top_k=max_top_k)
            toks = jax.device_get(toks_dev)     # the ONE host sync
            self._host_lengths[active_mask] += 1
        self._step_count += 1

        for slot, req in list(self.active.items()):
            tok = int(toks[slot])
            req.generated.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = len(req.generated) >= req.max_new_tokens
            over = int(pre_lengths[slot]) + 2 >= self.logical_max
            if hit_eos or full or over:
                req.finish_time = self.clock
                if self.span_hook:
                    self.span_hook.on_finish(req)
                finished.append(req)
                self._retire(slot)
        return finished

    def _retire(self, slot: int):
        del self.active[slot]
        self._admit_order.remove(slot)
        if self.cache_kind == "paged":
            PK.free_slot(self.pstate, slot)
        else:
            self._host_lengths[slot] = 0
            self.cache = KV.evict_request(self.cache, slot)

    def prefix_stats(self) -> dict:
        """Live prefix-sharing gauges (hit rate, CoW forks, blocks saved)
        — the telemetry the orchestrator folds into MetricsSnapshot."""
        if self.cache_kind != "paged":
            return {"queries": 0, "hits": 0, "hit_rate": 0.0,
                    "cow_forks": 0, "blocks_saved_total": 0,
                    "blocks_saved_now": 0, "cached_blocks": 0}
        return PK.prefix_stats(self.pstate)

    @property
    def block_size(self) -> int:
        """Pool block granularity (0 for a dense engine) — what the pod
        router hashes incoming prompts by (serving/router.py)."""
        return self.pstate.block_size if self.cache_kind == "paged" else 0

    def prefix_keys(self) -> set:
        """Hex-encoded content-chain keys RESIDENT in this engine's
        prefix cache — the router's pod-wide affinity signal. Hex (not
        raw bytes) so the set survives msgpack/JSON round trips
        unchanged."""
        if self.cache_kind != "paged" or not self.prefix_sharing:
            return set()
        return {k.hex() for k in self.pstate.prefix_cache}

    def stream_progress(self) -> Dict[int, List[int]]:
        """rid -> tokens generated so far, for every SLOT-HOLDING
        request (decoding or mid-prefill) — the ingress streaming feed.
        Full lists each step, not deltas: idempotent under migration
        overlap and crash replay (a restarted stream re-emits a prefix
        of itself; consumers keep a high-water mark)."""
        out = {r.rid: list(r.generated) for r in self.active.values()}
        out.update({r.rid: list(r.generated)
                    for r in self.prefilling.values()})
        return out

    def run_until_done(self, max_steps: int = 10_000):
        out = []
        steps = 0
        while (self.queue or self.active or self.prefilling) \
                and steps < max_steps:
            fin = self.step() or []
            out.extend(fin)
            steps += 1
        return out

    # ------------------------------------------- live module scaling API
    def apply_plan(self, plan):
        """Apply a PlacementPlan's per-layer replication degrees (P) to
        the LIVE decode step — CoCoServe scale-up without draining: the
        next ``step()`` runs ``forward_paged`` unrolled, each layer under
        its plan-assigned batch-sharding constraint (degrees quantized to
        the local replication mesh; an all-ones plan restores the O(1)
        lax.scan step). Token streams are unaffected — resharding changes
        where the batch computes, not what it computes."""
        if self.cache_kind != "paged":
            raise ValueError("apply_plan targets the paged decode step; "
                             "dense engines predate module scaling")
        from repro.core import replication as R
        p = tuple(plan.p) if hasattr(plan, "p") else tuple(plan)
        if len(p) != self.cfg.num_layers:
            raise ValueError(f"plan covers {len(p)} layers, "
                             f"model has {self.cfg.num_layers}")
        self.replication_degrees = p
        if all(d == 1 for d in p):
            self._step_degrees = None
        else:
            mesh_n = R.default_replication_mesh().devices.size
            self._step_degrees = tuple(R.quantize_degrees(list(p), mesh_n))

    # --------------------------------------- request migration (paged)
    def pause_request(self, slot: int,
                      since_epoch: Optional[int] = None) -> dict:
        """Detach the ACTIVE request in ``slot`` and export its full
        serving state: KV blocks (paged_kv.export_blocks wire format),
        position (token count), and the counter-based sampling state —
        which is just (seed, len(generated)), carried by the Request
        itself. Shared (refcount > 1) blocks are MATERIALIZED into the
        payload with their prefix keys, so the export is self-contained;
        the slot then releases its claim (decref — co-holders of shared
        blocks are untouched, sole-owned blocks return to the pool).
        ``resume_request`` on any engine with identical cfg/params
        continues the stream token-identically.

        ``since_epoch`` (a prior ``snapshot_request``'s ``epoch``) makes
        this the phase-2 pause of an OVERLAPPED migration: the payload
        carries only the blocks written since the snapshot — the short
        delta the destination's ``commit_resume`` applies over its
        staged phase-1 base."""
        if self.cache_kind != "paged":
            raise ValueError("pause/resume migrates paged KV blocks; "
                             "dense slabs go through core.migration")
        if slot in self.active:
            req = self.active.pop(slot)
            phase = "decode"
        else:
            # a MID-PREFILL request pauses too: the cursor (lengths ==
            # prefill_pos) and the chunk-written blocks travel in the
            # payload, so the destination resumes WITHOUT replaying the
            # prefill work that already landed
            req = self.prefilling.pop(slot)
            self._prefill_matched.pop(slot, None)
            phase = "prefill"
        self._admit_order.remove(slot)
        if self.span_hook:
            self.span_hook.on_pause(req.rid)
        payload = PK.export_blocks(self.pstate, slot,
                                   since_epoch=since_epoch)
        PK.free_slot(self.pstate, slot)
        req.slot = None
        # "position"/"counter" are INFORMATIONAL wire-format mirrors (for
        # cross-host transports/logging); the authoritative copies travel
        # inside the payload: import_blocks restores position from
        # kv["length"], the sampler re-derives the counter from
        # len(request.generated). "v" stamps the payload shape — resume
        # ops reject a mismatch loudly instead of KeyError-ing mid-bind.
        return {"v": MIGRATION_WIRE_VERSION, "request": req, "kv": payload,
                "position": payload["length"],
                "counter": len(req.generated),
                "phase": phase}

    @staticmethod
    def _check_payload_version(payload: dict, op: str):
        """Reject an old- or alien-shape migration payload with a clear
        error (surfaced as ``RemoteError`` over RPC) rather than letting
        a missing field KeyError deep inside the bind path."""
        v = payload.get("v") if isinstance(payload, dict) else None
        if v != MIGRATION_WIRE_VERSION:
            raise ValueError(
                f"{op}: migration payload version {v!r} unsupported "
                f"(this engine speaks v{MIGRATION_WIRE_VERSION}; "
                "re-export from a matching peer)")

    def resume_request(self, payload: dict) -> bool:
        """Rebind a paused request's blocks into this engine's pool and
        put it back in decode rotation. Imported blocks arrive OWNED
        (refcount 1); prefix keys carried in the payload re-seed this
        pool's cache so later admissions can alias the migrated prompt.
        Returns False — WITHOUT dropping the request or touching the pool
        — when no slot or not enough blocks are free (the caller
        re-queues it; counter-based sampling replays the continuation
        deterministically)."""
        if self.cache_kind != "paged":
            raise ValueError("resume_request needs a paged engine")
        self._check_payload_version(payload, "resume_request")
        req = payload["request"]
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        try:
            PK.import_blocks(self.pstate, slot, payload["kv"])
        except PK.OutOfBlocks:
            return False
        self._bind_resumed(req, slot, payload)
        return True

    def _bind_resumed(self, req: Request, slot: int, payload: dict):
        """Place a migrated-in request: decode rotation normally, or —
        when it was paused MID-PREFILL — the prefilling set, cursor
        restored from the imported length, where the scheduler's next
        plan grants its remaining chunks (the phase scheduler drains it
        with one full-remainder chunk)."""
        req.slot = slot
        if payload.get("phase", "decode") == "prefill":
            req.prefill_pos = int(payload["kv"]["length"])
            self.prefilling[slot] = req
        else:
            self.active[slot] = req
        self._admit_order.append(slot)  # migrated-in = youngest
        if self.span_hook:
            self.span_hook.on_resume(req, payload.get("phase", "decode"))

    # ------------------------------- overlapped (two-phase) migration
    def snapshot_request(self, slot: int) -> dict:
        """Phase 1 of an overlapped migration: export the ACTIVE request
        in ``slot`` WITHOUT detaching it — the stream keeps decoding
        while the bulk payload travels and the destination stages it
        (``prepare_resume``). The returned ``epoch`` is the dirty-set
        cursor: pass it to ``pause_request(slot, since_epoch=epoch)``
        for the phase-2 delta (blocks written since this snapshot)."""
        if self.cache_kind != "paged":
            raise ValueError("snapshot_request needs a paged engine")
        req = (self.active.get(slot) or self.prefilling[slot])
        payload = PK.export_blocks(self.pstate, slot)
        return {"v": MIGRATION_WIRE_VERSION, "rid": req.rid, "kv": payload,
                "epoch": payload["epoch"], "position": payload["length"]}

    def prepare_resume(self, snap: dict) -> Optional[int]:
        """Stage a phase-1 snapshot into this pool: import the blocks
        into a free slot that admission cannot touch (``_staged``), but
        do NOT activate anything — the request itself is still decoding
        at the source. Returns the staging slot, or None (without
        mutating the pool) when no slot or not enough blocks are free."""
        if self.cache_kind != "paged":
            raise ValueError("prepare_resume needs a paged engine")
        self._check_payload_version(snap, "prepare_resume")
        free = self._free_slots()
        if not free:
            return None
        slot = free[0]
        try:
            PK.import_blocks(self.pstate, slot, snap["kv"])
        except PK.OutOfBlocks:
            return None
        self._staged[slot] = snap["rid"]
        return slot

    def commit_resume(self, slot: int, payload: dict) -> bool:
        """Phase 2: apply the pause-time delta over the staged base and
        put the request into decode rotation. ``payload`` is the source's
        ``pause_request(slot, since_epoch=snapshot epoch)`` result. On
        OutOfBlocks (the delta needed new columns a now-full pool can't
        provide) the staging is rolled back and False returned — the
        caller re-queues the request, which replays deterministically."""
        assert slot in self._staged, f"slot {slot} holds no staged import"
        self._check_payload_version(payload, "commit_resume")
        req = payload["request"]
        try:
            PK.import_blocks_delta(self.pstate, slot, payload["kv"])
        except PK.OutOfBlocks:
            self.abort_resume(slot)
            return False
        del self._staged[slot]
        # the phase is decided at PAUSE time: a request snapshotted
        # mid-prefill may have finished its prompt during the overlap
        # steps — the delta carries the later writes either way
        self._bind_resumed(req, slot, payload)
        return True

    def abort_resume(self, slot: int):
        """Drop a staged phase-1 import (source died, request finished
        at the source, or the caller chose replay): free the staged
        blocks and return the slot to admission."""
        if slot in self._staged:
            del self._staged[slot]
            PK.free_slot(self.pstate, slot)
