"""Continuous-batching inference engine (real JAX execution).

Iteration-level scheduling in the Orca/vLLM style: a fixed pool of batch
slots; new requests are prefilled individually (batch=1) and inserted into a
free slot; every engine step decodes all active slots in one fused
``decode_step``. Inactive slots decode garbage that is masked out — the
standard static-batch trick that keeps the jitted step shape-stable.

This engine is exercised with reduced configs in tests/examples; the
full-scale serving path is proven via the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import kvcache as KV


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full distribution
    seed: int = 0
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, dtype="float32", swa: bool = False,
                 encoder_input_fn: Optional[Callable] = None,
                 prefill_chunk: int = 0, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = KV.cache_capacity(cfg, max_len, swa=swa)
        self.logical_max = max_len
        self.window = cfg.sliding_window if swa else None
        self.dtype = dtype
        self.encoder_input_fn = encoder_input_fn
        self.prefill_chunk = prefill_chunk  # 0 = one-shot prefill
        self.cache = T.init_cache(cfg, max_batch, self.max_len, dtype)
        self.active: Dict[int, Request] = {}   # slot -> request
        self.queue: List[Request] = []
        self.clock = 0.0
        self._step_count = 0

        cfg_ = cfg
        window = self.window

        @jax.jit
        def _prefill(params, tokens, cache, enc):
            return T.forward(params, cfg_, tokens, mode="prefill",
                             cache=cache, window=window, encoder_input=enc)

        @jax.jit
        def _decode(params, tokens, positions, cache):
            return T.forward(params, cfg_, tokens, positions=positions,
                             mode="decode", cache=cache, window=window)

        @jax.jit
        def _extend(params, tokens, positions, cache):
            # multi-token continuation (chunked prefill tail chunks)
            return T.forward(params, cfg_, tokens, positions=positions,
                             mode="decode", cache=cache, window=window)

        self._prefill = _prefill
        self._decode = _decode
        self._extend = _extend

    # ------------------------------------------------------------- sampling
    def _sample(self, req: Request, logits_row) -> int:
        V = self.cfg.vocab_size
        logits = logits_row[:V]
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        rng = np.random.default_rng(
            req.seed * 1_000_003 + len(req.generated))
        lg = np.asarray(logits, np.float64) / req.temperature
        if req.top_k:
            kth = np.partition(lg, -req.top_k)[-req.top_k]
            lg = np.where(lg >= kth, lg, -np.inf)
        p = np.exp(lg - lg.max())
        p /= p.sum()
        return int(rng.choice(V, p=p))

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request):
        req.submit_time = self.clock
        self.queue.append(req)

    def _free_slots(self):
        return [s for s in range(self.max_batch) if s not in self.active]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            req.slot = slot
            S = len(req.prompt)
            rcache = T.init_cache(self.cfg, 1, self.max_len, self.dtype)
            enc = None
            if self.cfg.family == "audio":
                enc = (self.encoder_input_fn(req) if self.encoder_input_fn
                       else jnp.zeros((1, self.cfg.encoder_seq_len,
                                       self.cfg.d_model), jnp.float32))
            chunk = self.prefill_chunk or S
            first = min(chunk, S)
            logits, rcache, _ = self._prefill(
                self.params, jnp.asarray(req.prompt[:first], jnp.int32)[None],
                rcache, enc)
            off = first
            while off < S:  # chunked prefill: bound per-iteration work
                n = min(chunk, S - off)
                toks = jnp.asarray(req.prompt[off:off + n], jnp.int32)[None]
                pos = jnp.arange(off, off + n, dtype=jnp.int32)[None]
                logits, rcache, _ = self._extend(self.params, toks, pos,
                                                 rcache)
                off += n
            nxt = self._sample(req, logits[0])
            req.generated.append(nxt)
            req.first_token_time = self.clock
            self.cache = KV.insert_request(self.cache, slot, rcache, S)
            self.active[slot] = req

    # ------------------------------------------------------------------ step
    def step(self, dt: float = 1.0):
        """One engine iteration: admit from queue, one decode step for all
        active slots, retire finished requests."""
        self.clock += dt
        self._admit()
        if not self.active:
            return
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.asarray(jax.device_get(self.cache["length"]))
        positions = np.zeros((B, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
            positions[slot, 0] = lengths[slot]
        logits, self.cache, _ = self._decode(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            self.cache)
        self._step_count += 1
        finished = []
        for slot, req in list(self.active.items()):
            tok = self._sample(req, logits[slot])
            req.generated.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = len(req.generated) >= req.max_new_tokens
            over = int(positions[slot, 0]) + 2 >= self.logical_max
            if hit_eos or full or over:
                req.finish_time = self.clock
                finished.append(req)
                self.cache = KV.evict_request(self.cache, slot)
                del self.active[slot]
        return finished

    def run_until_done(self, max_steps: int = 10_000):
        out = []
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            fin = self.step() or []
            out.extend(fin)
            steps += 1
        return out
