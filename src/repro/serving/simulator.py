"""Event-driven cluster serving simulator.

Reproduces the paper's evaluation (Figs. 2/3/6/8/10/11) on this CPU-only
container: per-iteration latency comes from an analytic roofline cost model
(compute / HBM / interconnect — the same constants as EXPERIMENTS.md), memory
from the Table-1 module footprints, and the three serving systems differ
exactly along the axes the paper describes:

* ``hft``       — static batching (a batch runs to completion before new
  admissions), KV reserved at max length (fragmentation), no admission
  control: memory overrun = OOM failure, batch dropped + restart stall.
* ``vllm``      — continuous batching + paged KV (allocate-as-you-go, small
  page overhead), admission control prevents most OOM.
* ``cocoserve`` — ``vllm`` scheduling + the CoCoServe Controller: layer
  replication (Alg. 1) accelerates iterations per the speedup model, and
  Module Reduction (Alg. 2) migrates KV/layers before violations escalate.

The simulator is intentionally deterministic given (workload seed, config).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cluster import Cluster, layer_weight_bytes
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import Monitor, MetricsSnapshot
from repro.core.plan import PlacementPlan
from repro.core.speedup import (SpeedupModelConfig, gamma_of, speedup_homo)
from repro.serving.kvcache import kv_bytes_per_token
from repro.serving.workload import SimRequest, WorkloadConfig, generate


# Kernel efficiency (fraction of peak the serving stack reaches). The paper's
# Fig. 2 shows HFT leaving 20-40% of the GPU idle and suffering Python-level
# serial overheads; vLLM/CoCoServe run fused paged-attention kernels.
SYSTEM_EFFICIENCY = {"hft": 0.08, "vllm": 0.50, "cocoserve": 0.50}
# Effective HBM efficiency (naive attention re-reads & fragmentation vs paged)
SYSTEM_MEM_EFF = {"hft": 0.75, "vllm": 0.85, "cocoserve": 0.85}
# Static batch cap: HFT uses the paper's default static batch of 15;
# continuous batching admits until memory admission control stops it.
SYSTEM_BATCH_CAP = {"hft": 20, "vllm": 48, "cocoserve": 48}
# Pipelined overlap efficiency once layers span multiple devices (the paper's
# degree-of-parallelism effect, Fig. 6c/d): each extra device contributes a
# modest fraction of its HBM bandwidth to the aggregate weight stream.
PIPELINE_OVERLAP = 0.15
# HFT OOM model: a naive allocator under queue pressure (no paging, dynamic
# per-request tensors + fragmentation) fails once the backlog exceeds this
# multiple of the batch capacity (Fig. 11a).
HFT_OOM_QUEUE_FACTOR = 6.0


@dataclasses.dataclass
class SimConfig:
    model: ModelConfig
    system: str = "cocoserve"          # hft | vllm | cocoserve
    n_devices: int = 4
    n_instances: int = 1
    max_batch: int = 0                 # 0 -> SYSTEM_BATCH_CAP default
    max_seq: int = 768                 # prompt + 256 gen + slack
    slo_latency_s: float = 12.0
    hbm_bw: float = 1.5e12             # A100: ~1.5 TB/s
    restart_stall_s: float = 3.0      # HFT OOM recovery
    page_overhead: float = 0.04
    controller_period_s: float = 1.0
    tick_floor_s: float = 1e-3
    queue_timeout_s: float = 30.0      # client gives up waiting (all systems)
    # Fig. 6 sweep support: pre-replicate N layers at degree dop across the
    # other devices and (optionally) freeze the controller.
    preset_replicated_layers: int = 0
    preset_dop: int = 1
    enable_controller: bool = True
    # override the kernel-efficiency table (Fig. 6 reproduces the paper's
    # compute-bound HFT-based executor with replication added)
    efficiency_override: Optional[float] = None
    # set to paper's testbed by default
    device_mem_gb: float = 40.0
    device_flops: float = 312e12
    link_gbps: float = 64.0

    def __post_init__(self):
        if self.max_batch == 0:
            self.max_batch = SYSTEM_BATCH_CAP[self.system]


@dataclasses.dataclass
class SimResult:
    completed: List[SimRequest]
    dropped: int
    oom_events: int
    sim_time: float
    controller_log: List[str]
    peak_mem_per_device: List[float]

    # ------------------------------------------------------------- metrics
    def latencies(self):
        return np.array([r.latency for r in self.completed]) \
            if self.completed else np.array([float("inf")])

    @property
    def mean_latency(self) -> float:
        return float(np.mean(self.latencies()))

    @property
    def p95_latency(self) -> float:
        return float(np.percentile(self.latencies(), 95))

    @property
    def throughput_tokens(self) -> float:
        toks = sum(r.prompt_len + r.generated for r in self.completed)
        return toks / max(self.sim_time, 1e-9)

    @property
    def throughput_requests(self) -> float:
        return len(self.completed) / max(self.sim_time, 1e-9)

    def slo_attainment(self, slo: float) -> float:
        total = len(self.completed) + self.dropped
        if total == 0:
            return 1.0
        ok = sum(1 for r in self.completed if r.latency <= slo)
        return ok / total


class InstanceSim:
    """One model instance: cost model + memory accounting + batch state."""

    def __init__(self, sim: SimConfig, cluster: Cluster, home: int,
                 plan: Optional[PlacementPlan] = None):
        self.sim = sim
        cfg = sim.model
        self.cfg = cfg
        self.cluster = cluster
        self.home = home
        self.plan = plan or PlacementPlan.initial(cfg.num_layers, home)
        self.batch_cap = sim.max_batch
        self.running: List[SimRequest] = []
        self.stall_until = 0.0
        # static footprints
        self.weight_bytes = cfg.param_count() * 2
        self.layer_bytes = layer_weight_bytes(cfg)
        self.kv_per_token = kv_bytes_per_token(cfg)
        self.m = SpeedupModelConfig(d_model=cfg.d_model, seq_len=1,
                                    batch_size=max(sim.max_batch, 1))
        self.gamma = gamma_of(cluster, self.m)
        # big models span multiple devices (tensor parallel) like the
        # paper's 70B instance on 4xA100-40GB
        cap = cluster.device(home).mem_capacity
        self.span = min(sim.n_devices,
                        max(1, int(np.ceil(self.weight_bytes / (0.8 * cap)))))
        for j in range(self.span):
            dev = cluster.device((home + j) % sim.n_devices)
            dev.used_mem += self.weight_bytes / self.span

    # ------------------------------------------------------------- memory
    def kv_bytes_running(self) -> float:
        scale = 1.0 + self.sim.page_overhead
        if self.sim.system == "hft":
            # static allocation at max length for every admitted request
            return len(self.running) * self.sim.max_seq * self.kv_per_token
        toks = sum(r.prompt_len + r.generated for r in self.running)
        return toks * self.kv_per_token * scale

    def kv_home_fraction(self) -> float:
        """Fraction of this instance's KV still on the home device (the
        rest was migrated by Alg. 2 phase 1)."""
        migrated = sum(1 for (l, comp) in self.plan.migrated
                       if comp == "kv_cache")
        return 1.0 - migrated / max(self.cfg.num_layers, 1)

    def mem_on_home(self) -> float:
        return (self.weight_bytes / self.span
                + self.kv_bytes_running() * self.kv_home_fraction() / self.span)

    # ---------------------------------------------------------- cost model
    def _active_params(self) -> float:
        cfg = self.cfg
        n = self.weight_bytes / 2
        if cfg.num_experts:
            frac = ((cfg.num_experts_per_tok + cfg.num_shared_experts)
                    / max(cfg.num_experts, 1))
            n = n * min(1.0, frac + 0.3)
        return n

    def _iter_seconds(self, batch: int, mean_ctx: float, new_tokens: int
                      ) -> float:
        """One decode iteration: per-layer roofline, replication splits the
        batch p_i ways (compute AND this-batch KV reads), discontinuities pay
        scatter/gather on the link — the executable form of Eqs. 1-3."""
        cfg = self.cfg
        dev = self.cluster.device(self.home)
        eff = (self.sim.efficiency_override
               or SYSTEM_EFFICIENCY[self.sim.system])
        mem_eff = SYSTEM_MEM_EFF[self.sim.system]
        p = np.asarray(self.plan.p, dtype=np.float64)
        share = np.ceil(batch / p)                      # requests per replica
        n_layers = max(cfg.num_layers, 1)
        layer_params = self._active_params() / n_layers
        layer_bytes = 2.0 * layer_params
        # tensor-parallel span splits both compute and the weight stream
        span_eff = 1.0 + 0.9 * (self.span - 1)
        compute = (2.0 * layer_params * share
                   / (dev.compute_flops * eff * span_eff))
        kv_layer_ctx = mean_ctx * self.kv_per_token / n_layers
        # layers spread across k devices stream weights from k HBMs in a
        # pipelined fashion (the paper's dop effect, Fig. 6c/d)
        k_dev = max(len(self.plan.devices_used()), self.span)
        bw_factor = 1.0 + PIPELINE_OVERLAP * (k_dev - 1) \
            if self.span == 1 else span_eff
        mem = (layer_bytes / bw_factor + share * kv_layer_ctx) \
            / (self.sim.hbm_bw * mem_eff)
        layer_t = float(np.maximum(compute, mem).sum())
        # TP collectives for spanning instances (2 all-reduces per layer)
        if self.span > 1:
            layer_t += n_layers * (2 * 2 * cfg.d_model * batch
                                   / self.cluster.link_bandwidth + 4e-6)
        # lm head
        head = (2.0 * cfg.d_model * cfg.vocab_size * batch
                / (dev.compute_flops * eff * span_eff))
        # migrated KV is read over the interconnect every iteration
        mig_frac = 1.0 - self.kv_home_fraction()
        mig_t = (mig_frac * batch * mean_ctx * self.kv_per_token
                 / self.cluster.link_bandwidth)
        # scatter/gather at plan discontinuities (δ boundaries)
        breaks = self.plan.continuity_breaks()
        act_bytes = 2 * cfg.d_model * batch
        comm_t = breaks * (act_bytes / self.cluster.link_bandwidth + 4e-6)
        return layer_t + head + mig_t + comm_t

    def _prefill_seconds(self, tokens: int) -> float:
        dev = self.cluster.device(self.home)
        eff = (self.sim.efficiency_override
               or SYSTEM_EFFICIENCY[self.sim.system])
        sp = speedup_homo(self.plan.p, self.gamma)
        span_eff = 1.0 + 0.9 * (self.span - 1)
        return (2.0 * self._active_params() * tokens
                / (dev.compute_flops * eff * span_eff) / sp)


def _percentile(vals, q):
    return float(np.percentile(vals, q)) if len(vals) else 0.0


def simulate(sim: SimConfig, wl: WorkloadConfig) -> SimResult:
    cluster = Cluster.homogeneous(sim.n_devices, mem_gb=sim.device_mem_gb,
                                  flops=sim.device_flops,
                                  link_gbps=sim.link_gbps)
    instances = [InstanceSim(sim, cluster, home=i % sim.n_devices)
                 for i in range(sim.n_instances)]
    if sim.preset_replicated_layers:
        for inst in instances:
            others = [d for d in range(sim.n_devices) if d != inst.home]
            for i in range(min(sim.preset_replicated_layers,
                               sim.model.num_layers)):
                for j in range(sim.preset_dop - 1):
                    inst.plan.add_replica(i, others[j % len(others)])
    requests = generate(wl)
    pending = list(requests)
    completed: List[SimRequest] = []
    dropped = 0
    oom_events = 0
    ctrl_log: List[str] = []
    peak_mem = [0.0] * sim.n_devices

    monitors = [Monitor() for _ in instances]
    controllers: List[Optional[Controller]] = [None] * len(instances)
    if sim.system == "cocoserve" and sim.enable_controller:
        for i, inst in enumerate(instances):
            ccfg = ControllerConfig(replica_size=inst.layer_bytes,
                                    gamma=inst.gamma)

            def mk_violating(inst=inst):
                def f(plan, bs):
                    dev = cluster.device(inst.home)
                    old_plan, inst_plan = inst.plan, plan
                    inst.plan = plan
                    over_mem = inst.mem_on_home() > dev.mem_capacity * 0.92
                    it = inst._iter_seconds(max(len(inst.running), 1), 300, 1)
                    inst.plan = old_plan
                    # violating if memory critical or iteration too slow for SLO
                    return over_mem or (it * 256 > sim.slo_latency_s)
                return f

            controllers[i] = Controller(
                ccfg, cluster, inst.plan, monitors[i],
                batch_size=sim.max_batch, is_violating=mk_violating())

    t = 0.0
    next_ctrl = sim.controller_period_s
    recent_lat: List[float] = []
    guard = 0
    horizon = wl.duration_s + 600.0
    while (pending or any(inst.running for inst in instances)) and t < horizon:
        guard += 1
        if guard > 2_000_000:
            break
        # ---------------- client timeouts
        for r in [r for r in pending
                  if r.arrival <= t - sim.queue_timeout_s]:
            r.dropped = True
            pending.remove(r)
            dropped += 1

        # ---------------- admission
        for inst in instances:
            if t < inst.stall_until:
                continue
            free_now = [r for r in pending if r.arrival <= t]
            if sim.system == "hft":
                # static batching: only admit when the instance is idle
                if inst.running or not free_now:
                    continue
                # naive allocator under backlog pressure: fragmentation +
                # dynamic per-request tensors overflow -> OOM, batch lost
                if len(free_now) > HFT_OOM_QUEUE_FACTOR * inst.batch_cap:
                    oom_events += 1
                    inst.stall_until = t + sim.restart_stall_s
                    batch = free_now[:inst.batch_cap]
                    for r in batch:
                        r.dropped = True
                        pending.remove(r)
                        dropped += 1
                    continue
                batch = free_now[:inst.batch_cap]
                for r in batch:
                    pending.remove(r)
                inst.running = batch
                pf = inst._prefill_seconds(sum(r.prompt_len for r in batch))
                t_pf = t + pf
                for r in batch:
                    r.first_token = t_pf
            else:
                # continuous batching with admission control
                dev = cluster.device(inst.home)
                while (free_now and len(inst.running) < inst.batch_cap):
                    r = free_now[0]
                    new_kv = ((r.prompt_len + r.output_len)
                              * inst.kv_per_token * inst.kv_home_fraction()
                              / inst.span)
                    headroom = dev.mem_capacity * 0.96 - inst.mem_on_home()
                    if new_kv > headroom:
                        if sim.system == "vllm" and len(inst.running) == 0:
                            # cannot fit even alone -> genuine OOM drop
                            oom_events += 1
                            r.dropped = True
                            pending.remove(r)
                            free_now.pop(0)
                            dropped += 1
                            continue
                        break
                    pending.remove(r)
                    free_now.pop(0)
                    pf = inst._prefill_seconds(r.prompt_len)
                    r.first_token = t + pf
                    inst.running.append(r)
            # round-robin: one instance admits per pass, all get a chance

        # ---------------- one decode iteration per instance
        dt_candidates = []
        for inst in instances:
            if not inst.running or t < inst.stall_until:
                continue
            batch = len(inst.running)
            mean_ctx = np.mean([r.prompt_len + r.generated
                                for r in inst.running])
            it = inst._iter_seconds(batch, mean_ctx, batch)
            dt_candidates.append(it)
            for r in list(inst.running):
                if r.first_token > t:  # still prefilling
                    continue
                r.generated += 1
                if r.generated >= r.output_len:
                    r.finish = t + it
                    recent_lat.append(r.latency)
                    completed.append(r)
                    inst.running.remove(r)
        # advance time
        if dt_candidates:
            dt = max(min(dt_candidates), sim.tick_floor_s)
        elif pending:
            dt = max(min(r.arrival for r in pending) - t, sim.tick_floor_s)
        else:
            dt = sim.tick_floor_s
        t += dt

        # ---------------- memory accounting + monitor + controller
        for d in cluster.devices:
            base = sum(inst.mem_on_home() for inst in instances
                       if (d.device_id - inst.home) % sim.n_devices
                       < inst.span)
            repl = 0.0  # replica weights + migrated-in KV
            for inst in instances:
                for l, reps in inst.plan.replicas.items():
                    repl += reps.count(d.device_id) * inst.layer_bytes
                for (l, comp), dv in inst.plan.migrated.items():
                    if dv == d.device_id and comp == "kv_cache":
                        repl += (inst.kv_bytes_running()
                                 / max(inst.cfg.num_layers, 1))
            d.used_mem = base + repl
            peak_mem[d.device_id] = max(peak_mem[d.device_id], d.used_mem)
            d.util_compute = min(1.0, sum(
                len(inst.running) / inst.batch_cap for inst in instances
                if inst.home == d.device_id))

        if t >= next_ctrl:
            next_ctrl += sim.controller_period_s
            window = recent_lat[-64:]
            viol = (np.mean([1.0 if l > sim.slo_latency_s else 0.0
                             for l in window]) if window else 0.0)
            for i, inst in enumerate(instances):
                dev = cluster.device(inst.home)
                monitors[i].record(MetricsSnapshot(
                    t=t, rps=wl.rps,
                    p50_latency=_percentile(window, 50),
                    p95_latency=_percentile(window, 95),
                    slo_violation_rate=float(viol),
                    oom_events=0,
                    queue_len=len(pending),
                    device_util=[d.util_compute for d in cluster.devices],
                    device_mem_frac=[d.used_mem / d.mem_capacity
                                     for d in cluster.devices]))
                ctrl = controllers[i]
                if ctrl is not None:
                    ctrl.plan = inst.plan
                    action = ctrl.tick()
                    if action:
                        inst.plan = ctrl.plan
                        inst.batch_cap = min(inst.batch_cap,
                                             max(ctrl.batch_size, 1))
                        ctrl_log.append(f"t={t:.2f} inst{i} {action}")

    return SimResult(completed=completed, dropped=dropped,
                     oom_events=oom_events, sim_time=max(t, 1e-9),
                     controller_log=ctrl_log, peak_mem_per_device=peak_mem)
