"""DENSE KV-cache utilities: capacity planning, byte accounting, slot
updates for the Engine's dense fallback path.

The cache pytrees themselves come from ``models.transformer.init_cache``;
this module adds the serving-level bookkeeping: how big a cache is (the
quantity CoCoServe's migration/scale-down reasons about), ring-buffer
capacity for sliding-window archs, and per-slot insertion of a freshly
prefilled request into a batched cache (continuous batching).

The PRIMARY decode path is the paged block pool (serving/paged_kv.py +
``Engine(cache_kind="paged")``); ``insert_request``/``evict_request``
below only serve the dense ``[B, max_len]`` cache that sliding-window,
MLA, SSM, hybrid and audio families still decode against. The byte
accounting (``kv_bytes_per_token``, ``state_bytes``) is layout-agnostic
and used by both paths.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def cache_capacity(cfg: ModelConfig, logical_len: int, *, swa: bool = False):
    """Rows to allocate per request: full length, or the ring window."""
    if swa and cfg.sliding_window:
        return min(logical_len, cfg.sliding_window)
    return logical_len


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Per-token per-request KV bytes across all layers (Table 1 analysis)."""
    if cfg.family == "ssm":
        return 0  # O(1) state, no per-token growth
    hd = cfg.resolved_head_dim
    if cfg.attention_kind == "mla":
        per_layer = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        per_layer = 2 * cfg.num_kv_heads * hd
    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.hybrid_attn_every
        return nb * 2 * cfg.num_kv_heads * hd * dtype_bytes
    n = cfg.num_layers
    return n * per_layer * dtype_bytes


def state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """O(1) recurrent-state bytes per request (SSM/hybrid archs)."""
    if cfg.ssm_state == 0:
        return 0
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * N
    per_layer = (cfg.ssm_conv_dim - 1) * ch + cfg.ssm_heads * P * N
    return cfg.num_layers * per_layer * dtype_bytes


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))


def insert_request(cache, slot: int, request_cache, length: int):
    """Insert a single-request (batch=1) prefilled cache into batch ``slot``.

    Both caches must come from the same (cfg, max_len). Batched leaves have
    the batch at axis 1 for stacked layers ([L,B,...]) and axis 0 for the
    top-level fields ([B,...]); we detect by matching against the request
    leaf's shape.
    """
    def put(dst, src):
        # batch axis = first axis where src has size 1 and all other dims
        # line up. (With max_batch == 1 shapes are equal and the first
        # size-1 axis wins — the whole cache belongs to slot 0, so a full
        # overwrite is correct.)
        for ax in range(dst.ndim):
            if src.shape[ax] == 1 and \
                    dst.shape[:ax] == src.shape[:ax] and \
                    dst.shape[ax + 1:] == src.shape[ax + 1:]:
                idx = [slice(None)] * dst.ndim
                idx[ax] = slice(slot, slot + 1)
                return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        raise ValueError(f"cannot align {src.shape} into {dst.shape}")

    new = jax.tree_util.tree_map(put, cache, request_cache)
    new["length"] = cache["length"].at[slot].set(length)
    if "positions" in cache:
        new["positions"] = cache["positions"].at[slot].set(
            request_cache["positions"][0])
    return new


def evict_request(cache, slot: int):
    """Reset a slot (request finished): zero length, re-poison positions."""
    new = dict(cache)
    new["length"] = cache["length"].at[slot].set(0)
    if "positions" in cache:
        new["positions"] = cache["positions"].at[slot].set(T.BIG_POS)
    return new
