"""Multi-process serving instances: a real paged ``Engine`` in a child
process behind an engine-server loop, driven through ``EngineProxy``.

Topology (one proxy <-> one server, one AF_UNIX stream each)::

    orchestrator process                      engine-server process
    ────────────────────                      ─────────────────────
    EngineProxy ──frames──▶ transport.serve ──▶ Engine(cache_kind=paged)
       │  submit/step/apply_plan/pause/...          │ real JAX execution
       ◀── step reply: finished Requests,  ◀────────┘
           serialized EngineTelemetry, gauge dict

The child is SPAWNED (never forked — JAX runtimes do not survive a
fork), connects back to the parent's rendezvous socket, receives one
``init`` frame ({cfg, params as a host-array tree, engine kwargs}),
builds the engine, and enters the dispatch loop. Everything after init
is msgpack frames: admissions, telemetry, controller plans (replication
degree lists), and the column-keyed block payloads of
``paged_kv.export_blocks`` — the same wire format the in-process path
uses, now actually crossing a process boundary. No shared memory, no
fork-inherited state: what the frames carry is ALL the two sides share,
which is exactly the multi-host contract.

Three rendezvous modes share that contract (the frames are identical;
only who dials whom differs):

* **spawned, child dials back** (the PR-4 default): the parent listens
  on a fresh rendezvous socket — AF_UNIX normally, loopback TCP when
  ``REPRO_RPC_TRANSPORT=tcp`` — and the spawned child connects back.
* **spawned, child listens** (``endpoint="tcp://host:port"``): the
  child binds the endpoint and the parent connects with retry/backoff
  (a just-spawned server that hasn't bound yet looks like connection
  refused). This is how launch/pod.py runs local inventory nodes.
* **attached** (``endpoint=..., spawn=False``): the engine server is
  already running on ANOTHER HOST (``python -m repro.launch.pod
  --serve tcp://0.0.0.0:PORT``); the proxy only connects. There is no
  child process to join — liveness is purely the transport's.

Liveness: the proxy keeps a ``pristine`` clone of every request the
server currently holds (``inflight_requests``). When the server dies —
crash, OOM kill, host loss, or the test-only ``crash`` op — the next
RPC (or the orchestrator's batched poll) raises ``TransportClosed`` and
the orchestrator re-queues those clones on a surviving instance;
counter-based sampling keys replay them token-identically, so a worker
loss costs recompute, never output.

"""
from __future__ import annotations

import multiprocessing as mp
import os
from typing import Dict, List, Optional

from repro.serving import instrument as INS
from repro.serving import observe as OBS
from repro.serving import transport as TR
from repro.serving.instance import InstanceHandle, pristine
from repro.serving.request import RequestSpec
from repro.serving.instrument import EngineTelemetry
from repro.serving.engine import Request


# ============================================================ server side
class EngineServer:
    """Dispatch table around one Engine (runs in the child process)."""

    def __init__(self, engine):
        self.engine = engine
        self.telemetry = EngineTelemetry()
        self.recorder = None   # lazy observe.EngineSpanRecorder

    # ---- serving ops
    def submit(self, spec: RequestSpec):
        self.engine.submit(spec)
        return len(self.engine.queue)

    def set_token_budget(self, budget: int) -> int:
        return self.engine.set_token_budget(int(budget))

    def step(self):
        done = INS.timed_step(self.engine, self.telemetry)
        out = {"finished": done, "telemetry": self.telemetry.to_state(),
               "info": self.info(),
               # full per-stream token lists each step (tiny at decode
               # rates; idempotent under migration/replay) — the ingress
               # streaming feed rides the reply, no extra RPC
               "streams": {int(r): list(t) for r, t
                           in self.engine.stream_progress().items()}}
        if self.recorder is not None:
            # spans ship home piggybacked like telemetry; stamped with
            # THIS process's clock — the proxy skew-corrects on ingest
            out["spans"] = self.recorder.drain()
        return out

    # ---- tracing
    def _on_trace(self, ctx: dict):
        """Trace context piggybacked on an RPC frame (transport.serve
        delivers it before the op runs): install the span recorder on
        first use, register rid -> trace id so the engine's lifecycle
        hooks start recording for this request."""
        if self.recorder is None:
            self.recorder = OBS.EngineSpanRecorder(
                origin=f"server:{os.getpid()}")
            self.engine.span_hook = self.recorder
        self.recorder.register(int(ctx["rid"]), ctx["trace_id"])

    def trace_register(self, ctx: dict):
        """Explicit registration op — migration/replay continuations
        arrive via resume/commit payloads, not a traced submit frame."""
        self._on_trace(ctx)
        return True

    def clock_sync(self) -> float:
        """This process's span clock, for the proxy's RTT-midpoint
        offset estimation (observe.estimate_clock_offset)."""
        return OBS.server_now()

    def apply_plan(self, p: List[int]):
        self.engine.apply_plan(list(p))
        return True

    def requeue_front(self, req: Request):
        self.engine.queue.appendleft(req)
        return len(self.engine.queue)

    def push_queue(self, req: Request):
        self.engine.queue.append(req)
        return len(self.engine.queue)

    def drain_queue(self):
        out = []
        while self.engine.queue:
            out.append(self.engine.queue.popleft())
        return out

    # ---- telemetry
    def info(self) -> dict:
        e = self.engine
        return {"clock": e.clock,
                "queue_len": len(e.queue),
                "active": {int(s): int(r) for s, r in e.slot_rids().items()},
                "free_blocks": e.pstate.free_block_count(),
                "blocks_in_use": e.pstate.blocks_in_use(),
                "n_blocks": e.pstate.n_blocks,
                "max_batch": e.max_batch,
                "pool_bytes": e.pstate.pool_bytes(),
                "preempt_count": e.preempt_count,
                "prefix_stats": e.prefix_stats(),
                "block_size": e.block_size,
                # sorted list (sets aren't msgpack-able); the proxy
                # rebuilds the set on read
                "prefix_keys": sorted(e.prefix_keys())}

    # ---- migration (each blocks until device state is real — the reply
    # frame doubles as the transfer-complete barrier — and piggybacks
    # the gauge dict so the proxy's cache stays fresh without a second
    # round trip inside the migration stall window)
    def _sync(self):
        import jax
        jax.block_until_ready((self.engine.pstate.k, self.engine.pstate.v))

    def _reply(self, result):
        """Migration reply envelope: the gauge dict, plus any spans the
        op itself closed (a pause closes the victim's decode span —
        shipping it HERE instead of on the next step reply means the
        trace can finish before this server ever steps again)."""
        out = {"result": result, "info": self.info()}
        if self.recorder is not None:
            spans = self.recorder.drain()
            if spans:
                out["spans"] = spans
        return out

    def pause_request(self, slot: int, since_epoch=None):
        payload = self.engine.pause_request(slot, since_epoch=since_epoch)
        return self._reply(payload)

    def resume_request(self, payload: dict):
        ok = self.engine.resume_request(payload)
        self._sync()
        return self._reply(ok)

    def snapshot_request(self, slot: int):
        return self.engine.snapshot_request(slot)

    def prepare_resume(self, snap: dict):
        slot = self.engine.prepare_resume(snap)
        self._sync()
        return self._reply(slot)

    def commit_resume(self, slot: int, payload: dict):
        ok = self.engine.commit_resume(slot, payload)
        self._sync()
        return self._reply(ok)

    def abort_resume(self, slot: int):
        self.engine.abort_resume(slot)
        return self._reply(True)

    # ---- liveness
    def ping(self):
        return "pong"

    def heartbeat(self):
        """The hung-vs-dead probe payload: cheap, never touches the
        device — a worker stalled in a long device op still answers
        once the in-order queue reaches it, a blackholed one never
        does. Returns enough identity for the orchestrator to log."""
        return {"clock": self.engine.clock,
                "queue_len": len(self.engine.queue),
                "pid": os.getpid()}

    def crash(self):
        """Test-only fault injection: die without a word — the parent's
        next recv sees EOF, exactly like a kill -9 / OOM kill."""
        os._exit(17)

    def dispatch(self) -> dict:
        d = {op: getattr(self, op) for op in (
            "submit", "set_token_budget", "step", "apply_plan",
            "requeue_front", "push_queue",
            "drain_queue", "info", "pause_request", "resume_request",
            "snapshot_request", "prepare_resume", "commit_resume",
            "abort_resume", "ping", "heartbeat", "crash",
            "trace_register", "clock_sync")}
        # not a wire op: transport.serve's hook for trace contexts
        # piggybacked on ordinary frames
        d["_on_trace"] = self._on_trace
        return d


def _serve_connection(conn: "TR.Connection"):
    """Shared tail of both server entries: build the engine from the
    orchestrator's init frame, ack ready, serve until shutdown/hangup."""
    init = conn.recv()
    from repro.serving.engine import Engine  # import after spawn, in-child
    engine = Engine(init["cfg"], init["params"], **init["engine_kw"])
    server = EngineServer(engine)
    conn.send({"id": 0, "ok": True, "result": "ready"})
    TR.serve(conn, server.dispatch())
    conn.close()


def engine_server_main(address: str):
    """Child-process entry, dial-back mode: connect to the parent's
    rendezvous listener (AF_UNIX path or ``tcp://host:port``), then
    serve."""
    _serve_connection(TR.connect(address))


def engine_server_listen(address: str):
    """Engine-server entry, listening mode: bind ``address`` (normally
    ``tcp://host:port`` — the multi-host deployment unit), accept ONE
    orchestrator, serve it, exit. Run standalone on a pod node via
    ``python -m repro.launch.pod --serve tcp://0.0.0.0:PORT``."""
    srv = TR.listen(address)
    try:
        conn = TR.accept(srv, timeout=None)
    finally:
        srv.close()
    _serve_connection(conn)


# ============================================================= proxy side
class _PendingStage:
    """Pipelined prepare_resume: unwraps the piggybacked gauge dict on
    completion and maps a dead peer to TransportClosed."""

    def __init__(self, proxy: "EngineProxy", pending: TR.Pending):
        self._proxy = proxy
        self._pending = pending

    def wait(self):
        try:
            return self._proxy._unwrap(self._pending.wait())
        except TR.TransportClosed:
            self._proxy._dead = True
            raise


def rendezvous_transport() -> str:
    """Transport family for spawned proxies with no explicit endpoint:
    ``REPRO_RPC_TRANSPORT=tcp`` lifts the whole plane onto loopback TCP
    (frames identical; the tier-2 suite runs unchanged under it),
    anything else keeps the AF_UNIX default."""
    return os.environ.get("REPRO_RPC_TRANSPORT", "unix").lower()


class EngineProxy(InstanceHandle):
    """The orchestrator-side handle of a remote engine: mirrors the
    in-process ``Engine`` control surface over RPC frames. Gauges
    (queue depth, pool vacancy, clock, prefix stats) read a cache
    refreshed by every step reply — one RPC round trip per orchestrator
    step in steady state, and the step reply itself is drained through
    the orchestrator's batched poll (``step_async`` + ``finish_step``),
    so N instances cost one multiplexed wait, not N sequential ones."""

    def __init__(self, cfg, params, *, start_timeout: float = 120.0,
                 endpoint: Optional[str] = None, spawn: bool = True,
                 adopt_process=None, peer_label: Optional[str] = None,
                 **engine_kw):
        self.telemetry = EngineTelemetry()
        self._inflight: Dict[int, Request] = {}   # rid -> pristine clone
        self._streams: Dict[int, List[int]] = {}  # last step's stream feed
        self._span_feed: List[dict] = []   # skew-corrected server spans
        self.clock_offset = 0.0            # server clock - ours (est.)
        self._dead = False
        self.process = None
        self.endpoint = endpoint
        self.peer_label = peer_label
        # everything respawn() needs to bring up a fresh replacement
        self._spec = {"cfg": cfg, "params": params,
                      "start_timeout": start_timeout,
                      "engine_kw": dict(engine_kw)}
        self._listen_mode = endpoint is not None
        # supervised respawn can recreate any server WE own a process
        # for (spawned either way, or adopted from the pod launcher);
        # an attached server on another host is that host's to restart
        self._respawnable = (endpoint is None or spawn
                             or adopt_process is not None)
        self._generation = 0
        try:
            self._start(cfg, params, start_timeout, endpoint, spawn,
                        adopt_process, engine_kw)
        except BaseException:
            # never leak a spawned engine server: a failed rendezvous /
            # init handshake reaps the child before propagating
            if self.process is not None and self.process.is_alive():
                self.process.kill()
                self.process.join(timeout=10)
            raise

    def _start(self, cfg, params, start_timeout, endpoint, spawn,
               adopt_process, engine_kw):
        import jax
        import numpy as np

        ctx = mp.get_context("spawn")     # never fork a live JAX runtime
        if endpoint is None:
            # dial-back rendezvous: parent listens, spawned child connects
            if rendezvous_transport() == "tcp":
                srv = TR.listen("tcp://127.0.0.1:0")
                address = TR.bound_endpoint(srv)
            else:
                address = TR.listener_address()
                srv = TR.listen(address)
            self.endpoint = address
            self.process = ctx.Process(target=engine_server_main,
                                       args=(address,), daemon=True)
            self.process.start()
            try:
                self.conn = TR.accept(srv, timeout=start_timeout)
            finally:
                srv.close()
                if TR.parse_endpoint(address)[0] == "unix":
                    try:
                        os.unlink(address)
                    except OSError:
                        pass
        else:
            # listening server at a known endpoint: spawn it locally
            # (pod inventory node on this host), adopt one the pod
            # launcher already spawned (so liveness/kill still see the
            # child), or attach to a server running on another host;
            # either way the proxy dials in, retrying while the server
            # boots toward its bind
            if spawn:
                self.process = ctx.Process(target=engine_server_listen,
                                           args=(endpoint,), daemon=True)
                self.process.start()
            elif adopt_process is not None:
                self.process = adopt_process

            def child_died() -> Optional[str]:
                # a spawned server that died before binding (EADDRINUSE
                # on a colliding inventory port, import failure) would
                # otherwise look like "still booting" for the whole
                # connect deadline
                if self.process is not None and not self.process.is_alive():
                    return (f"engine server exited with code "
                            f"{self.process.exitcode} before accepting")
                return None

            self.conn = TR.connect(endpoint, timeout=start_timeout,
                                   abort=child_died)
        self.conn.peer_label = self.peer_label
        self.rpc = TR.Rpc(self.conn)
        host_params = jax.tree_util.tree_map(np.asarray, params)
        self.conn.send({"cfg": cfg, "params": host_params,
                        "engine_kw": dict(engine_kw,
                                          cache_kind="paged")})
        ready = self.conn.recv()          # init ack doubles as ready gate
        assert ready.get("result") == "ready", ready
        self._info = self._call("info")
        # estimate the server's span-clock offset from a few cheap round
        # trips while the connection is otherwise idle — a respawned
        # server gets a fresh proxy, hence a fresh estimate
        self.clock_offset = OBS.estimate_clock_offset(
            lambda: self._call("clock_sync"))

    # ------------------------------------------------------------- rpc
    def _call(self, op, *args, **kw):
        if self._dead:
            raise TR.TransportClosed(f"instance already dead ({op})")
        try:
            return self.rpc.call(op, *args, **kw)
        except TR.TransportClosed:
            self._dead = True
            raise

    # ------------------------------------------------------ serving ops
    # Queue/migration mutations refresh the cached gauge dict (the queue
    # ops piggyback the server's returned depth; migration ops re-pull
    # info — they are rare, the extra round trip is noise), so routing
    # and run-until-done loops never act on a stale zero.
    def submit(self, spec: RequestSpec, trace: Optional[dict] = None):
        # the mirror holds the minted-but-never-run Request: pristine by
        # construction, replayable token-identically after a crash
        self._inflight[spec.rid] = spec.to_request()
        self._info["queue_len"] = self._call("submit", spec, _trace=trace)

    def set_token_budget(self, budget: int) -> int:
        return int(self._call("set_token_budget", int(budget)))

    def step(self) -> List[Request]:
        return self.finish_step(self._call("step"))

    def step_async(self) -> TR.Pending:
        """Fan-out half of the batched control-plane poll: send the step
        request without waiting. The orchestrator drains the reply via
        ``transport.drain_pendings`` and hands it to ``finish_step``."""
        if self._dead:
            raise TR.TransportClosed("instance already dead (step)")
        try:
            return self.rpc.call_async("step")
        except TR.TransportClosed:
            self._dead = True
            raise

    def finish_step(self, reply: dict) -> List[Request]:
        """Apply one step reply: refresh the telemetry mirror and gauge
        cache, retire finished requests from the inflight mirror."""
        self.telemetry.load_state(reply["telemetry"])
        self._info = reply["info"]
        self._streams = {int(r): list(t) for r, t
                         in reply.get("streams", {}).items()}
        spans = reply.get("spans")
        if spans:
            self._span_feed.extend(
                OBS.correct_spans(spans, self.clock_offset))
        done = reply["finished"]
        for r in done:
            self._inflight.pop(r.rid, None)
        return done

    # ---------------------------------------------------------- tracing
    def register_trace(self, ctx: dict):
        self._call("trace_register", ctx)

    def drain_spans(self) -> List[dict]:
        if not self._span_feed:
            return []
        out, self._span_feed = self._span_feed, []
        return out

    def apply_plan(self, p):
        p = list(p.p) if hasattr(p, "p") else list(p)
        self._call("apply_plan", p)

    def requeue_front(self, req: Request):
        self._inflight[req.rid] = pristine(req)
        self._info["queue_len"] = self._call("requeue_front", req)

    def push_queue(self, req: Request):
        self._inflight[req.rid] = pristine(req)
        self._info["queue_len"] = self._call("push_queue", req)

    def drain_queue(self) -> List[Request]:
        out = self._call("drain_queue")
        for r in out:
            self._inflight.pop(r.rid, None)
        self._info["queue_len"] = 0
        return out

    # -------------------------------------------------------- telemetry
    def refresh_info(self):
        self._info = self._call("info")

    def queue_len(self) -> int:
        return self._info["queue_len"]

    def active_rids(self) -> Dict[int, int]:
        return {int(s): rid for s, rid in self._info["active"].items()}

    def free_blocks(self) -> int:
        return self._info["free_blocks"]

    def blocks_in_use(self) -> int:
        return self._info["blocks_in_use"]

    @property
    def n_blocks(self) -> int:
        return self._info["n_blocks"]

    @property
    def max_batch(self) -> int:
        return self._info["max_batch"]

    def pool_bytes(self) -> int:
        return self._info["pool_bytes"]

    def clock(self) -> float:
        return self._info["clock"]

    def preempt_count(self) -> int:
        return self._info["preempt_count"]

    def prefix_stats(self) -> dict:
        return self._info["prefix_stats"]

    @property
    def block_size(self) -> int:
        return self._info.get("block_size", 0)

    def prefix_keys(self) -> set:
        return set(self._info.get("prefix_keys", ()))

    def stream_view(self) -> Dict[int, List[int]]:
        return self._streams

    # -------------------------------------------------------- migration
    def _unwrap(self, reply: dict):
        """Migration replies piggyback the server's gauge dict (and any
        spans the op closed — skew-corrected into the feed like the
        step-reply ones)."""
        self._info = reply["info"]
        spans = reply.get("spans")
        if spans:
            self._span_feed.extend(
                OBS.correct_spans(spans, self.clock_offset))
        return reply["result"]

    def pause_request(self, slot: int,
                      since_epoch: Optional[int] = None) -> dict:
        payload = self._unwrap(self._call("pause_request", slot,
                                          since_epoch=since_epoch))
        self._inflight.pop(payload["request"].rid, None)
        return payload

    def resume_request(self, payload: dict) -> bool:
        ok = self._unwrap(self._call("resume_request", payload))
        if ok:
            self._inflight[payload["request"].rid] = \
                pristine(payload["request"])
        return ok

    def snapshot_request(self, slot: int) -> dict:
        return self._call("snapshot_request", slot)

    def prepare_resume_async(self, snap: dict) -> "_PendingStage":
        if self._dead:
            raise TR.TransportClosed("instance already dead "
                                     "(prepare_resume)")
        return _PendingStage(self, self.rpc.call_async("prepare_resume",
                                                       snap))

    def commit_resume(self, slot: int, payload: dict) -> bool:
        ok = self._unwrap(self._call("commit_resume", slot, payload))
        if ok:
            self._inflight[payload["request"].rid] = \
                pristine(payload["request"])
        return ok

    def abort_resume(self, slot: int):
        self._unwrap(self._call("abort_resume", slot))

    # --------------------------------------------------------- liveness
    def set_rpc_deadline(self, seconds: Optional[float]):
        """Stamp a per-call deadline on every future RPC (None
        disables). A missed deadline raises ``RpcTimeout`` / resolves
        to a ``hung`` poll entry instead of stalling the caller."""
        self.rpc.call_timeout = seconds

    def probe(self, timeout: float = 1.0) -> str:
        """Classify this peer after a missed deadline:

        * ``"dead"``  — process exited or transport closed;
        * ``"alive"`` — heartbeat answered within ``timeout``: the peer
          is merely slow, or the lost call's request frame was dropped
          (in-order serving means a heartbeat answered after a call was
          sent proves that call either already replied or never
          arrived);
        * ``"hung"``  — socket open, heartbeat unanswered: blackholed /
          half-open / livelocked — quarantine territory.
        """
        if self._dead:
            return "dead"
        if self.process is not None and not self.process.is_alive():
            self._dead = True
            return "dead"
        try:
            self.rpc.call_timed("heartbeat", timeout)
            return "alive"
        except TR.RpcTimeout:
            return "hung"
        except TR.TransportClosed:
            self._dead = True
            return "dead"

    def quarantine(self):
        """Take a hung peer out of the plane for good: close the
        transport (a merely-slow server's dispatch loop exits on the
        EOF) and hard-kill an owned process — a quarantined worker must
        never act again, so the idempotent replay of its inflight
        mirror cannot race a zombie's late writes. Safe on an
        already-dead peer (idempotent)."""
        self._dead = True
        self.conn.close()
        if self.process is not None and self.process.is_alive():
            self.process.kill()
            self.process.join(timeout=10)

    @property
    def respawnable(self) -> bool:
        return self._respawnable

    def respawn(self, start_timeout: Optional[float] = None
                ) -> "EngineProxy":
        """Bring up a FRESH engine server from this proxy's init spec —
        the supervised-restart half of the failure domain. Listening
        servers respawn at the same endpoint; dial-back children get a
        new rendezvous. The replacement starts empty (queue and KV are
        gone with the process — the orchestrator already replayed the
        inflight mirror elsewhere) and carries an incarnation-suffixed
        peer label (``w1`` -> ``w1~r1``) so a static FaultPlan never
        re-targets the replacement of a peer it already faulted."""
        if not self._respawnable:
            raise RuntimeError(
                f"instance at {self.endpoint!r} is attach-only: its "
                "server is not ours to restart")
        spec = self._spec
        base = (self.peer_label.split("~", 1)[0]
                if self.peer_label else None)
        label = f"{base}~r{self._generation + 1}" if base else None
        fresh = EngineProxy(
            spec["cfg"], spec["params"],
            start_timeout=(spec["start_timeout"] if start_timeout is None
                           else start_timeout),
            endpoint=self.endpoint if self._listen_mode else None,
            spawn=True, peer_label=label, **spec["engine_kw"])
        fresh._generation = self._generation + 1
        fresh.set_rpc_deadline(self.rpc.call_timeout)
        return fresh

    def alive(self) -> bool:
        if self._dead:
            return False
        # attached servers (no child to watch) are alive until the
        # transport says otherwise
        return self.process is None or self.process.is_alive()

    def mark_dead(self):
        """Record a transport death observed OUTSIDE ``_call`` — e.g. a
        ``closed`` entry from the orchestrator's batched poll."""
        self._dead = True

    def inflight_requests(self) -> List[Request]:
        return list(self._inflight.values())

    def kill(self):
        """Hard-kill the server (crash-recovery tests): SIGKILL for a
        spawned child, abrupt socket close for an attached one — either
        way the next RPC observes TransportClosed."""
        if self.process is not None:
            self.process.kill()
            self.process.join(timeout=10)
        else:
            self.conn.close()
            self._dead = True

    def inject_crash(self):
        """Ask the server to os._exit mid-protocol (fault injection)."""
        try:
            self.rpc.call_async("crash")    # no reply will ever come
        except TR.TransportClosed:
            pass
        if self.process is not None:
            self.process.join(timeout=10)

    def close(self):
        if not self._dead and (self.process is None
                               or self.process.is_alive()):
            try:
                self.rpc.call("shutdown")
            except TR.TransportError:
                pass
        self._dead = True
        if self.process is not None:
            self.process.join(timeout=10)
            if self.process.is_alive():   # pragma: no cover - stuck child
                self.process.terminate()
                self.process.join(timeout=5)
        self.rpc.close()
