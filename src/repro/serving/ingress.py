"""Serving front door: a dependency-free async HTTP/1.1 ingress over
the orchestrator (DESIGN.md §11).

The plane's first client-facing surface, in the repo's no-framework
transport style: stdlib ``asyncio`` streams and hand-rolled HTTP/1.1 —
request line + headers parsed directly, responses with explicit
``Content-Length`` or ``Transfer-Encoding: chunked`` — the same way
serving/transport.py hand-rolls its RPC frames. Endpoints:

* ``POST /v1/completions`` — the de-facto standard completion API.
  Body: ``{"prompt": [token ids] | "text", "max_tokens", "temperature",
  "top_k", "seed", "stream", "slo_class", "deadline_ms"}``, parsed
  straight into a ``serving.request.RequestSpec`` — the one request
  shape the whole stack speaks. Bad bodies get a TYPED 400 taxonomy:
  unknown top-level keys, an unknown ``slo_class`` and a non-positive
  ``deadline_ms`` each answer with a distinct machine-readable
  ``error`` code (``unknown_fields`` / ``unknown_slo_class`` /
  ``bad_deadline``) so clients can tell a typo from a bad value without
  string-matching free text. With ``stream: true`` the response is
  chunked SSE: one ``data: {"token": t, "index": n}`` event per token,
  flushed AS THE STEP LOOP EMITS IT (not after completion), terminated
  by ``data: [DONE]``. Without, one JSON body after the request
  finishes. String prompts are mapped by a deterministic byte-level
  stand-in tokenizer (``2 + byte % (vocab-2)``) — the repo serves
  randomly initialized reference models, so a real BPE vocabulary would
  add a dependency without adding meaning; token-id prompts are the
  precise interface.
* ``GET /v1/models`` — the served model's identity.
* ``GET /healthz`` — liveness + pod size (the probe surface).
* ``GET /stats`` — the orchestrator's ``MetricsSnapshot`` plus the
  ingress's own ``IngressCounters`` (routing/backpressure ledger).
* ``GET /metrics`` — Prometheus text exposition (serving/observe.py's
  in-repo registry, no client library): request/429/token counters,
  fleet gauges (tok/s, budget utilization, prefix hit rate, pod size),
  per-instance queue depth / vacancy / TTFT / ITL histograms,
  per-SLO-class TTFT/ITL histograms (``slo_class`` label), the
  in-force per-instance token budget, fault counters. Rendered from an
  IMMUTABLE mirror the pump thread rebuilds
  next to ``last_snapshot`` — a scrape never touches the orchestrator.
* ``GET /debug/flightrec`` — the orchestrator's flight-recorder ring
  (controller votes with inputs, migrations with phase timings,
  quarantines/respawns, routing verdicts), newest last.

**Tracing**: every accepted completion opens a trace
(serving/observe.py); its id returns as ``X-Request-Id`` (unary header
/ SSE head). The HTTP thread records accept + route spans; engine-side
spans ride the step replies and the orchestrator closes the tree when
the request finishes, exporting JSONL when ``trace_out`` is set.

**Threading model** — the one invariant everything below serves:
``transport.Rpc`` is NOT thread-safe, so exactly ONE thread (the
**pump**) ever touches the orchestrator's serving ops. The asyncio
event loop runs in its own thread and only (a) parses HTTP, (b) routes
admissions through ``Orchestrator.route`` — which reads nothing but
CACHED gauges (an EngineProxy's ``_info`` mirror), never the wire — and
(c) awaits per-request ``asyncio.Queue``s. The pump drains the
submission queue into ``submit_to``, steps the orchestrator while any
instance has work, and pushes token events into those queues via
``loop.call_soon_threadsafe`` — tokens cross the thread boundary, RPCs
never do. Elasticity rides for free: the pump's ``step()`` runs the
orchestrator's control ticks, so pod grow/shrink happens on the same
thread that owns the instances.

**Budget governor**: the pump also runs the adaptive half of the
SLO loop (DESIGN.md §13). ``BudgetGovernor`` periodically reads each
instance's EXISTING telemetry windows — ``budget_utilization``,
engine-clock TTFT p95 and queue-delay p95 — and retargets that
instance's per-step token budget through
``InstanceHandle.set_token_budget``: grow when the step loop is
saturated AND requests are queueing (more prefill tokens pack per
step), shrink when the budget is mostly idle (a smaller budget
tightens per-step latency). Multiplicative steps with a clamp; every
change lands in the flight recorder as a ``budget_governor`` event.

**Admission backpressure**: the router only considers instances whose
queue — including requests accepted here but not yet pumped
(``_pending``) — is under the orchestrator's ``max_queue``. When none
qualifies the ingress answers ``429`` with ``Retry-After`` instead of
queueing unboundedly; load sheds at the door, not as pool OOM.

**Graceful shutdown**: ``close()`` stops intake (503), sends every open
stream a ``data: {"error": "shutting down"}`` event followed by the
proper zero-length chunk terminator (clients see a well-formed HTTP
tail, not a reset), then stops the pump and joins both threads.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
import queue
import threading
import time
from typing import Dict, Optional

import numpy as np

from repro.serving import observe as OBS
from repro.serving.engine import Request
from repro.serving.instrument import IngressCounters
from repro.serving.request import RequestSpec, SamplingParams, SpecError


def byte_tokens(text: str, vocab_size: int) -> np.ndarray:
    """Deterministic byte-level stand-in tokenizer (module docstring):
    identical text -> identical token ids -> identical content-chain
    keys, so string-prompt clients still exercise prefix affinity."""
    span = max(vocab_size - 2, 1)
    return np.asarray([2 + b % span for b in text.encode("utf-8")],
                      np.int32)


class _BadRequest(Exception):
    """Malformed HTTP or JSON — answered with 400. ``body``, when set,
    is the exact JSON error body (the typed taxonomy: unknown fields /
    unknown slo_class / bad deadline); None means the responder's
    generic 400 body."""

    def __init__(self, body: Optional[dict] = None):
        super().__init__((body or {}).get("error", "bad request"))
        self.body = body


class BudgetGovernor:
    """The adaptive token-budget loop (module docstring, DESIGN.md §13).

    Ticked from the pump thread — ``set_token_budget`` is a serving op
    (an RPC on remote instances) and may only run there. Control law:

    * **grow** (``x grow``) when the window says the step loop is
      saturated (``budget_utilization >= high_util``) AND requests are
      actually waiting (queue-delay or TTFT p95 at or above
      ``delay_steps`` engine steps) — a bigger budget packs more
      prefill chunk tokens per step, draining the queue;
    * **shrink** (``x shrink``) when the budget mostly rides empty
      (``utilization <= low_util``) — a smaller budget tightens
      per-step wall time, which is ITL for every active stream.

    Multiplicative moves bounded to [min_budget, max_budget]; the
    engine echoes the budget IN FORCE (phase engines echo 0 and are
    skipped via their empty ``packed_tokens`` window)."""

    def __init__(self, orch, *, period_s: float = 0.5, grow: float = 1.5,
                 shrink: float = 0.75, high_util: float = 0.90,
                 low_util: float = 0.35, delay_steps: float = 4.0,
                 min_budget: int = 32, max_budget: int = 8192):
        self.orch = orch
        self.period_s = period_s
        self.grow = grow
        self.shrink = shrink
        self.high_util = high_util
        self.low_util = low_util
        self.delay_steps = delay_steps
        self.min_budget = min_budget
        self.max_budget = max_budget
        self.budgets: Dict[int, int] = {}   # instance -> in-force budget
        self.adjustments = 0                # lifetime changes applied
        self._t_last: Optional[float] = None

    def tick(self, now: float) -> bool:
        """One control decision per alive instance, at most once per
        ``period_s``. Returns True when a tick ran (tests key on it)."""
        if self._t_last is not None and now - self._t_last < self.period_s:
            return False
        self._t_last = now
        o = self.orch
        for i in o._alive():
            tel = o.telemetry[i]
            if not tel.budget or not tel.packed_tokens:
                continue                    # phase engine, or no data yet
            util = tel.budget_utilization()
            cur = self.budgets.get(i, tel.budget)
            delay = max(tel.queue_delay_quantile(0.95),
                        tel.ttft_quantile(0.95))
            if util >= self.high_util and delay >= self.delay_steps:
                new = min(int(cur * self.grow), self.max_budget)
            elif util <= self.low_util:
                new = max(int(cur * self.shrink), self.min_budget)
            else:
                new = cur
            if new == cur:
                continue
            in_force = o.instances[i].set_token_budget(new)
            self.budgets[i] = in_force
            self.adjustments += 1
            o.flightrec.record(
                "budget_governor", instance=i, budget=in_force,
                prev=cur, utilization=round(util, 4),
                queue_delay_p95=round(delay, 3))
        return True


@dataclasses.dataclass
class _Session:
    """One in-flight completion: the bridge between the pump thread
    (producer) and the handler coroutine (consumer)."""
    rid: int
    events: asyncio.Queue          # ("tok", t) | ("done", _) | ("abort", why)
    sent: int = 0                  # pump-side high-water mark into the stream


class Ingress:
    """The HTTP front door over one Orchestrator (module docstring).

    The caller keeps ownership of the orchestrator but MUST stop
    driving it once ``start()`` runs — the pump thread owns every
    serving op until ``close()``.
    """

    def __init__(self, orch, *, host: str = "127.0.0.1", port: int = 0,
                 model_id: Optional[str] = None,
                 trace_out: Optional[str] = None,
                 govern_budget: bool = True):
        self.orch = orch
        # the adaptive token-budget loop (class docstring); govern_budget
        # False pins every instance's budget for identity-sensitive runs
        self.governor = BudgetGovernor(orch) if govern_budget else None
        self.host = host
        self.port = port                   # 0 -> ephemeral; real after start
        self.model_id = model_id or getattr(orch.cfg, "name", None) \
            or getattr(orch.cfg, "family", "model")
        self.counters = IngressCounters()
        self.last_snapshot = None          # refreshed by the pump
        # request tracing: adopt the orchestrator's tracer (a test may
        # have installed one) or own a fresh one; trace_out appends one
        # JSONL line per finished trace
        if orch.tracer is None:
            orch.tracer = OBS.Tracer(out_path=trace_out)
            self._own_tracer = True
        else:
            self._own_tracer = False
        self.tracer = orch.tracer
        self._metrics_mirror = None        # pump-built, swapped atomically
        self._rids = itertools.count(1)
        self._lock = threading.Lock()      # _pending + _sessions + _rids
        self._pending: Dict[int, int] = {}  # instance -> accepted, unpumped
        self._sessions: Dict[int, _Session] = {}
        self._submit_q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._closing = False
        # test hook: while set, the pump neither submits nor steps — the
        # deterministic way to hold queues full for 429 assertions
        self.hold_pump = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._started = threading.Event()
        self._http_thread: Optional[threading.Thread] = None
        self._pump_thread: Optional[threading.Thread] = None
        self._pump_error: Optional[BaseException] = None

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "Ingress":
        self._http_thread = threading.Thread(
            target=self._run_loop, name="ingress-http", daemon=True)
        self._http_thread.start()
        if not self._started.wait(timeout=30):
            raise RuntimeError("ingress failed to bind within 30s")
        self._pump_thread = threading.Thread(
            target=self._pump, name="ingress-pump", daemon=True)
        self._pump_thread.start()
        return self

    def close(self):
        """Graceful shutdown (module docstring): stop intake, abort open
        streams with a well-formed tail, stop the pump, join."""
        if self._loop is None:
            return
        self._closing = True
        try:
            fut = asyncio.run_coroutine_threadsafe(self._shutdown(),
                                                   self._loop)
            fut.result(timeout=10)
        except Exception:
            pass
        self._stop.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=10)
        self._loop.call_soon_threadsafe(self._loop.stop)
        if self._http_thread is not None:
            self._http_thread.join(timeout=10)
        if self._own_tracer:
            self.tracer.close()

    async def _shutdown(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        with self._lock:
            sessions = list(self._sessions.values())
            self._sessions.clear()
        for s in sessions:
            self.counters.aborted_streams += 1
            s.events.put_nowait(("abort", "shutting down"))
        await asyncio.sleep(0.05)          # let handlers flush their tails

    def _run_loop(self):
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop

        async def _bind():
            self._server = await asyncio.start_server(
                self._handle, self.host, self.port)
            self.port = self._server.sockets[0].getsockname()[1]
            self._started.set()

        loop.run_until_complete(_bind())
        try:
            loop.run_forever()
        finally:
            loop.close()

    # --------------------------------------------------------------- pump
    def _has_work(self) -> bool:
        o = self.orch
        return any(o.instances[i].queue_len() or o.instances[i].active_rids()
                   for i in o._alive())

    def _pump(self):
        """The ONLY thread that touches orchestrator serving ops."""
        o = self.orch
        self.last_snapshot = o.snapshot()
        self._metrics_mirror = self._build_mirror()
        t_snap = t_ctl = time.monotonic()
        try:
            while not self._stop.is_set():
                if self.hold_pump.is_set():
                    time.sleep(0.002)
                    continue
                moved = self._drain_submissions()
                if self._has_work():
                    for r in o.step():
                        self._finish(r)
                    self._push_streams()
                    moved = True
                now = time.monotonic()
                if self.governor is not None:
                    self.governor.tick(now)
                if now - t_snap > 0.2 or moved:
                    self.last_snapshot = o.snapshot()
                    # one plain-data mirror per refresh; /metrics (HTTP
                    # thread) renders whichever mirror it observes — it
                    # never reads handles or telemetry deques itself
                    self._metrics_mirror = self._build_mirror()
                    t_snap = now
                if not moved:
                    # step() carries the control ticks under load; while
                    # IDLE the loop must still tick so the idle-driven
                    # pod decision (shrink) can ever fire
                    if (o.pod_cfg is not None
                            and o.worker_factory is not None
                            and now - t_ctl > 0.25):
                        o.control_tick()
                        t_ctl = now
                    time.sleep(0.002)
        except BaseException as e:  # noqa: BLE001 — surface, don't vanish
            self._pump_error = e
            with self._lock:
                sessions = list(self._sessions.values())
                self._sessions.clear()
            for s in sessions:
                self._post(s, ("abort", f"pump failed: {e!r}"))
            raise

    def _drain_submissions(self) -> bool:
        moved = False
        while True:
            try:
                idx, spec = self._submit_q.get_nowait()
            except queue.Empty:
                return moved
            self.orch.submit_to(idx, spec)
            with self._lock:
                n = self._pending.get(idx, 0) - 1
                if n > 0:
                    self._pending[idx] = n
                else:
                    self._pending.pop(idx, None)
            moved = True

    def _post(self, sess: _Session, event):
        """Thread-safe event push into a session's asyncio queue."""
        self._loop.call_soon_threadsafe(sess.events.put_nowait, event)

    def _push_streams(self):
        for rid, toks in self.orch.stream_view().items():
            with self._lock:
                sess = self._sessions.get(rid)
            if sess is None or len(toks) <= sess.sent:
                continue
            for t in toks[sess.sent:]:
                self._post(sess, ("tok", int(t)))
            self.counters.tokens_out += len(toks) - sess.sent
            sess.sent = len(toks)

    def _finish(self, req: Request):
        with self._lock:
            sess = self._sessions.pop(req.rid, None)
        if sess is None:
            return
        toks = list(req.generated)
        for t in toks[sess.sent:]:          # final flush past the mark
            self._post(sess, ("tok", int(t)))
        self.counters.tokens_out += max(0, len(toks) - sess.sent)
        sess.sent = len(toks)
        self._post(sess, ("done", None))

    # ------------------------------------------------------------ protocol
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            try:
                parsed = await self._read_request(reader)
                if parsed is None:          # EOF before a request line
                    return
                method, path, headers, body = parsed
            except _BadRequest as e:
                self.counters.bad_requests += 1
                await self._respond(writer, 400,
                                    e.body or {"error": "bad request"})
                return
            except (asyncio.IncompleteReadError, ValueError,
                    UnicodeDecodeError):
                self.counters.bad_requests += 1
                await self._respond(writer, 400, {"error": "bad request"})
                return
            if self._closing:
                await self._respond(writer, 503,
                                    {"error": "shutting down"})
                return
            if path == "/v1/completions":
                if method != "POST":
                    await self._respond(writer, 405,
                                        {"error": "use POST"})
                    return
                await self._completions(writer, body)
            elif path == "/v1/models" and method == "GET":
                await self._respond(writer, 200, {
                    "object": "list",
                    "data": [{"id": self.model_id, "object": "model",
                              "owned_by": "repro"}]})
            elif path == "/healthz" and method == "GET":
                await self._respond(writer, 200, {
                    "status": "error" if self._pump_error else "ok",
                    "pod_size": self.orch.pod_size()})
            elif path == "/stats" and method == "GET":
                await self._respond(writer, 200, self._stats())
            elif path == "/metrics" and method == "GET":
                await self._respond_text(writer, self._render_metrics())
            elif path == "/debug/flightrec" and method == "GET":
                await self._respond(writer, 200, self.orch.flightrec.dump())
            else:
                await self._respond(writer, 404, {"error": "not found"})
        except (ConnectionError, BrokenPipeError):
            pass                            # client went away mid-reply
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, BrokenPipeError):
                pass

    async def _read_request(self, reader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin1").strip().split()
        if len(parts) != 3 or not parts[2].startswith("HTTP/"):
            raise _BadRequest
        method, path = parts[0].upper(), parts[1]
        headers = {}
        while True:
            hl = await reader.readline()
            if hl in (b"\r\n", b"\n"):
                break
            if not hl or b":" not in hl:
                raise _BadRequest
            k, v = hl.decode("latin1").split(":", 1)
            headers[k.strip().lower()] = v.strip()
        body = b""
        if "content-length" in headers:
            n = int(headers["content-length"])
            if not 0 <= n <= 8_000_000:
                raise _BadRequest
            body = await reader.readexactly(n)
        return method, path, headers, body

    async def _respond(self, writer, status: int, obj: dict,
                       extra_headers=()):
        reasons = {200: "OK", 400: "Bad Request", 404: "Not Found",
                   405: "Method Not Allowed", 429: "Too Many Requests",
                   503: "Service Unavailable"}
        body = json.dumps(obj).encode()
        head = (f"HTTP/1.1 {status} {reasons.get(status, 'Error')}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n")
        for k, v in extra_headers:
            head += f"{k}: {v}\r\n"
        writer.write(head.encode("latin1") + b"\r\n" + body)
        await writer.drain()

    async def _respond_text(self, writer, text: str):
        """Prometheus text exposition (the one non-JSON responder)."""
        body = text.encode("utf-8")
        head = ("HTTP/1.1 200 OK\r\n"
                "Content-Type: text/plain; version=0.0.4; "
                "charset=utf-8\r\n"
                f"Content-Length: {len(body)}\r\n"
                "Connection: close\r\n\r\n")
        writer.write(head.encode("latin1") + body)
        await writer.drain()

    # --------------------------------------------------------- /metrics
    # TTFT is on the ENGINE clock (steps); ITL's stand-in is per-step
    # wall seconds (one decode step emits one token per active stream)
    _TTFT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
    _ITL_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                    0.1, 0.25, 1.0)
    # per-class ITL is on the ENGINE clock (mean steps between tokens,
    # 1.0 = a stream that decoded every step; see instrument.py)
    _CLASS_ITL_BUCKETS = (1.0, 1.25, 1.5, 2.0, 4.0, 8.0, 16.0)

    def _build_mirror(self) -> dict:
        """Plain-data snapshot of everything /metrics exposes, built on
        the PUMP thread (the only one allowed to read handles and
        telemetry windows). The HTTP thread renders from whichever
        mirror reference it sees — immutable once built."""
        o = self.orch
        snap = self.last_snapshot
        inst = []
        for i, h in enumerate(o.instances):
            if i in o._retired:
                continue
            up = h.alive()
            tel = o.telemetry[i]
            inst.append({
                "idx": i, "up": 1 if up else 0,
                "queue_depth": h.queue_len() if up else 0,
                "block_vacancy": (1.0 - h.blocks_in_use()
                                  / max(h.n_blocks, 1)) if up else 0.0,
                "tokens_per_s": tel.tokens_per_s(),
                "ttfts": list(tel.ttfts),
                "itls": list(tel.step_seconds),
                "token_budget": tel.budget,
                "class_ttfts": {c: list(d)
                                for c, d in tel.class_ttfts.items()},
                "class_itls": {c: list(d)
                               for c, d in tel.class_itls.items()}})
        return {
            "instances": inst,
            "tokens_per_s": snap.tokens_per_s if snap else 0.0,
            "budget_utilization": (snap.budget_utilization
                                   if snap else 0.0),
            "prefix_hit_rate": snap.prefix_hit_rate if snap else 0.0,
            "pod_size": o.pod_size(),
            "faults": {"rpc_timeouts": o.faults.rpc_timeouts,
                       "quarantines": o.faults.quarantines,
                       "respawns": o.faults.respawns,
                       "evictions": o.faults.evictions},
        }

    def _render_metrics(self) -> str:
        """One scrape: counters (plain-int reads, safe cross-thread) +
        the pump's latest immutable mirror, through the in-repo
        registry (serving/observe.py)."""
        reg = OBS.MetricsRegistry()
        c = self.counters
        reg.counter("repro_requests_total",
                    "Completions accepted at the front door.", c.requests)
        reg.counter("repro_http_429_total",
                    "Admissions shed by backpressure.", c.rejected_429)
        reg.counter("repro_bad_requests_total",
                    "Malformed requests answered 400.", c.bad_requests)
        reg.counter("repro_tokens_out_total",
                    "Tokens flushed to clients.", c.tokens_out)
        reg.counter("repro_streams_total",
                    "Completions served as SSE streams.", c.streamed)
        reg.counter("repro_aborted_streams_total",
                    "Streams cut by shutdown or client hangup.",
                    c.aborted_streams)
        reg.counter("repro_routed_total", "Admissions by routing rule.",
                    c.routed_prefix, labels={"reason": "prefix"})
        reg.counter("repro_routed_total", "Admissions by routing rule.",
                    c.routed_vacancy, labels={"reason": "vacancy"})
        m = self._metrics_mirror
        if m is not None:
            reg.gauge("repro_tokens_per_s",
                      "Fleet decode throughput (tokens/s).",
                      m["tokens_per_s"])
            reg.gauge("repro_budget_utilization",
                      "Mean fraction of the per-step token budget "
                      "packed.", m["budget_utilization"])
            reg.gauge("repro_prefix_hit_rate",
                      "Fraction of prompt blocks served from the "
                      "prefix cache.", m["prefix_hit_rate"])
            reg.gauge("repro_pod_size", "Alive, non-retired instances.",
                      m["pod_size"])
            for kind, v in sorted(m["faults"].items()):
                reg.counter("repro_faults_total",
                            "Failure-domain events by kind.", v,
                            labels={"kind": kind})
            for e in m["instances"]:
                lab = {"instance": str(e["idx"])}
                reg.gauge("repro_instance_up",
                          "1 while the instance answers.", e["up"],
                          labels=lab)
                reg.gauge("repro_queue_depth",
                          "Requests queued on the instance.",
                          e["queue_depth"], labels=lab)
                reg.gauge("repro_block_vacancy",
                          "Fraction of the instance's KV pool free.",
                          e["block_vacancy"], labels=lab)
                reg.gauge("repro_instance_tokens_per_s",
                          "Per-instance decode throughput.",
                          e["tokens_per_s"], labels=lab)
                reg.histogram("repro_ttft_steps",
                              "Time to first token, engine-clock steps "
                              "(rolling window).", e["ttfts"],
                              self._TTFT_BUCKETS, labels=lab)
                reg.histogram("repro_itl_seconds",
                              "Inter-token latency: wall seconds per "
                              "engine step (rolling window).", e["itls"],
                              self._ITL_BUCKETS, labels=lab)
                reg.gauge("repro_token_budget",
                          "Per-step token budget in force (0 = phase "
                          "scheduler, nothing to govern).",
                          e["token_budget"], labels=lab)
                for cls in sorted(e["class_ttfts"]):
                    reg.histogram(
                        "repro_class_ttft_steps",
                        "Per-SLO-class time to first token, "
                        "engine-clock steps (rolling window).",
                        e["class_ttfts"][cls], self._TTFT_BUCKETS,
                        labels={"instance": str(e["idx"]),
                                "slo_class": cls})
                for cls in sorted(e["class_itls"]):
                    reg.histogram(
                        "repro_class_itl_steps",
                        "Per-SLO-class mean inter-token gap, "
                        "engine-clock steps (1.0 = never stalled).",
                        e["class_itls"][cls], self._CLASS_ITL_BUCKETS,
                        labels={"instance": str(e["idx"]),
                                "slo_class": cls})
        if self.governor is not None:
            reg.counter("repro_budget_adjustments_total",
                        "Token-budget retargets applied by the "
                        "ingress governor.", self.governor.adjustments)
        reg.counter("repro_traces_exported_total",
                    "Finished traces written to the JSONL sink.",
                    self.tracer.exported)
        reg.counter("repro_trace_spans_dropped_total",
                    "Spans that arrived for unknown/finished traces.",
                    self.tracer.dropped_spans)
        reg.gauge("repro_flightrec_events",
                  "Control-plane events recorded since start.",
                  self.orch.flightrec.dump()["recorded"])
        return reg.render()

    def _stats(self) -> dict:
        snap = self.last_snapshot
        o = self.orch
        return {
            "snapshot": dataclasses.asdict(snap) if snap else None,
            "ingress": self.counters.as_dict(),
            "pod": {"size": o.pod_size(),
                    "retired": sorted(o._retired),
                    "log": list(o.pod_log)},
            "finished": len(o.finished),
            "dropped": o.dropped,
        }

    # --------------------------------------------------------- completions
    # the completion body's contract: exactly these top-level keys
    _BODY_KEYS = frozenset((
        "prompt", "max_tokens", "temperature", "top_k", "seed",
        "eos_id", "stream", "slo_class", "deadline_ms"))

    def _parse_completion(self, body: bytes):
        """Parse one completions body into ``(RequestSpec, stream)``.

        The 400 taxonomy (module docstring): unknown top-level keys
        answer ``unknown_fields`` (naming them), and ``SpecError`` codes
        from spec validation pass through verbatim (``unknown_slo_class``
        / ``bad_deadline``); anything else malformed keeps the generic
        body. The spec is minted with ``rid=0`` — the real stream id is
        stamped on after admission (rids are only spent on accepts)."""
        try:
            obj = json.loads(body.decode("utf-8"))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise _BadRequest from e
        if not isinstance(obj, dict):
            raise _BadRequest
        unknown = sorted(set(obj) - self._BODY_KEYS)
        if unknown:
            raise _BadRequest({
                "error": "unknown_fields",
                "detail": ("unknown top-level keys: "
                           + ", ".join(unknown)),
                "fields": unknown})
        prompt = obj.get("prompt")
        if isinstance(prompt, str) and prompt:
            toks = byte_tokens(prompt, self.orch.cfg.vocab_size)
        elif (isinstance(prompt, list) and prompt
              and all(isinstance(t, int) and 0 <= t for t in prompt)):
            toks = np.asarray(prompt, np.int32)
        else:
            raise _BadRequest
        if len(toks) > 8192:
            raise _BadRequest
        try:
            spec = RequestSpec(
                rid=0, prompt=toks,
                max_tokens=int(obj.get("max_tokens", 16)),
                sampling=SamplingParams(
                    temperature=float(obj.get("temperature", 0.0)),
                    top_k=int(obj.get("top_k", 0)),
                    seed=int(obj.get("seed", 0))),
                eos_id=(None if obj.get("eos_id") is None
                        else int(obj["eos_id"])),
                slo_class=str(obj.get("slo_class", "standard")),
                deadline_ms=(None if obj.get("deadline_ms") is None
                             else float(obj["deadline_ms"])))
            stream = bool(obj.get("stream", False))
        except (TypeError, ValueError) as e:
            raise _BadRequest from e
        if spec.max_tokens > 4096:
            raise _BadRequest
        try:
            spec.validate()
        except SpecError as e:
            if e.code == "malformed":
                raise _BadRequest from e
            raise _BadRequest({"error": e.code,
                               "detail": e.detail}) from e
        return spec, stream

    async def _completions(self, writer, body: bytes):
        t_accept = OBS.server_now()
        try:
            spec, stream = self._parse_completion(body)
        except _BadRequest as e:
            self.counters.bad_requests += 1
            await self._respond(
                writer, 400,
                e.body or {"error": "malformed completion request"})
            return
        # admission: route on CACHED gauges, charging not-yet-pumped
        # accepts so a same-tick burst cannot over-admit. The router
        # sees the full spec — batch-class traffic gets one seat less
        # of queue headroom (router._headroom).
        with self._lock:
            t_route = OBS.server_now()
            decision = self.orch.route(spec=spec,
                                       pending=dict(self._pending))
            if decision is None:
                self.counters.rejected_429 += 1
            else:
                self._pending[decision.idx] = \
                    self._pending.get(decision.idx, 0) + 1
                rid = next(self._rids)
                spec = dataclasses.replace(spec, rid=rid)
                sess = _Session(rid, asyncio.Queue())
                self._sessions[rid] = sess
                self.counters.requests += 1
                if decision.reason == "prefix":
                    self.counters.routed_prefix += 1
                else:
                    self.counters.routed_vacancy += 1
        if decision is None:
            await self._respond(writer, 429,
                                {"error": "all queues full, retry"},
                                extra_headers=[("Retry-After", "1")])
            return
        # open the trace BEFORE the submit queue: the pump attaches its
        # context to the RPC frame, so engine spans record from hook one
        trace_id = self.tracer.begin(
            rid, t0=t_accept, prompt_tokens=int(len(spec.prompt)),
            max_tokens=spec.max_tokens, stream=stream,
            slo_class=spec.slo_class)
        self.tracer.span(rid, "accept", t_accept, t_route)
        self.tracer.span(rid, "route", t_route,
                         attrs={"instance": decision.idx,
                                "reason": decision.reason,
                                "matched_blocks": decision.matched_blocks})
        self._submit_q.put((decision.idx, spec))
        if stream:
            self.counters.streamed += 1
            await self._stream_response(writer, rid, decision, sess,
                                        trace_id)
        else:
            await self._unary_response(writer, rid, decision, sess,
                                       trace_id)

    async def _unary_response(self, writer, rid, decision, sess,
                              trace_id):
        toks = []
        while True:
            kind, val = await sess.events.get()
            if kind == "tok":
                toks.append(val)
            elif kind == "done":
                break
            else:                           # abort
                await self._respond(writer, 503,
                                    {"error": val, "id": rid,
                                     "tokens": toks},
                                    extra_headers=[("X-Request-Id",
                                                    trace_id)])
                return
        await self._respond(writer, 200, {
            "id": rid, "object": "text_completion",
            "model": self.model_id, "tokens": toks,
            "routing": {"instance": decision.idx,
                        "matched_blocks": decision.matched_blocks,
                        "reason": decision.reason},
            "usage": {"completion_tokens": len(toks)}},
            extra_headers=[("X-Request-Id", trace_id)])

    async def _stream_response(self, writer, rid, decision, sess,
                               trace_id):
        head = ("HTTP/1.1 200 OK\r\n"
                "Content-Type: text/event-stream\r\n"
                "Cache-Control: no-cache\r\n"
                f"X-Request-Id: {trace_id}\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n\r\n")
        writer.write(head.encode("latin1"))
        await writer.drain()

        def chunk(payload: bytes) -> bytes:
            return f"{len(payload):x}\r\n".encode() + payload + b"\r\n"

        # routing verdict first, so clients (and the bench) can audit
        # affinity without scraping /stats
        first = json.dumps({"id": rid, "instance": decision.idx,
                            "matched_blocks": decision.matched_blocks,
                            "routing": decision.reason})
        writer.write(chunk(f"data: {first}\n\n".encode()))
        await writer.drain()
        n = 0
        try:
            while True:
                kind, val = await sess.events.get()
                if kind == "tok":
                    ev = json.dumps({"token": val, "index": n})
                    writer.write(chunk(f"data: {ev}\n\n".encode()))
                    await writer.drain()
                    n += 1
                elif kind == "done":
                    writer.write(chunk(b"data: [DONE]\n\n"))
                    break
                else:                       # abort: well-formed tail
                    ev = json.dumps({"error": val})
                    writer.write(chunk(f"data: {ev}\n\n".encode()))
                    break
            writer.write(b"0\r\n\r\n")      # chunked terminator
            await writer.drain()
        except (ConnectionError, BrokenPipeError):
            # client hung up mid-stream: drop the session; the request
            # itself finishes on the engine (tokens just go unread)
            self.counters.aborted_streams += 1
            with self._lock:
                self._sessions.pop(rid, None)
