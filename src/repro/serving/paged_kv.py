"""Paged KV cache — the block-table pool behind the Engine's PRIMARY
decode path (serving/engine.py with ``cache_kind="paged"``).

Layout: a global pool of fixed-size blocks per layer, stored
KV-HEAD-MAJOR — ``k/v: [L, n_blocks, KV, bs, hd]`` — so each (block,
kv-head) pair is a contiguous ``[bs, hd]`` tile. That is exactly the tile
the Pallas decode kernel (kernels/paged_decode.py) DMAs per grid step, so
the kernel reads the pool natively instead of transposing the whole pool
per call (which would defeat its length-proportional HBM traffic on real
hardware). A per-request block table ``[B, max_blocks]`` of pool indices
(-1 = unallocated) maps absolute token position ``p`` to table column
``p // block_size``.

Allocation is on-demand per ``block_size`` tokens, so memory — and
decode-step HBM traffic — scales with *actual* tokens (the paged-KV
property that prevents the HFT static-reservation OOMs, and the substrate
CoCoServe's module replication moves around: KV blocks, not dense slabs).
Freeing a request returns whole blocks to the pool; fragmentation is
bounded by ``block_size - 1`` tokens per request. Sliding-window archs
additionally return *leading* blocks once every token in them has fallen
out of the attention window (``free_out_of_window``) — the block table
keeps holes (-1) at those columns, and allocation is column-indexed so
holes never get rewritten.

Division of labour with the engine:

* ``allocate`` / ``free_slot`` / ``free_out_of_window`` run on the HOST
  free list (no device work);
* ``write_tokens`` scatters a freshly prefilled request's K/V into the
  pool (one functional scatter per request, issued at admission);
* ``export_blocks`` / ``import_blocks`` are the block-granular migration
  wire format (DESIGN.md): CoCoServe's scale-down moves a live request's
  KV blocks between instances' pools without touching dense slabs;
* the per-step decode read is ``models.transformer.forward_paged`` — a
  gather over the block table inside the jitted step, or the Pallas kernel
  in kernels/paged_decode.py;
* ``paged_attention_ref`` below is the vectorized pure-jnp oracle both
  are tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PagedState:
    """Device arrays + host-side free list for one engine."""
    k: jnp.ndarray            # [L, n_blocks, KV, bs, hd] (KV-head-major)
    v: jnp.ndarray
    block_tables: np.ndarray  # [B, max_blocks] int32 host array (-1 empty)
    lengths: np.ndarray       # [B] int32 host array
    free: List[int]
    block_size: int

    @property
    def n_blocks(self) -> int:
        return self.k.shape[1]

    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self.free)

    def pool_bytes(self) -> int:
        return int(self.k.size * self.k.dtype.itemsize
                   + self.v.size * self.v.dtype.itemsize)

    def utilization(self) -> float:
        """Fraction of allocated slots actually holding tokens (1 - frag).
        Capped at 1: windowed requests count absolute ``lengths`` but only
        hold their live (in-window) blocks."""
        used_blocks = self.blocks_in_use()
        if used_blocks == 0:
            return 1.0
        toks = int(self.lengths.sum())
        return min(1.0, toks / (used_blocks * self.block_size))


def init_paged(cfg: ModelConfig, max_batch: int, n_blocks: int,
               block_size: int = 16, dtype="bfloat16",
               max_len: int = 4096) -> PagedState:
    dtype = jnp.dtype(dtype)
    hd = cfg.resolved_head_dim
    L, KV = cfg.num_layers, cfg.num_kv_heads
    max_blocks = -(-max_len // block_size)
    shape = (L, n_blocks, KV, block_size, hd)
    return PagedState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        block_tables=np.full((max_batch, max_blocks), -1, np.int32),
        lengths=np.zeros((max_batch,), np.int32),
        free=list(range(n_blocks)), block_size=block_size)


class OutOfBlocks(RuntimeError):
    pass


def allocate(state: PagedState, slot: int, n_tokens: int,
             window: Optional[int] = None):
    """Ensure ``slot`` has blocks for lengths[slot] + n_tokens tokens.

    Column-indexed: position ``p`` lives in table column ``p // bs``, so a
    row with leading holes (sliding-window freeing) only allocates the
    columns the new tokens actually land in. With ``window``, columns
    already fully OUT of the attention window after the write are never
    allocated at all — a long prompt admitted into a window-sized pool
    only claims its live suffix (plus the current write head), never
    transient full-prompt residency. Raises OutOfBlocks — WITHOUT
    mutating any state — when the pool has too few free blocks or the
    needed column exceeds the table row (context > ``max_len``)."""
    if n_tokens <= 0:
        return
    bs = state.block_size
    start = int(state.lengths[slot])
    first_col = start // bs
    last_col = (start + n_tokens - 1) // bs
    if window is not None:
        # same dead-column rule as free_out_of_window at the post-write
        # length: the next query (pos start+n_tokens) attends kpos >
        # start+n_tokens-window only
        dead = (start + n_tokens - window + 1) // bs
        first_col = max(first_col, min(dead, last_col))
    if last_col >= state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"slot {slot} block table full: needs column {last_col}, "
            f"table holds {state.block_tables.shape[1]}")
    missing = [c for c in range(first_col, last_col + 1)
               if state.block_tables[slot, c] < 0]
    if len(missing) > len(state.free):
        raise OutOfBlocks(
            f"need {len(missing)} blocks, {len(state.free)} free")
    for c in missing:
        state.block_tables[slot, c] = state.free.pop()


def free_slot(state: PagedState, slot: int):
    for b in state.block_tables[slot]:
        if b >= 0:
            state.free.append(int(b))
    state.block_tables[slot] = -1
    state.lengths[slot] = 0


def free_out_of_window(state: PagedState, slot: int, window: int) -> int:
    """Sliding-window reclamation: return the leading blocks of ``slot``
    whose every token has fallen out of the attention window.

    The next query sits at position ``lengths[slot]`` and attends keys
    with position > ``lengths[slot] - window`` (see layers._attn_mask), so
    table column c is dead once ``(c+1)*bs - 1 <= lengths[slot] - window``.
    Dead columns become holes (-1) that the masked attention never reads
    and column-indexed ``allocate`` never refills. Returns #blocks freed.

    Called per slot per decode step, so it must not rescan history: dead
    columns below the newly-dead ones are already holes (freed earlier or
    window-skipped at allocation), hence the backward scan stops at the
    first hole — O(newly dead + 1) per call, O(1) amortized.
    """
    bs = state.block_size
    n_dead = min(max((int(state.lengths[slot]) - window + 1) // bs, 0),
                 state.block_tables.shape[1])
    freed = 0
    for c in range(n_dead - 1, -1, -1):
        b = int(state.block_tables[slot, c])
        if b < 0:
            break
        state.free.append(b)
        state.block_tables[slot, c] = -1
        freed += 1
    return freed


def write_tokens(state: PagedState, slot: int, k_new, v_new):
    """Append k/v for S new tokens of one request (k_new/v_new:
    [L, S, KV, hd]). Requires allocate() first."""
    return write_tokens_batch(state, [slot], k_new[:, None], v_new[:, None])


def write_tokens_batch(state: PagedState, slots, k_new, v_new,
                       lengths: Optional[Sequence[int]] = None):
    """Append k/v for up to S new tokens of G requests in ONE pool scatter.

    k_new/v_new: [L, G, S, KV, hd] — S is the (possibly padded) group
    length; ``lengths`` gives each request's TRUE new-token count (default
    S for all). Rows are padded to a shared S by the engine's power-of-two
    prefill buckets; pad positions scatter to an out-of-range block index
    and are dropped, so one executable serves the whole bucket.

    A functional ``.at[].set`` copies the whole pool, so batching a
    G-request admission wave into one scatter per pool costs 2 copies
    instead of 2·G. Requires allocate() first (for the true lengths).
    Returns the updated (functional) device arrays stored back into
    ``state``.
    """
    L, G, S = k_new.shape[:3]
    bs = state.block_size
    if lengths is None:
        lengths = [S] * G
    n_pool = state.n_blocks
    max_col = state.block_tables.shape[1] - 1
    blocks, offs = [], []
    for slot, n in zip(slots, lengths):
        start = int(state.lengths[slot])
        pos = np.arange(start, start + S)
        cols = np.minimum(pos // bs, max_col)
        blk = state.block_tables[slot, cols]
        # dropped: pad positions (>= n) AND unallocated columns (window-
        # skipped prefill prefixes; -1 would WRAP, not drop)
        blk = np.where((np.arange(S) < n) & (blk >= 0), blk, n_pool)
        blocks.append(blk)
        offs.append(pos % bs)
        state.lengths[slot] = start + n
    bidx = jnp.asarray(np.concatenate(blocks), jnp.int32)   # [G*S]
    oidx = jnp.asarray(np.concatenate(offs), jnp.int32)
    # pool is [L, n_blocks, KV, bs, hd]: advanced indices at axes 1 and 3
    # move to the front, so updates are laid out [G*S, L, KV, hd]
    kf = k_new.reshape(L, G * S, *k_new.shape[3:]).transpose(1, 0, 2, 3)
    vf = v_new.reshape(L, G * S, *v_new.shape[3:]).transpose(1, 0, 2, 3)
    state.k = state.k.at[:, bidx, :, oidx].set(kf.astype(state.k.dtype),
                                               mode="drop")
    state.v = state.v.at[:, bidx, :, oidx].set(vf.astype(state.v.dtype),
                                               mode="drop")
    return state


def export_blocks(state: PagedState, slot: int) -> Dict:
    """Serialize one request's KV to the block-granular migration wire
    format (DESIGN.md §block-migration): the live block-table COLUMNS
    (absolute position // block_size — holes from sliding-window freeing
    are preserved), the pool blocks at those columns as host arrays, and
    the token count. Does NOT free the source blocks — callers pair this
    with ``free_slot`` once the payload is safely away.
    """
    cols = np.nonzero(state.block_tables[slot] >= 0)[0].astype(np.int32)
    if len(cols):
        ids = jnp.asarray(state.block_tables[slot, cols], jnp.int32)
        k = np.asarray(state.k[:, ids])        # [L, n, KV, bs, hd]
        v = np.asarray(state.v[:, ids])
    else:
        L, _, KV, bs, hd = state.k.shape
        k = np.zeros((L, 0, KV, bs, hd), state.k.dtype)
        v = np.zeros((L, 0, KV, bs, hd), state.v.dtype)
    return {"cols": cols, "k": k, "v": v,
            "length": int(state.lengths[slot]),
            "block_size": state.block_size,
            "nbytes": int(k.nbytes + v.nbytes)}


def import_blocks(state: PagedState, slot: int, payload: Dict) -> PagedState:
    """Materialize an exported request into ``slot`` of (another) pool:
    allocate fresh pool blocks, rebind them at the SAME table columns
    (absolute positions are preserved, so RoPE/window masking and the
    counter-based sampling replay are untouched), and scatter the block
    data in. Raises OutOfBlocks without mutating state when the pool or
    the table row can't hold the payload."""
    if payload["block_size"] != state.block_size:
        raise ValueError(
            f"block_size mismatch: payload {payload['block_size']} "
            f"vs pool {state.block_size}")
    if (state.block_tables[slot] >= 0).any():
        raise ValueError(f"import into non-empty slot {slot}")
    cols = np.asarray(payload["cols"], np.int64)
    n = len(cols)
    if n > len(state.free):
        raise OutOfBlocks(f"import needs {n} blocks, {len(state.free)} free")
    if n and int(cols.max()) >= state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"import needs column {int(cols.max())}, table holds "
            f"{state.block_tables.shape[1]}")
    ids = [state.free.pop() for _ in range(n)]
    state.block_tables[slot, cols] = np.asarray(ids, np.int32)
    state.lengths[slot] = payload["length"]
    if n:
        idx = jnp.asarray(ids, jnp.int32)
        state.k = state.k.at[:, idx].set(
            jnp.asarray(payload["k"]).astype(state.k.dtype))
        state.v = state.v.at[:, idx].set(
            jnp.asarray(payload["v"]).astype(state.v.dtype))
    return state


def gather_request(state: PagedState, slot: int, max_len: int):
    """Materialize a request's KV as dense [L, max_len, KV, hd] (oracle /
    fallback path; the paged kernel reads blocks directly)."""
    bs = state.block_size
    n_blk = -(-max_len // bs)
    tbl = state.block_tables[slot, :n_blk]
    tbl = np.where(tbl >= 0, tbl, 0)
    k = state.k[:, jnp.asarray(tbl, jnp.int32)]      # [L, n_blk, KV, bs, hd]
    v = state.v[:, jnp.asarray(tbl, jnp.int32)]
    L, _, KV, _, hd = state.k.shape
    k = k.transpose(0, 1, 3, 2, 4).reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    v = v.transpose(0, 1, 3, 2, 4).reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    return k, v


def paged_attention_ref(q, state: PagedState, slots, *, layer: int):
    """Pure-jnp paged decode attention for a batch of slots, vectorized
    over the batch (one batched gather + masked softmax — no per-slot
    Python loop, so oracle checks don't dominate test time).

    q: [B, H, hd]; returns [B, H, hd]. Oracle for kernels/paged_decode.py
    and for models.transformer.forward_paged's gather path.
    """
    import math
    B, H, hd = q.shape
    KV = state.k.shape[2]
    bs = state.block_size
    rep = H // KV
    slots = list(slots)
    lens = state.lengths[slots]                      # [B] host
    n_blk = max(1, -(-int(lens.max()) // bs))
    tbl = state.block_tables[slots, :n_blk]
    tbl = jnp.asarray(np.where(tbl >= 0, tbl, 0), jnp.int32)
    k = state.k[layer][tbl]                          # [B, n_blk, KV, bs, hd]
    v = state.v[layer][tbl]
    k = k.transpose(0, 1, 3, 2, 4).reshape(B, n_blk * bs, KV, hd)
    v = v.transpose(0, 1, 3, 2, 4).reshape(B, n_blk * bs, KV, hd)
    kh = jnp.repeat(k, rep, axis=2).astype(jnp.float32)  # [B, S, H, hd]
    vh = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kh) / math.sqrt(hd)
    mask = jnp.arange(n_blk * bs)[None, :] < jnp.asarray(lens)[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, vh).astype(q.dtype)
