"""Paged KV cache — block-table memory management (the vLLM mechanism the
paper benchmarks against, §2.1/§6).

Layout: a global pool of fixed-size blocks per layer,
``k/v: [L, n_blocks, block_size, KV, hd]``, plus a per-request block table
``[B, max_blocks]`` of pool indices (-1 = unallocated). Allocation is
on-demand per ``block_size`` tokens, so memory scales with *actual* tokens
(the paged-KV property that prevents the HFT static-reservation OOMs), and
freeing a request returns whole blocks to the pool — fragmentation is
bounded by ``block_size - 1`` tokens per request.

The gather/scatter forms below are the pure-jnp oracle for the paged
decode-attention Pallas kernel (kernels/paged_decode.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PagedState:
    """Device arrays + host-side free list for one engine."""
    k: jnp.ndarray            # [L, n_blocks, bs, KV, hd]
    v: jnp.ndarray
    block_tables: np.ndarray  # [B, max_blocks] int32 host array (-1 empty)
    lengths: np.ndarray       # [B] int32 host array
    free: List[int]
    block_size: int

    @property
    def n_blocks(self) -> int:
        return self.k.shape[1]

    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self.free)

    def utilization(self) -> float:
        """Fraction of allocated slots actually holding tokens (1 - frag)."""
        used_blocks = self.blocks_in_use()
        if used_blocks == 0:
            return 1.0
        toks = int(self.lengths.sum())
        return toks / (used_blocks * self.block_size)


def init_paged(cfg: ModelConfig, max_batch: int, n_blocks: int,
               block_size: int = 16, dtype="bfloat16",
               max_len: int = 4096) -> PagedState:
    dtype = jnp.dtype(dtype)
    hd = cfg.resolved_head_dim
    L, KV = cfg.num_layers, cfg.num_kv_heads
    max_blocks = -(-max_len // block_size)
    shape = (L, n_blocks, block_size, KV, hd)
    return PagedState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        block_tables=np.full((max_batch, max_blocks), -1, np.int32),
        lengths=np.zeros((max_batch,), np.int32),
        free=list(range(n_blocks)), block_size=block_size)


class OutOfBlocks(RuntimeError):
    pass


def allocate(state: PagedState, slot: int, n_tokens: int):
    """Ensure ``slot`` has blocks for lengths[slot] + n_tokens tokens."""
    need_total = int(state.lengths[slot]) + n_tokens
    have = int((state.block_tables[slot] >= 0).sum())
    need_blocks = -(-need_total // state.block_size) - have
    if need_blocks > len(state.free):
        raise OutOfBlocks(
            f"need {need_blocks} blocks, {len(state.free)} free")
    for i in range(need_blocks):
        state.block_tables[slot, have + i] = state.free.pop()


def free_slot(state: PagedState, slot: int):
    for b in state.block_tables[slot]:
        if b >= 0:
            state.free.append(int(b))
    state.block_tables[slot] = -1
    state.lengths[slot] = 0


def write_tokens(state: PagedState, slot: int, k_new, v_new):
    """Append k/v for S new tokens of one request.

    k_new/v_new: [L, S, KV, hd]. Requires allocate() first. Returns the
    updated (functional) device arrays stored back into ``state``.
    """
    S = k_new.shape[1]
    start = int(state.lengths[slot])
    bs = state.block_size
    # target (block, offset) per token
    pos = np.arange(start, start + S)
    blocks = state.block_tables[slot, pos // bs]
    offs = pos % bs
    bidx = jnp.asarray(blocks, jnp.int32)
    oidx = jnp.asarray(offs, jnp.int32)
    # scatter: k[:, blocks[t], offs[t]] = k_new[:, t]
    state.k = state.k.at[:, bidx, oidx].set(k_new)
    state.v = state.v.at[:, bidx, oidx].set(v_new)
    state.lengths[slot] = start + S
    return state


def gather_request(state: PagedState, slot: int, max_len: int):
    """Materialize a request's KV as dense [L, max_len, KV, hd] (oracle /
    fallback path; the paged kernel reads blocks directly)."""
    bs = state.block_size
    n_blk = -(-max_len // bs)
    tbl = state.block_tables[slot, :n_blk]
    tbl = np.where(tbl >= 0, tbl, 0)
    k = state.k[:, jnp.asarray(tbl, jnp.int32)]      # [L, n_blk, bs, KV, hd]
    v = state.v[:, jnp.asarray(tbl, jnp.int32)]
    L, _, _, KV, hd = state.k.shape
    k = k.reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    v = v.reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    return k, v


def paged_attention_ref(q, state: PagedState, slots, *, layer: int):
    """Pure-jnp paged decode attention for a batch of slots.

    q: [B, H, hd]; returns [B, H, hd]. Oracle for kernels/paged_decode.py.
    """
    import math
    B, H, hd = q.shape
    KV = state.k.shape[3]
    bs = state.block_size
    rep = H // KV
    outs = []
    for b, slot in enumerate(slots):
        length = int(state.lengths[slot])
        n_blk = max(1, -(-length // bs))
        tbl = jnp.asarray(
            np.where(state.block_tables[slot, :n_blk] >= 0,
                     state.block_tables[slot, :n_blk], 0), jnp.int32)
        k = state.k[layer, tbl].reshape(n_blk * bs, KV, hd)
        v = state.v[layer, tbl].reshape(n_blk * bs, KV, hd)
        kh = jnp.repeat(k, rep, axis=1)
        vh = jnp.repeat(v, rep, axis=1)
        s = jnp.einsum("hd,shd->hs", q[b].astype(jnp.float32),
                       kh.astype(jnp.float32)) / math.sqrt(hd)
        mask = jnp.arange(n_blk * bs) < length
        s = jnp.where(mask[None, :], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("hs,shd->hd", w, vh.astype(jnp.float32)))
    return jnp.stack(outs).astype(q.dtype)
