"""Paged KV cache — the block-table pool behind the Engine's PRIMARY
decode path (serving/engine.py with ``cache_kind="paged"``).

Layout: a global pool of fixed-size blocks per layer,
``k/v: [L, n_blocks, block_size, KV, hd]``, plus a per-request block table
``[B, max_blocks]`` of pool indices (-1 = unallocated). Allocation is
on-demand per ``block_size`` tokens, so memory — and decode-step HBM
traffic — scales with *actual* tokens (the paged-KV property that prevents
the HFT static-reservation OOMs, and the substrate CoCoServe's module
replication moves around: KV blocks, not dense slabs). Freeing a request
returns whole blocks to the pool; fragmentation is bounded by
``block_size - 1`` tokens per request.

Division of labour with the engine:

* ``allocate`` / ``free_slot`` run on the HOST free list (no device work);
* ``write_tokens`` scatters a freshly prefilled request's K/V into the
  pool (one functional scatter per request, issued at admission);
* the per-step decode read is ``models.transformer.forward_paged`` — a
  gather over the block table inside the jitted step, or the Pallas kernel
  in kernels/paged_decode.py;
* ``paged_attention_ref`` below is the vectorized pure-jnp oracle both
  are tested against.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PagedState:
    """Device arrays + host-side free list for one engine."""
    k: jnp.ndarray            # [L, n_blocks, bs, KV, hd]
    v: jnp.ndarray
    block_tables: np.ndarray  # [B, max_blocks] int32 host array (-1 empty)
    lengths: np.ndarray       # [B] int32 host array
    free: List[int]
    block_size: int

    @property
    def n_blocks(self) -> int:
        return self.k.shape[1]

    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self.free)

    def utilization(self) -> float:
        """Fraction of allocated slots actually holding tokens (1 - frag)."""
        used_blocks = self.blocks_in_use()
        if used_blocks == 0:
            return 1.0
        toks = int(self.lengths.sum())
        return toks / (used_blocks * self.block_size)


def init_paged(cfg: ModelConfig, max_batch: int, n_blocks: int,
               block_size: int = 16, dtype="bfloat16",
               max_len: int = 4096) -> PagedState:
    dtype = jnp.dtype(dtype)
    hd = cfg.resolved_head_dim
    L, KV = cfg.num_layers, cfg.num_kv_heads
    max_blocks = -(-max_len // block_size)
    shape = (L, n_blocks, block_size, KV, hd)
    return PagedState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        block_tables=np.full((max_batch, max_blocks), -1, np.int32),
        lengths=np.zeros((max_batch,), np.int32),
        free=list(range(n_blocks)), block_size=block_size)


class OutOfBlocks(RuntimeError):
    pass


def allocate(state: PagedState, slot: int, n_tokens: int):
    """Ensure ``slot`` has blocks for lengths[slot] + n_tokens tokens.

    Raises OutOfBlocks — WITHOUT mutating any state — when the pool has
    too few free blocks or the slot's block-table row is full (the
    request's context exceeds ``max_len``)."""
    need_total = int(state.lengths[slot]) + n_tokens
    have = int((state.block_tables[slot] >= 0).sum())
    need_blocks = -(-need_total // state.block_size) - have
    if have + need_blocks > state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"slot {slot} block table full: needs {have + need_blocks} "
            f"entries, table holds {state.block_tables.shape[1]}")
    if need_blocks > len(state.free):
        raise OutOfBlocks(
            f"need {need_blocks} blocks, {len(state.free)} free")
    for i in range(need_blocks):
        state.block_tables[slot, have + i] = state.free.pop()


def free_slot(state: PagedState, slot: int):
    for b in state.block_tables[slot]:
        if b >= 0:
            state.free.append(int(b))
    state.block_tables[slot] = -1
    state.lengths[slot] = 0


def write_tokens(state: PagedState, slot: int, k_new, v_new):
    """Append k/v for S new tokens of one request (k_new/v_new:
    [L, S, KV, hd]). Requires allocate() first."""
    return write_tokens_batch(state, [slot], k_new[:, None], v_new[:, None])


def write_tokens_batch(state: PagedState, slots, k_new, v_new):
    """Append k/v for S new tokens of G requests in ONE pool scatter.

    k_new/v_new: [L, G, S, KV, hd] (same S per request — the engine's
    same-length prefill groups). A functional ``.at[].set`` copies the
    whole pool, so batching a G-request admission wave into one scatter
    per pool costs 2 copies instead of 2·G. Requires allocate() first.
    Returns the updated (functional) device arrays stored back into
    ``state``.
    """
    L, G, S = k_new.shape[:3]
    bs = state.block_size
    blocks, offs = [], []
    for slot in slots:
        start = int(state.lengths[slot])
        pos = np.arange(start, start + S)
        blocks.append(state.block_tables[slot, pos // bs])
        offs.append(pos % bs)
        state.lengths[slot] = start + S
    bidx = jnp.asarray(np.concatenate(blocks), jnp.int32)   # [G*S]
    oidx = jnp.asarray(np.concatenate(offs), jnp.int32)
    kf = k_new.reshape(L, G * S, *k_new.shape[3:])
    vf = v_new.reshape(L, G * S, *v_new.shape[3:])
    # scatter: k[:, blocks[t], offs[t]] = k_new[:, t]
    state.k = state.k.at[:, bidx, oidx].set(kf.astype(state.k.dtype))
    state.v = state.v.at[:, bidx, oidx].set(vf.astype(state.v.dtype))
    return state


def gather_request(state: PagedState, slot: int, max_len: int):
    """Materialize a request's KV as dense [L, max_len, KV, hd] (oracle /
    fallback path; the paged kernel reads blocks directly)."""
    bs = state.block_size
    n_blk = -(-max_len // bs)
    tbl = state.block_tables[slot, :n_blk]
    tbl = np.where(tbl >= 0, tbl, 0)
    k = state.k[:, jnp.asarray(tbl, jnp.int32)]      # [L, n_blk, bs, KV, hd]
    v = state.v[:, jnp.asarray(tbl, jnp.int32)]
    L, _, _, KV, hd = state.k.shape
    k = k.reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    v = v.reshape(L, n_blk * bs, KV, hd)[:, :max_len]
    return k, v


def paged_attention_ref(q, state: PagedState, slots, *, layer: int):
    """Pure-jnp paged decode attention for a batch of slots, vectorized
    over the batch (one batched gather + masked softmax — no per-slot
    Python loop, so oracle checks don't dominate test time).

    q: [B, H, hd]; returns [B, H, hd]. Oracle for kernels/paged_decode.py
    and for models.transformer.forward_paged's gather path.
    """
    import math
    B, H, hd = q.shape
    KV = state.k.shape[3]
    bs = state.block_size
    rep = H // KV
    slots = list(slots)
    lens = state.lengths[slots]                      # [B] host
    n_blk = max(1, -(-int(lens.max()) // bs))
    tbl = state.block_tables[slots, :n_blk]
    tbl = jnp.asarray(np.where(tbl >= 0, tbl, 0), jnp.int32)
    k = state.k[layer][tbl].reshape(B, n_blk * bs, KV, hd)
    v = state.v[layer][tbl].reshape(B, n_blk * bs, KV, hd)
    kh = jnp.repeat(k, rep, axis=2).astype(jnp.float32)  # [B, S, H, hd]
    vh = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kh) / math.sqrt(hd)
    mask = jnp.arange(n_blk * bs)[None, :] < jnp.asarray(lens)[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, vh).astype(q.dtype)
