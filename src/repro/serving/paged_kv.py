"""Paged KV cache — the block-table pool behind the Engine's PRIMARY
decode path (serving/engine.py with ``cache_kind="paged"``).

Layout: a global pool of fixed-size blocks per layer, stored
KV-HEAD-MAJOR — ``k/v: [L, n_blocks, KV, bs, hd]`` — so each (block,
kv-head) pair is a contiguous ``[bs, hd]`` tile. That is exactly the tile
the Pallas decode kernel (kernels/paged_decode.py) DMAs per grid step, so
the kernel reads the pool natively instead of transposing the whole pool
per call (which would defeat its length-proportional HBM traffic on real
hardware). A per-request block table ``[B, max_blocks]`` of pool indices
(-1 = unallocated) maps absolute token position ``p`` to table column
``p // block_size``.

Allocation is on-demand per ``block_size`` tokens, so memory — and
decode-step HBM traffic — scales with *actual* tokens (the paged-KV
property that prevents the HFT static-reservation OOMs, and the substrate
CoCoServe's module replication moves around: KV blocks, not dense slabs).
Freeing a request returns whole blocks to the pool; fragmentation is
bounded by ``block_size - 1`` tokens per request. Sliding-window archs
additionally return *leading* blocks once every token in them has fallen
out of the attention window (``free_out_of_window``) — the block table
keeps holes (-1) at those columns, and allocation is column-indexed so
holes never get rewritten.

Ownership model (prefix sharing / copy-on-write, vLLM §4.3):

Every block carries a REFERENCE COUNT. A block is in exactly one of four
states, and every transition goes through ``_incref``/``_decref``:

* **free**      — refcount 0, on ``state.free``; content is garbage.
* **owned**     — refcount 1, bound in exactly one block table; the owner
  may write into it (``allocate`` hands blocks out in this state).
* **shared**    — refcount > 1, bound in several block tables (prompt-
  prefix aliasing); READ-ONLY: any stream about to write into a shared
  block must fork it first (``ensure_writable`` — the copy-on-write).
* **cached-free** — refcount 0 but still holding a registered full
  prompt block: parked on the LRU ``cached_free`` list, revivable by a
  later ``match_prefix`` hit, evicted (cache entry dropped) only under
  allocation pressure.

The prefix cache keys FULL prompt blocks by a content chain hash
(``H(parent_key, block_tokens)``), so a hit on block c guarantees tokens
``[0, (c+1)*bs)`` are identical — and, K/V being a deterministic function
of the token prefix and absolute positions, the cached block's contents
are exactly what a fresh prefill would recompute. Admissions that hit
alias the cached blocks instead of re-prefilling them; the engine runs
prefill only over the suffix.

Division of labour with the engine:

* ``allocate`` / ``free_slot`` / ``free_out_of_window`` and the prefix-
  cache ops (``match_prefix`` / ``adopt_prefix`` / ``register_prefix`` /
  ``ensure_writable``) run on the HOST free list + refcounts (forking is
  the only one that touches the device: one pool-block copy);
* ``write_tokens`` scatters a freshly prefilled request's K/V into the
  pool (one functional scatter per request, issued at admission);
* ``export_blocks`` / ``import_blocks`` are the block-granular migration
  wire format (DESIGN.md): CoCoServe's scale-down moves a live request's
  KV blocks between instances' pools without touching dense slabs;
  shared blocks are MATERIALIZED into the payload (content copied) and
  their prefix keys travel along, so the destination can re-seed its own
  cache — sharing survives migration without cross-pool refcounts.
  An import whose carried prefix key is ALREADY RESIDENT in the
  destination cache aliases (increfs) the resident block instead of
  materializing a duplicate — cross-instance dedupe (content-chain keys
  certify identical content, so aliasing is exact);
* the DIRTY SET behind overlapped migration: every pool write stamps the
  written blocks with a monotonically increasing ``write_epoch``
  (``mark_written``), so ``export_blocks(..., since_epoch=e)`` can ship
  only the blocks touched after a phase-1 snapshot — the short delta a
  two-phase migration pause-copies while the bulk streamed overlapped
  with decode (``import_blocks_delta`` applies it over the staged base);
* the per-step decode read is ``models.transformer.forward_paged`` — a
  gather over the block table inside the jitted step, or the Pallas kernel
  in kernels/paged_decode.py;
* ``paged_attention_ref`` below is the vectorized pure-jnp oracle both
  are tested against.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PagedState:
    """Device arrays + host-side free list / refcounts for one engine.

    Invariants (asserted by tests/test_prefix_sharing.py):

    * ``refcount[b] == 0``  iff  ``b`` is on ``free`` or ``cached_free``;
    * ``refcount[b]`` equals the number of block-table cells holding ``b``;
    * ``block_key[b] == key`` iff ``prefix_cache[key] == b`` (a bijection
      over registered blocks);
    * blocks on ``free`` are never registered; blocks on ``cached_free``
      always are (their cache entry is dropped when they are evicted).
    """
    k: jnp.ndarray            # [L, n_blocks, KV, bs, hd] (KV-head-major)
    v: jnp.ndarray
    block_tables: np.ndarray  # [B, max_blocks] int32 host array (-1 empty)
    lengths: np.ndarray       # [B] int32 host array
    free: List[int]
    block_size: int
    # --- prefix sharing / copy-on-write ---
    refcount: Optional[np.ndarray] = None     # [n_blocks] int32
    enable_prefix_cache: bool = False
    prefix_cache: Dict[bytes, int] = dataclasses.field(default_factory=dict)
    block_key: Dict[int, bytes] = dataclasses.field(default_factory=dict)
    cached_free: "OrderedDict[int, None]" = \
        dataclasses.field(default_factory=OrderedDict)
    # --- dirty set for overlapped (two-phase) migration ---
    write_epoch: int = 0                       # bumps once per pool write
    block_epoch: Optional[np.ndarray] = None   # [n_blocks] int64 last write
    # --- counters (feed serving/instrument + core/monitor gauges) ---
    prefix_queries: int = 0       # full prompt blocks looked up
    prefix_hits: int = 0          # ... of which aliased an existing block
    cow_forks: int = 0            # copy-on-write block copies performed
    blocks_saved_total: int = 0   # cumulative allocations avoided by hits
    dedup_imports: int = 0        # imported blocks aliased to residents

    def __post_init__(self):
        if self.refcount is None:     # direct constructions (tests, tools)
            self.refcount = np.zeros((self.k.shape[1],), np.int32)
        if self.block_epoch is None:
            self.block_epoch = np.zeros((self.k.shape[1],), np.int64)

    @property
    def n_blocks(self) -> int:
        return self.k.shape[1]

    def free_block_count(self) -> int:
        """Blocks allocatable right now: the plain free list plus the
        cached-free (refcount-0 but prefix-registered) blocks that
        allocation pressure may evict."""
        return len(self.free) + len(self.cached_free)

    def blocks_in_use(self) -> int:
        return self.n_blocks - self.free_block_count()

    def shared_blocks_saved(self) -> int:
        """Physical blocks the pool is saving RIGHT NOW through sharing:
        each block referenced r > 1 times stands in for r - 1 copies."""
        return int(np.maximum(self.refcount - 1, 0).sum())

    def pool_bytes(self) -> int:
        return int(self.k.size * self.k.dtype.itemsize
                   + self.v.size * self.v.dtype.itemsize)

    def utilization(self) -> float:
        """Fraction of allocated slots actually holding tokens (1 - frag).
        Capped at 1: windowed requests count absolute ``lengths`` but only
        hold their live (in-window) blocks, and shared blocks serve
        several requests' tokens at once."""
        used_blocks = self.blocks_in_use()
        if used_blocks == 0:
            return 1.0
        toks = int(self.lengths.sum())
        return min(1.0, toks / (used_blocks * self.block_size))


def init_paged(cfg: ModelConfig, max_batch: int, n_blocks: int,
               block_size: int = 16, dtype="bfloat16",
               max_len: int = 4096,
               prefix_cache: bool = False) -> PagedState:
    """Build a pool. ``prefix_cache=True`` enables prompt-prefix sharing:
    full prompt blocks are content-hashed so later admissions alias them
    (the Engine turns this on for its paged path by default)."""
    dtype = jnp.dtype(dtype)
    hd = cfg.resolved_head_dim
    L, KV = cfg.num_layers, cfg.num_kv_heads
    max_blocks = -(-max_len // block_size)
    shape = (L, n_blocks, KV, block_size, hd)
    return PagedState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        block_tables=np.full((max_batch, max_blocks), -1, np.int32),
        lengths=np.zeros((max_batch,), np.int32),
        free=list(range(n_blocks)), block_size=block_size,
        refcount=np.zeros((n_blocks,), np.int32),
        enable_prefix_cache=prefix_cache)


class OutOfBlocks(RuntimeError):
    pass


# ----------------------------------------------------- refcount primitives
def _pop_block(state: PagedState) -> int:
    """Take a refcount-0 block for a new owner: plain free list first,
    then the OLDEST cached-free block (its prefix-cache entry is evicted
    — LRU under allocation pressure). Raises OutOfBlocks, mutating
    nothing, when neither has one."""
    if state.free:
        return state.free.pop()
    if state.cached_free:
        b = next(iter(state.cached_free))
        del state.cached_free[b]
        key = state.block_key.pop(b)
        state.prefix_cache.pop(key, None)
        return b
    raise OutOfBlocks("pool exhausted: no free or cached-free blocks")


def _incref(state: PagedState, b: int):
    if int(state.refcount[b]) == 0:
        # reviving a cached-free block: content stays valid, it just
        # leaves the evictable list
        state.cached_free.pop(b, None)
    state.refcount[b] += 1


def _decref(state: PagedState, b: int):
    state.refcount[b] -= 1
    assert state.refcount[b] >= 0, f"refcount underflow on block {b}"
    if state.refcount[b] == 0:
        if b in state.block_key:        # registered: stay revivable
            state.cached_free[b] = None  # most-recently-freed = LRU tail
        else:
            state.free.append(b)


def mark_written(state: PagedState, block_ids) -> int:
    """Stamp ``block_ids`` as written at a fresh ``write_epoch`` — the
    dirty-set bookkeeping behind two-phase migration: a later
    ``export_blocks(..., since_epoch=e)`` ships exactly the blocks
    stamped after epoch ``e``. Called by every pool-content writer: the
    batched prefill scatter, the fused decode step's host bookkeeping
    (serving/engine.py), CoW forks, and imports. Returns the new epoch."""
    state.write_epoch += 1
    ids = [int(b) for b in block_ids if 0 <= int(b) < state.n_blocks]
    if ids:
        state.block_epoch[ids] = state.write_epoch
    return state.write_epoch


# -------------------------------------------------------------- allocation
def allocate(state: PagedState, slot: int, n_tokens: int,
             window: Optional[int] = None):
    """Ensure ``slot`` has blocks for lengths[slot] + n_tokens tokens.

    Fresh blocks come out OWNED (refcount 1) by ``slot``. Column-indexed:
    position ``p`` lives in table column ``p // bs``, so a row with
    leading holes (sliding-window freeing) or an aliased shared prefix
    only allocates the columns the new tokens actually land in. With
    ``window``, columns already fully OUT of the attention window after
    the write are never allocated at all — a long prompt admitted into a
    window-sized pool only claims its live suffix (plus the current write
    head), never transient full-prompt residency. Raises OutOfBlocks —
    WITHOUT mutating any state — when the pool has too few free blocks or
    the needed column exceeds the table row (context > ``max_len``).
    Under pressure the pool evicts cached-free blocks (oldest first) to
    satisfy the request."""
    if n_tokens <= 0:
        return
    bs = state.block_size
    start = int(state.lengths[slot])
    first_col = start // bs
    last_col = (start + n_tokens - 1) // bs
    if window is not None:
        # same dead-column rule as free_out_of_window at the post-write
        # length: the next query (pos start+n_tokens) attends kpos >
        # start+n_tokens-window only
        dead = (start + n_tokens - window + 1) // bs
        first_col = max(first_col, min(dead, last_col))
    if last_col >= state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"slot {slot} block table full: needs column {last_col}, "
            f"table holds {state.block_tables.shape[1]}")
    missing = [c for c in range(first_col, last_col + 1)
               if state.block_tables[slot, c] < 0]
    if len(missing) > state.free_block_count():
        raise OutOfBlocks(
            f"need {len(missing)} blocks, {state.free_block_count()} free")
    for c in missing:
        b = _pop_block(state)
        state.refcount[b] = 1
        state.block_tables[slot, c] = b


def free_slot(state: PagedState, slot: int):
    """Release ``slot``'s claim on every block it holds (DECREF, not
    unconditional free): an owned block returns to the pool, a shared
    block survives for its other holders, and a registered block parks on
    the cached-free list so later admissions can still alias it."""
    for b in state.block_tables[slot]:
        if b >= 0:
            _decref(state, int(b))
    state.block_tables[slot] = -1
    state.lengths[slot] = 0


def free_out_of_window(state: PagedState, slot: int, window: int) -> int:
    """Sliding-window reclamation: release the leading blocks of ``slot``
    whose every token has fallen out of the attention window.

    The next query sits at position ``lengths[slot]`` and attends keys
    with position > ``lengths[slot] - window`` (see layers._attn_mask), so
    table column c is dead once ``(c+1)*bs - 1 <= lengths[slot] - window``.
    Dead columns become holes (-1) that the masked attention never reads
    and column-indexed ``allocate`` never refills. Out-of-window release
    is a DECREF like any other: a block another stream still references
    merely loses this slot's claim. Returns #blocks this slot released.

    Called per slot per decode step, so it must not rescan history: dead
    columns below the newly-dead ones are already holes (freed earlier or
    window-skipped at allocation), hence the backward scan stops at the
    first hole — O(newly dead + 1) per call, O(1) amortized.
    """
    bs = state.block_size
    n_dead = min(max((int(state.lengths[slot]) - window + 1) // bs, 0),
                 state.block_tables.shape[1])
    freed = 0
    for c in range(n_dead - 1, -1, -1):
        b = int(state.block_tables[slot, c])
        if b < 0:
            break
        _decref(state, b)
        state.block_tables[slot, c] = -1
        freed += 1
    return freed


# ----------------------------------------------------------- prefix cache
def _chain_keys(tokens, block_size: int) -> List[bytes]:
    """Content chain hash of every FULL block of ``tokens``: key_c =
    H(key_{c-1} || tokens[c*bs:(c+1)*bs]). Keying block c on the whole
    prefix (not just its own tokens) is what makes a hit mean "identical
    tokens from position 0" — the property that lets cached K/V stand in
    for a fresh prefill."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    keys, h = [], b""
    for c in range(len(toks) // block_size):
        h = hashlib.sha1(
            h + toks[c * block_size:(c + 1) * block_size].tobytes()).digest()
        keys.append(h)
    return keys


def match_prefix(state: PagedState, tokens, *,
                 record: bool = True) -> List[int]:
    """Longest cached prefix of ``tokens``: returns the pool block ids
    (in column order) of the leading FULL blocks whose content chain is
    registered. Read-only apart from the hit/query counters; the caller
    decides whether to ``adopt_prefix`` the result. Empty when the cache
    is disabled or nothing matches.

    ``record=False`` skips the counters — the engine uses it so that
    backpressure retries (the same queued prompt re-matched every step)
    don't inflate the hit-rate gauge; it records once per ADMITTED
    request via ``record_lookup``."""
    if not state.enable_prefix_cache:
        return []
    keys = _chain_keys(tokens, state.block_size)
    if not keys:
        return []
    out: List[int] = []
    for key in keys:
        b = state.prefix_cache.get(key)
        if b is None:
            break
        out.append(b)
    if record:
        state.prefix_queries += len(keys)
        state.prefix_hits += len(out)
    return out


def record_lookup(state: PagedState, tokens, matched: Sequence[int]):
    """Count one prefix-cache lookup in the hit-rate gauges: the full
    blocks of ``tokens`` as queries, ``matched`` as hits (which are also
    allocations avoided -> blocks_saved_total). Engines call this once
    per SUCCESSFULLY admitted request — never per attempt, so
    backpressure retries and fork-failure requeues don't skew the
    gauges."""
    state.prefix_queries += len(tokens) // state.block_size
    state.prefix_hits += len(matched)
    state.blocks_saved_total += len(matched)


def adopt_prefix(state: PagedState, slot: int, block_ids: Sequence[int],
                 n_tokens: int):
    """Alias a matched prefix into ``slot``: INCREF each block and bind it
    at its column; ``slot`` then owns ``n_tokens`` of context without a
    single pool write or prefill FLOP. ``n_tokens`` may stop short of the
    aliased span (the engine caps it at prompt_len - 1 so there is always
    at least one suffix token to recompute for first-token logits — the
    write-back into the shared tail block is what copy-on-write forks).
    Requires an empty slot row at those columns."""
    assert n_tokens <= len(block_ids) * state.block_size
    for c, b in enumerate(block_ids):
        assert state.block_tables[slot, c] < 0, \
            f"adopt into occupied column {c} of slot {slot}"
        _incref(state, int(b))
        state.block_tables[slot, c] = int(b)
    state.lengths[slot] = n_tokens


def register_prefix(state: PagedState, slot: int, tokens) -> int:
    """Publish ``slot``'s FULL, fully-written blocks into the prefix
    cache so later admissions can alias them. First binding of a key
    wins; partially-filled tail blocks and window holes are skipped.
    Registration does not change ownership — the block stays with its
    refcount, it merely becomes discoverable (and, once its refcount
    drops to 0, parks on cached_free instead of the free list).
    Returns the number of newly registered blocks."""
    if not state.enable_prefix_cache:
        return 0
    n = 0
    for c, key in enumerate(_chain_keys(tokens, state.block_size)):
        b = int(state.block_tables[slot, c])
        if b < 0 or key in state.prefix_cache or b in state.block_key:
            continue
        state.prefix_cache[key] = b
        state.block_key[b] = key
        n += 1
    return n


def ensure_writable(state: PagedState, slot: int, start: int,
                    n_tokens: int) -> int:
    """Copy-on-write: fork every SHARED block that the write of
    ``n_tokens`` tokens at position ``start`` would touch. A fork takes a
    fresh block (OutOfBlocks if none — no partial table corruption: the
    failing column is untouched), device-copies the shared block's pool
    content, rebinds ``slot``'s column to the private copy and DECREFs
    the original (which stays alive for its other holders, cache entry
    included). Owned (refcount-1) blocks pass through untouched — writes
    there are already private. Returns the number of forks performed."""
    if n_tokens <= 0:
        return 0
    bs = state.block_size
    pairs = []              # (shared src block, private dst block)
    try:
        for c in range(start // bs, (start + n_tokens - 1) // bs + 1):
            if c >= state.block_tables.shape[1]:
                break
            b = int(state.block_tables[slot, c])
            if b < 0 or int(state.refcount[b]) <= 1:
                continue
            nb = _pop_block(state)
            state.refcount[nb] = 1
            state.refcount[b] -= 1  # still >= 1: other holders keep it
            state.block_tables[slot, c] = nb
            state.cow_forks += 1
            pairs.append((b, nb))
    finally:
        # ONE batched gather+scatter for all forks (a functional pool
        # update copies the whole array, so per-fork .set calls would
        # cost N pool copies); the finally keeps already-rebound columns
        # backed by real content even when a later column's pop raises
        if pairs:
            src = jnp.asarray([p[0] for p in pairs], jnp.int32)
            dst = jnp.asarray([p[1] for p in pairs], jnp.int32)
            state.k = state.k.at[:, dst].set(state.k[:, src])
            state.v = state.v.at[:, dst].set(state.v[:, src])
            # a fork rebinds the column to a new physical block: the old
            # stamp lives on the old id, so the copy must be stamped for
            # the migration dirty set to ship the column's new content
            mark_written(state, [p[1] for p in pairs])
    return len(pairs)


def prefix_stats(state: PagedState) -> Dict:
    """The pool's sharing gauges (engine/orchestrator telemetry)."""
    q = state.prefix_queries
    return {"queries": q, "hits": state.prefix_hits,
            "hit_rate": state.prefix_hits / q if q else 0.0,
            "cow_forks": state.cow_forks,
            "blocks_saved_total": state.blocks_saved_total,
            "blocks_saved_now": state.shared_blocks_saved(),
            "dedup_imports": state.dedup_imports,
            "cached_blocks": len(state.prefix_cache)}


# ------------------------------------------------------------- pool writes
def write_tokens(state: PagedState, slot: int, k_new, v_new):
    """Append k/v for S new tokens of one request (k_new/v_new:
    [L, S, KV, hd]). Requires allocate() first; if any touched column is
    shared, the caller must ``ensure_writable`` first (the engine does)."""
    return write_tokens_batch(state, [slot], k_new[:, None], v_new[:, None])


def scatter_plan(state: PagedState, slots, S: int,
                 lengths: Optional[Sequence[int]] = None):
    """Host half of a batched S-token append for G slots: per-token
    (block, offset) scatter indices, flattened [G*S]. Pad positions
    (``>= lengths[i]``) and unallocated columns (window-skipped prefill
    prefixes; -1 would WRAP, not drop) point at the out-of-range block
    ``n_blocks`` so a ``mode="drop"`` scatter discards them. ADVANCES
    ``state.lengths`` and stamps the write epoch — callers must execute
    the device scatter they planned (``write_tokens_batch``, or the
    engine's fused chunk-prefill executable)."""
    bs = state.block_size
    if lengths is None:
        lengths = [S] * len(slots)
    n_pool = state.n_blocks
    max_col = state.block_tables.shape[1] - 1
    blocks, offs = [], []
    for slot, n in zip(slots, lengths):
        start = int(state.lengths[slot])
        pos = np.arange(start, start + S)
        cols = np.minimum(pos // bs, max_col)
        blk = state.block_tables[slot, cols]
        blk = np.where((np.arange(S) < n) & (blk >= 0), blk, n_pool)
        blocks.append(blk)
        offs.append(pos % bs)
        state.lengths[slot] = start + n
    bidx = np.concatenate(blocks)
    mark_written(state, np.unique(bidx))
    return bidx, np.concatenate(offs)


def write_tokens_batch(state: PagedState, slots, k_new, v_new,
                       lengths: Optional[Sequence[int]] = None):
    """Append k/v for up to S new tokens of G requests in ONE pool scatter.

    k_new/v_new: [L, G, S, KV, hd] — S is the (possibly padded) group
    length; ``lengths`` gives each request's TRUE new-token count (default
    S for all). Rows are padded to a shared S by the engine's power-of-two
    prefill buckets; pad positions scatter to an out-of-range block index
    and are dropped, so one executable serves the whole bucket.

    A functional ``.at[].set`` copies the whole pool, so batching a
    G-request admission wave into one scatter per pool costs 2 copies
    instead of 2·G. Requires allocate() first (for the true lengths), and
    — refcount contract — every written column must be OWNED (refcount 1)
    by its slot: the engine forks shared columns via ``ensure_writable``
    before scattering. Returns the updated (functional) device arrays
    stored back into ``state``.
    """
    L, G, S = k_new.shape[:3]
    bidx, oidx = scatter_plan(state, slots, S, lengths)
    bidx = jnp.asarray(bidx, jnp.int32)                     # [G*S]
    oidx = jnp.asarray(oidx, jnp.int32)
    # pool is [L, n_blocks, KV, bs, hd]: advanced indices at axes 1 and 3
    # move to the front, so updates are laid out [G*S, L, KV, hd]
    kf = k_new.reshape(L, G * S, *k_new.shape[3:]).transpose(1, 0, 2, 3)
    vf = v_new.reshape(L, G * S, *v_new.shape[3:]).transpose(1, 0, 2, 3)
    state.k = state.k.at[:, bidx, :, oidx].set(kf.astype(state.k.dtype),
                                               mode="drop")
    state.v = state.v.at[:, bidx, :, oidx].set(vf.astype(state.v.dtype),
                                               mode="drop")
    return state


# --------------------------------------------------- migration wire format
def export_blocks(state: PagedState, slot: int,
                  since_epoch: Optional[int] = None) -> Dict:
    """Serialize one request's KV to the block-granular migration wire
    format (DESIGN.md §block-migration): the live block-table COLUMNS
    (absolute position // block_size — holes from sliding-window freeing
    are preserved), the pool blocks at those columns as host arrays, the
    token count, and — for prefix-registered blocks — their content-chain
    ``keys`` (hex, per column) so the destination can re-seed its own
    prefix cache. SHARED blocks are materialized (content copied into the
    payload): refcounts never cross pools, so the payload is always
    self-contained and import-side correctness cannot depend on the
    source pool's sharing structure. Does NOT free or decref the source
    blocks — callers pair this with ``free_slot`` once the payload is
    safely away.

    ``since_epoch`` selects the DELTA wire format: only columns whose
    current block was written after that epoch (the dirty set since a
    phase-1 snapshot — decode-step appends, CoW forks, new columns) are
    shipped. The payload's ``epoch`` field is the pool's write epoch at
    export time: pass a snapshot's ``epoch`` back as ``since_epoch`` to
    get exactly the writes that landed in between.
    """
    cols = np.nonzero(state.block_tables[slot] >= 0)[0].astype(np.int32)
    if since_epoch is not None and len(cols):
        ids_np = state.block_tables[slot, cols]
        dirty = state.block_epoch[ids_np] > since_epoch
        cols = cols[dirty]
    if len(cols) == 1:
        # the overlapped-migration delta is usually ONE tail block: a
        # static slice + host copy beats the XLA gather by ~10x on CPU
        # pools, and this runs inside the migration's only stall window
        b = int(state.block_tables[slot, cols[0]])
        k = np.asarray(state.k[:, b])[:, None]  # [L, 1, KV, bs, hd]
        v = np.asarray(state.v[:, b])[:, None]
    elif len(cols):
        ids = jnp.asarray(state.block_tables[slot, cols], jnp.int32)
        k = np.asarray(state.k[:, ids])        # [L, n, KV, bs, hd]
        v = np.asarray(state.v[:, ids])
    else:
        L, _, KV, bs, hd = state.k.shape
        k = np.zeros((L, 0, KV, bs, hd), state.k.dtype)
        v = np.zeros((L, 0, KV, bs, hd), state.v.dtype)
    keys = {}
    for c in cols:
        b = int(state.block_tables[slot, c])
        if b in state.block_key:
            keys[int(c)] = state.block_key[b].hex()
    return {"cols": cols, "k": k, "v": v,
            "length": int(state.lengths[slot]),
            "block_size": state.block_size,
            "keys": keys,
            "epoch": state.write_epoch,
            "nbytes": int(k.nbytes + v.nbytes)}


def _register_carried_keys(state: PagedState, slot: int, payload: Dict):
    """Re-seed this pool's prefix cache from a payload's carried keys —
    first binding wins, so resident entries are never displaced."""
    if not state.enable_prefix_cache:
        return
    for c, hexkey in payload.get("keys", {}).items():
        key = bytes.fromhex(hexkey)
        b = int(state.block_tables[slot, int(c)])
        if b < 0 or key in state.prefix_cache or b in state.block_key:
            continue                    # existing binding wins
        state.prefix_cache[key] = b
        state.block_key[b] = key


def import_blocks(state: PagedState, slot: int, payload: Dict) -> PagedState:
    """Materialize an exported request into ``slot`` of (another) pool:
    allocate fresh OWNED (refcount-1) blocks, rebind them at the SAME
    table columns (absolute positions are preserved, so RoPE/window
    masking and the counter-based sampling replay are untouched), and
    scatter the block data in. Carried prefix ``keys`` are re-registered
    into this pool's cache (first binding wins) so admissions AFTER the
    migration can alias the migrated prompt — sharing structure survives
    the hop even though refcounts are pool-local.

    CROSS-INSTANCE DEDUPE: a column whose carried key is already
    resident in this pool's prefix cache ALIASES the resident block
    (incref — possibly reviving it off ``cached_free``) instead of
    materializing a duplicate copy. The content-chain key certifies the
    token prefix, and K/V is a deterministic function of it, so the
    resident content IS the payload content for that column. The aliased
    column arrives SHARED like any prefix hit; writes into it fork first
    (``ensure_writable``), exactly as for a same-pool alias.

    Raises OutOfBlocks without mutating state when the pool or the
    table row can't hold the payload."""
    if payload["block_size"] != state.block_size:
        raise ValueError(
            f"block_size mismatch: payload {payload['block_size']} "
            f"vs pool {state.block_size}")
    if (state.block_tables[slot] >= 0).any():
        raise ValueError(f"import into non-empty slot {slot}")
    cols = np.asarray(payload["cols"], np.int64)
    n = len(cols)
    if n and int(cols.max()) >= state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"import needs column {int(cols.max())}, table holds "
            f"{state.block_tables.shape[1]}")
    alias: Dict[int, int] = {}          # payload index -> resident block
    if state.enable_prefix_cache:
        for i, c in enumerate(cols):
            hexkey = payload.get("keys", {}).get(int(c))
            if hexkey is None:
                continue
            b = state.prefix_cache.get(bytes.fromhex(hexkey))
            if b is not None:
                alias[i] = b
    fresh = [i for i in range(n) if i not in alias]
    # reviving a cached-free resident consumes a unit of vacancy too —
    # account for it so the no-mutation-on-raise contract holds exactly
    revive = len({b for b in alias.values() if int(state.refcount[b]) == 0})
    if len(fresh) > state.free_block_count() - revive:
        raise OutOfBlocks(f"import needs {len(fresh)} blocks, "
                          f"{state.free_block_count() - revive} free")
    for i, b in alias.items():          # incref FIRST: aliased residents
        _incref(state, b)               # must not be evicted by the pops
        state.block_tables[slot, cols[i]] = b
    ids = [_pop_block(state) for _ in fresh]
    for b in ids:
        state.refcount[b] = 1
    if fresh:
        state.block_tables[slot, cols[fresh]] = np.asarray(ids, np.int32)
    state.lengths[slot] = payload["length"]
    state.dedup_imports += len(alias)
    state.blocks_saved_total += len(alias)
    if fresh:
        idx = jnp.asarray(ids, jnp.int32)
        sel = np.asarray(fresh, np.int64)
        state.k = state.k.at[:, idx].set(
            jnp.asarray(payload["k"][:, sel]).astype(state.k.dtype))
        state.v = state.v.at[:, idx].set(
            jnp.asarray(payload["v"][:, sel]).astype(state.v.dtype))
        mark_written(state, ids)
    _register_carried_keys(state, slot, payload)
    return state


def import_blocks_delta(state: PagedState, slot: int,
                        payload: Dict) -> PagedState:
    """Apply a DELTA export (``export_blocks(..., since_epoch=...)``)
    over a previously imported phase-1 base in ``slot`` — the commit
    half of two-phase migration. Columns already staged are overwritten
    in place when the staged block is exclusively owned; a staged block
    that became shared (an admission aliased it) or registered is
    REBOUND to a fresh block instead — overwriting it would corrupt its
    co-holders / its cache key. New columns (decode appends past the
    snapshot) allocate fresh. ``lengths[slot]`` advances to the source's
    pause-time length. Raises OutOfBlocks without mutating state when
    the pool can't hold the new/rebound columns."""
    if payload["block_size"] != state.block_size:
        raise ValueError(
            f"block_size mismatch: payload {payload['block_size']} "
            f"vs pool {state.block_size}")
    cols = np.asarray(payload["cols"], np.int64)
    n = len(cols)
    if n and int(cols.max()) >= state.block_tables.shape[1]:
        raise OutOfBlocks(
            f"delta needs column {int(cols.max())}, table holds "
            f"{state.block_tables.shape[1]}")
    def in_place(b):
        return b >= 0 and int(state.refcount[b]) == 1 \
            and b not in state.block_key
    staged = [int(state.block_tables[slot, c]) for c in cols]
    need = sum(0 if in_place(b) else 1 for b in staged)
    if need > state.free_block_count():
        raise OutOfBlocks(f"delta needs {need} blocks, "
                          f"{state.free_block_count()} free")
    ids = []
    for c, b in zip(cols, staged):
        if in_place(b):
            ids.append(b)
            continue
        nb = _pop_block(state)
        state.refcount[nb] = 1
        if b >= 0:
            _decref(state, b)           # co-holders / cache keep the old one
        state.block_tables[slot, c] = nb
        ids.append(nb)
    state.lengths[slot] = payload["length"]
    if n == 1:
        # the common overlapped-migration delta is ONE tail block: a
        # dynamic_update_slice at the block offset lowers to a cheaper
        # kernel than a gather-scatter (~2x on CPU pools), and this op
        # sits inside the migration's only stall window
        kd = jnp.asarray(payload["k"]).astype(state.k.dtype)
        vd = jnp.asarray(payload["v"]).astype(state.v.dtype)
        at = (0, ids[0], 0, 0, 0)
        state.k = jax.lax.dynamic_update_slice(state.k, kd, at)
        state.v = jax.lax.dynamic_update_slice(state.v, vd, at)
        mark_written(state, ids)
    elif n:
        idx = jnp.asarray(ids, jnp.int32)
        state.k = state.k.at[:, idx].set(
            jnp.asarray(payload["k"]).astype(state.k.dtype))
        state.v = state.v.at[:, idx].set(
            jnp.asarray(payload["v"]).astype(state.v.dtype))
        mark_written(state, ids)
    _register_carried_keys(state, slot, payload)
    return state


# ------------------------------------------------------------ dense views
def gather_requests(state: PagedState, slots: Sequence[int], max_len: int):
    """Materialize G requests' KV as dense [L, G, max_len, KV, hd] in ONE
    batched pool gather — the context splice for BUCKETED shared-prefix
    suffix prefill (a whole hit group's contexts in one device op
    instead of one gather per request). Rows past a slot's allocated
    columns are garbage — callers mask by position."""
    bs = state.block_size
    n_blk = -(-max_len // bs)
    G = len(slots)
    tbl = state.block_tables[np.asarray(slots, np.int64), :n_blk]
    tbl = jnp.asarray(np.where(tbl >= 0, tbl, 0), jnp.int32)   # [G, n_blk]
    L, _, KV, _, hd = state.k.shape
    k = state.k[:, tbl]                       # [L, G, n_blk, KV, bs, hd]
    v = state.v[:, tbl]
    k = k.transpose(0, 1, 2, 4, 3, 5).reshape(
        L, G, n_blk * bs, KV, hd)[:, :, :max_len]
    v = v.transpose(0, 1, 2, 4, 3, 5).reshape(
        L, G, n_blk * bs, KV, hd)[:, :, :max_len]
    return k, v


def gather_request(state: PagedState, slot: int, max_len: int):
    """Single-request ``gather_requests`` (oracle / fallback path):
    dense [L, max_len, KV, hd]."""
    k, v = gather_requests(state, [slot], max_len)
    return k[:, 0], v[:, 0]


def paged_attention_ref(q, state: PagedState, slots, *, layer: int):
    """Pure-jnp paged decode attention for a batch of slots, vectorized
    over the batch (one batched gather + masked softmax — no per-slot
    Python loop, so oracle checks don't dominate test time).

    q: [B, H, hd]; returns [B, H, hd]. Oracle for kernels/paged_decode.py
    and for models.transformer.forward_paged's gather path.
    """
    import math
    B, H, hd = q.shape
    KV = state.k.shape[2]
    bs = state.block_size
    rep = H // KV
    slots = list(slots)
    lens = state.lengths[slots]                      # [B] host
    n_blk = max(1, -(-int(lens.max()) // bs))
    tbl = state.block_tables[slots, :n_blk]
    tbl = jnp.asarray(np.where(tbl >= 0, tbl, 0), jnp.int32)
    k = state.k[layer][tbl]                          # [B, n_blk, KV, bs, hd]
    v = state.v[layer][tbl]
    k = k.transpose(0, 1, 3, 2, 4).reshape(B, n_blk * bs, KV, hd)
    v = v.transpose(0, 1, 3, 2, 4).reshape(B, n_blk * bs, KV, hd)
    kh = jnp.repeat(k, rep, axis=2).astype(jnp.float32)  # [B, S, H, hd]
    vh = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kh) / math.sqrt(hd)
    mask = jnp.arange(n_blk * bs)[None, :] < jnp.asarray(lens)[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, vh).astype(q.dtype)
