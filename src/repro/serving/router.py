"""Request routing policies for the serving front door.

The ingress (serving/ingress.py) and the orchestrator's ``submit`` both
answer the same question: WHICH instance should take this request? Before
this module the answer was hardcoded vacancy (most free pool blocks);
now it is a swappable policy object, and the default exploits the one
signal only the router can see pod-wide: PR 3's content-chain prefix
keys.

**Prefix-affinity routing** (``PrefixAffinityRouter``, the default,
after Ray Serve's prefix-aware LLMRouter): the router hashes the
incoming prompt through ``paged_kv._chain_keys`` — the SAME chain hash
the engines key their prefix caches by, so "the router thinks instance
i holds this prefix" and "instance i's cache hits on it" can never
disagree about what a match means — and prefers the instance whose
resident key set covers the LONGEST leading chain of the prompt. A hit
routed to its chain holder prefills only the suffix and allocates no
blocks for the shared span; the same request routed anywhere else
re-prefills and re-stores the whole prefix. Resident key sets ride the
step replies (``EngineServer.info["prefix_keys"]``), so the router's
view refreshes once per orchestrator step with zero extra RPCs — it can
be one step stale, which costs a miss, never correctness.

When no chain matches (or scores tie) the policy falls back to the
orchestrator's historical order: most free pool blocks, then shortest
queue, then lowest index — fully deterministic, asserted by
tests/test_router.py.

**Admission backpressure**: ``select`` only considers instances whose
queue (plus tokens the ingress has accepted but not yet submitted — the
``pending`` map) is below ``max_queue``. When NO alive instance is
admissible it returns None and the ingress answers 429 + Retry-After
instead of queueing unboundedly — load shedding at the front door, not
OOM at the pool.

**SLO-aware admission**: the decision sees the full ``RequestSpec``
(serving/request.py), not just the prompt. Batch-class requests get one
seat LESS of queue headroom per instance: under sustained pressure the
pod sheds batch traffic a beat before it sheds interactive/standard
traffic, so the latency classes always find the last seat. (Scheduling
WITHIN an admitted queue is the engine scheduler's job — the router
only decides who gets through the door.)

``RoundRobinRouter`` is the affinity-blind baseline the ingress bench
measures against (BENCH_ingress.json's >= 1.5x pod-wide hit-rate gate).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.serving import paged_kv as PK


@dataclasses.dataclass
class RouteDecision:
    """One routing verdict: the chosen instance, how many leading prompt
    blocks its prefix cache already holds, and which rule decided
    (``"prefix"`` when the chain match broke the tie, ``"vacancy"``
    otherwise)."""
    idx: int
    matched_blocks: int = 0
    reason: str = "vacancy"

    def as_event(self) -> dict:
        """Flight-recorder payload for this verdict (observe.FlightRecorder
        ``route`` events) — plain dict, msgpack/JSON-safe."""
        return {"idx": self.idx, "matched_blocks": self.matched_blocks,
                "reason": self.reason}


def chain_hexkeys(prompt, block_size: int) -> List[str]:
    """The prompt's content-chain keys (one per FULL block), hex-encoded
    to match the resident sets handles export over the wire."""
    if prompt is None or block_size <= 0:
        return []
    return [k.hex() for k in PK._chain_keys(prompt, block_size)]


class RouterPolicy:
    """Interface: pick one of ``among`` (indices into ``handles``) for a
    request, or None when admission must back off. ``spec`` is the
    request's ``RequestSpec`` (admission is class-aware — see module
    docstring); ``prompt`` alone still works for spec-less internal
    callers (replay, migration re-homing). ``pending`` maps instance
    index -> requests accepted upstream (by the ingress) but not yet
    visible in ``queue_len`` — the router charges them so a burst
    cannot over-admit between steps."""

    def select(self, handles: Sequence, among: Sequence[int], *,
               spec=None, prompt=None,
               pending: Optional[Dict[int, int]] = None,
               max_queue: Optional[int] = None) -> Optional[RouteDecision]:
        raise NotImplementedError


def _load(handles, idx: int, pending: Dict[int, int]):
    """The vacancy-order key the orchestrator has always routed by:
    most free blocks first, then shortest (queue + pending), then lowest
    index — the deterministic tiebreak."""
    h = handles[idx]
    return (-h.free_blocks(), h.queue_len() + pending.get(idx, 0), idx)


def _headroom(spec, max_queue) -> Optional[int]:
    """Class-adjusted admission bound: batch traffic may not take an
    instance's LAST queue seat (when there is more than one)."""
    if max_queue is None or spec is None:
        return max_queue
    if getattr(spec, "slo_class", "standard") == "batch" and max_queue > 1:
        return max_queue - 1
    return max_queue


def _admissible(handles, among, pending, max_queue) -> List[int]:
    if max_queue is None:
        return list(among)
    return [i for i in among
            if handles[i].queue_len() + pending.get(i, 0) < max_queue]


class PrefixAffinityRouter(RouterPolicy):
    """The default pod router (module docstring). ``min_match`` is the
    affinity floor: chains shorter than this many blocks are noise (a
    one-block match saves less than an imbalanced queue costs) and fall
    through to vacancy order."""

    def __init__(self, min_match: int = 1):
        self.min_match = max(1, int(min_match))

    def _matched(self, handle, keys: List[str]) -> int:
        """Longest LEADING run of the prompt's chain resident at this
        handle. Leading is the point: chain key c certifies tokens
        [0, (c+1)*bs) only when every earlier block is there to alias."""
        if not keys:
            return 0
        resident = handle.prefix_keys()
        if not resident:
            return 0
        n = 0
        for k in keys:
            if k not in resident:
                break
            n += 1
        return n

    def select(self, handles, among, *, spec=None, prompt=None,
               pending=None, max_queue=None) -> Optional[RouteDecision]:
        pending = pending or {}
        if prompt is None and spec is not None:
            prompt = spec.prompt
        cands = _admissible(handles, among, pending,
                            _headroom(spec, max_queue))
        if not cands:
            return None
        best = None
        if prompt is not None:
            # per-candidate block size: a heterogeneous pod hashes per
            # instance (chain keys are block-size-dependent)
            by_bs: Dict[int, List[str]] = {}
            scored = []
            for i in cands:
                bs = handles[i].block_size
                keys = by_bs.setdefault(bs, chain_hexkeys(prompt, bs))
                scored.append((self._matched(handles[i], keys), i))
            top = max(m for m, _ in scored)
            if top >= self.min_match:
                tied = [i for m, i in scored if m == top]
                idx = min(tied, key=lambda i: _load(handles, i, pending))
                best = RouteDecision(idx, matched_blocks=top,
                                     reason="prefix")
        if best is None:
            idx = min(cands, key=lambda i: _load(handles, i, pending))
            best = RouteDecision(idx)
        return best


class VacancyRouter(RouterPolicy):
    """Pure load routing — the pre-ingress ``Orchestrator.submit``
    behavior, kept as an explicit policy (and the affinity router's
    fallback order)."""

    def select(self, handles, among, *, spec=None, prompt=None,
               pending=None, max_queue=None) -> Optional[RouteDecision]:
        pending = pending or {}
        cands = _admissible(handles, among, pending,
                            _headroom(spec, max_queue))
        if not cands:
            return None
        return RouteDecision(min(cands,
                                 key=lambda i: _load(handles, i, pending)))


class RoundRobinRouter(RouterPolicy):
    """Affinity-blind baseline (bench control arm): strict rotation over
    the admissible candidates, skipping full ones."""

    def __init__(self):
        self._next = 0

    def select(self, handles, among, *, spec=None, prompt=None,
               pending=None, max_queue=None) -> Optional[RouteDecision]:
        pending = pending or {}
        cands = _admissible(handles, among, pending,
                            _headroom(spec, max_queue))
        if not cands:
            return None
        idx = cands[self._next % len(cands)]
        self._next += 1
        return RouteDecision(idx)
