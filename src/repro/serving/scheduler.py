"""Token-budget continuous batching policy (Sarathi-Serve / vLLM style).

The engine's original admission was two-phase: a prefill WAVE (whole
prompts, one bucketed forward) alternating with decode steps. One long
prompt therefore stalled every in-flight decode for its full prefill,
and the batch ran under-full on mixed workloads. This module replaces
the phase split with ONE policy over one queue: every step packs a fixed
per-step TOKEN BUDGET with

* one token per ACTIVE decode slot (decode-first: a running stream never
  skips a step because of admission work), then
* prefill CHUNKS for slots already mid-prefill (oldest first — finish
  what was started, so time-to-first-token is monotone per request), then
* prompt prefixes for WAITING queue heads (FIFO), whole prompts when the
  remaining budget covers them, otherwise one bounded first chunk.

The scheduler is pure POLICY: ``plan`` reads engine state (active /
prefilling / queue / pool) and returns grants; it never mutates the
engine or the pool. The engine executes grants and applies its existing
mechanisms — block allocation with backpressure (a grant that finds no
blocks is simply not executed and retries next step), never-fits
rejection, copy-on-write forks — so the OutOfBlocks semantics of the
phase engine carry over unchanged. Youngest-first preemption is likewise
expressed here (``victims``) as an ordering policy over the one
admission order shared by decoding and prefilling slots.

Non-final chunks are rounded DOWN to a multiple of the block size so a
persisted prefill cursor always sits on a block boundary: context
gathers stay full-block and prefix registration never sees a
half-written block.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class ChunkGrant:
    """Permission to run ``n_tokens`` of one request's prefill this step.

    ``slot is None`` marks a WAITING request (still at the queue head —
    the engine pops it on execution); otherwise the request is already
    mid-prefill in ``slot`` and this is a continuation chunk. ``final``
    says the grant reaches the end of the prompt, so the engine samples
    the first token and moves the request into decode rotation."""
    req: object
    slot: Optional[int]
    start: int
    n_tokens: int
    final: bool


@dataclasses.dataclass
class StepPlan:
    """One step's packing: how many decode tokens ride along, and which
    prefill grants fill the rest of the budget."""
    n_decode: int
    grants: List[ChunkGrant]
    budget: int

    @property
    def packed(self) -> int:
        return self.n_decode + sum(g.n_tokens for g in self.grants)

    @property
    def utilization(self) -> float:
        return self.packed / self.budget if self.budget else 0.0


class TokenBudgetScheduler:
    """The default paged-engine scheduler (``Engine(scheduler=
    "token_budget")``). ``chunk_align`` is the engine's block size."""

    def __init__(self, token_budget: int = 128, chunk_align: int = 16):
        assert token_budget > 0, token_budget
        self.token_budget = int(token_budget)
        self.chunk_align = max(int(chunk_align), 1)

    def _align(self, n: int) -> int:
        """Largest block-aligned chunk not exceeding ``n`` (0 = too small
        to be worth a partial grant this step)."""
        return n - n % self.chunk_align

    def plan(self, engine) -> StepPlan:
        """Pack one step. Decode slots are charged first so prefill can
        never crowd out running streams; the leftover budget goes to
        in-flight prefills (oldest first), then the queue FIFO. At most
        the LAST fresh grant is partial — the budget ran out on it."""
        n_decode = len(engine.active)
        remaining = self.token_budget - n_decode
        grants: List[ChunkGrant] = []
        for slot in list(engine._admit_order):
            req = engine.prefilling.get(slot)
            if req is None:
                continue
            if remaining <= 0:
                break
            left = engine.prefill_total(req) - req.prefill_pos
            n = left if left <= remaining else self._align(remaining)
            if n <= 0:
                continue
            grants.append(ChunkGrant(req, slot, req.prefill_pos, n,
                                     final=(n == left)))
            remaining -= n
        free = len(engine._free_slots()) - sum(
            1 for g in grants if g.slot is None)
        for req in engine.queue:
            if free <= 0 or remaining <= 0:
                break
            total = engine.prefill_total(req)
            n = total if total <= remaining else self._align(remaining)
            if n <= 0:
                break               # FIFO: never skip past the head
            grants.append(ChunkGrant(req, None, 0, n, final=(n == total)))
            remaining -= n
            free -= 1
            if n < total:
                break               # the partial grant drained the budget
        return StepPlan(n_decode, grants, self.token_budget)

    def victims(self, engine) -> List[int]:
        """Preemption order under pool pressure: every slot holding
        blocks (decoding or mid-prefill), oldest first — preempt from
        the tail (youngest), vLLM-style. Mid-prefill slots are ordinary
        victims: their cursor resets and the chunks replay."""
        return [s for s in engine._admit_order
                if s in engine.active or s in engine.prefilling]
