"""Pluggable step-scheduling policies over the paged engine.

The engine's original admission was two-phase: a prefill WAVE (whole
prompts, one bucketed forward) alternating with decode steps. PR 7
replaced that with token-budget continuous batching (Sarathi-Serve /
vLLM style): every step packs a fixed per-step TOKEN BUDGET with

* one token per ACTIVE decode slot (decode-first: a running stream never
  skips a step because of admission work), then
* prefill CHUNKS for slots already mid-prefill (oldest first — finish
  what was started, so time-to-first-token is monotone per request), then
* prompt prefixes for WAITING requests, whole prompts when the
  remaining budget covers them, otherwise one bounded first chunk.

This module makes that policy PLUGGABLE. ``SchedulerPolicy`` is the
interface (``plan`` packs one step, ``victims`` orders preemption), a
name registry maps ``Engine(scheduler=...)`` strings to classes, and
three policies ship:

* ``"budget"`` (alias ``"token_budget"``) — the FIFO token-budget
  packer above, unchanged semantics;
* ``"phase"`` — the legacy wave/decode loop. Its admission lives in the
  engine (``_admit_paged``), so ``plan`` is never called; it exists in
  the registry so the engine resolves every scheduler the same way and
  still gets a ``victims`` ordering from the policy object;
* ``"slo"`` — class-aware packing (``SloScheduler``): the budget is
  split across SLO classes in strict priority order
  (interactive > standard > batch), with deadline-aware ordering within
  a class and preemption that victimizes batch work youngest-first
  before ever touching an interactive stream.

Every policy is pure: ``plan`` reads engine state (active / prefilling /
queue / pool) and returns grants; it never mutates the engine or the
pool. The engine executes grants and applies its existing mechanisms —
block allocation with backpressure (a grant that finds no blocks is
simply not executed and retries next step), never-fits rejection,
copy-on-write forks — so the OutOfBlocks semantics of the phase engine
carry over unchanged.

Non-final chunks are rounded DOWN to a multiple of the block size so a
persisted prefill cursor always sits on a block boundary: context
gathers stay full-block and prefix registration never sees a
half-written block.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Type

from repro.serving.request import SLO_CLASSES


@dataclasses.dataclass
class ChunkGrant:
    """Permission to run ``n_tokens`` of one request's prefill this step.

    ``slot is None`` marks a WAITING request (still in the queue —
    the engine pops it on execution); otherwise the request is already
    mid-prefill in ``slot`` and this is a continuation chunk. ``final``
    says the grant reaches the end of the prompt, so the engine samples
    the first token and moves the request into decode rotation."""
    req: object
    slot: Optional[int]
    start: int
    n_tokens: int
    final: bool


@dataclasses.dataclass
class StepPlan:
    """One step's packing: how many decode tokens ride along, and which
    prefill grants fill the rest of the budget."""
    n_decode: int
    grants: List[ChunkGrant]
    budget: int

    @property
    def packed(self) -> int:
        return self.n_decode + sum(g.n_tokens for g in self.grants)

    @property
    def utilization(self) -> float:
        return self.packed / self.budget if self.budget else 0.0


@dataclasses.dataclass
class SloStepPlan(StepPlan):
    """A ``StepPlan`` that also reports how the prefill budget was split
    across SLO classes (``class_tokens[cls]`` = prefill tokens granted
    to that class this step). The split is an output, not a quota: the
    policy is strict-priority with spill, so the shares always sum to
    exactly the granted prefill tokens."""
    class_tokens: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in SLO_CLASSES})


class SchedulerPolicy:
    """Interface every step scheduler implements. Policies are pure:
    they read engine state and return orderings; the engine owns all
    mutation (pops, allocation, preemption)."""

    #: registry key the policy was resolved under
    name: str = "?"
    #: True when the engine should drive ``_admit_budget`` (plan-based
    #: packing); False for the legacy engine-driven phase loop.
    budgeted: bool = True

    def plan(self, engine) -> StepPlan:
        raise NotImplementedError

    def victims(self, engine) -> List[int]:
        """Preemption order under pool pressure: every slot holding
        blocks (decoding or mid-prefill), preferred victims LAST —
        the engine preempts from the tail of this list."""
        raise NotImplementedError


# ------------------------------------------------------------- registry
POLICIES: Dict[str, Type[SchedulerPolicy]] = {}


def register_policy(*names: str):
    """Class decorator: expose a policy under one or more registry
    names (the first is canonical, the rest are aliases)."""
    def deco(cls):
        cls.name = names[0]
        for n in names:
            POLICIES[n] = cls
        return cls
    return deco


def make_scheduler(name: str, *, token_budget: int = 128,
                   chunk_align: int = 16) -> SchedulerPolicy:
    """Resolve a registry name to a policy instance. Unknown names
    raise with the full menu so a typo in ``--scheduler`` fails fast."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r} "
            f"(registered: {', '.join(sorted(POLICIES))})") from None
    return cls(token_budget=token_budget, chunk_align=chunk_align)


def _slo_class(req) -> str:
    cls = getattr(req, "slo_class", "standard")
    return cls if cls in SLO_CLASSES else "standard"


@register_policy("budget", "token_budget")
class TokenBudgetScheduler(SchedulerPolicy):
    """The default paged-engine scheduler (``Engine(scheduler=
    "budget")``). ``chunk_align`` is the engine's block size."""

    def __init__(self, token_budget: int = 128, chunk_align: int = 16):
        assert token_budget > 0, token_budget
        self.token_budget = int(token_budget)
        self.chunk_align = max(int(chunk_align), 1)

    def _align(self, n: int) -> int:
        """Largest block-aligned chunk not exceeding ``n`` (0 = too small
        to be worth a partial grant this step)."""
        return n - n % self.chunk_align

    def plan(self, engine) -> StepPlan:
        """Pack one step. Decode slots are charged first so prefill can
        never crowd out running streams; the leftover budget goes to
        in-flight prefills (oldest first), then the queue FIFO. At most
        the LAST fresh grant is partial — the budget ran out on it."""
        n_decode = len(engine.active)
        remaining = self.token_budget - n_decode
        grants: List[ChunkGrant] = []
        for slot in list(engine._admit_order):
            req = engine.prefilling.get(slot)
            if req is None:
                continue
            if remaining <= 0:
                break
            left = engine.prefill_total(req) - req.prefill_pos
            n = left if left <= remaining else self._align(remaining)
            if n <= 0:
                continue
            grants.append(ChunkGrant(req, slot, req.prefill_pos, n,
                                     final=(n == left)))
            remaining -= n
        free = len(engine._free_slots()) - sum(
            1 for g in grants if g.slot is None)
        for req in engine.queue:
            if free <= 0 or remaining <= 0:
                break
            total = engine.prefill_total(req)
            n = total if total <= remaining else self._align(remaining)
            if n <= 0:
                break               # FIFO: never skip past the head
            grants.append(ChunkGrant(req, None, 0, n, final=(n == total)))
            remaining -= n
            free -= 1
            if n < total:
                break               # the partial grant drained the budget
        return StepPlan(n_decode, grants, self.token_budget)

    def victims(self, engine) -> List[int]:
        """Preemption order under pool pressure: every slot holding
        blocks (decoding or mid-prefill), oldest first — preempt from
        the tail (youngest), vLLM-style. Mid-prefill slots are ordinary
        victims: their cursor resets and the chunks replay."""
        return [s for s in engine._admit_order
                if s in engine.active or s in engine.prefilling]


@register_policy("phase")
class PhaseScheduler(SchedulerPolicy):
    """The legacy wave/decode loop, as a registry entry. Admission is
    engine-driven (``Engine._admit_paged`` / the dense batcher), so the
    engine never calls ``plan`` — only the preemption ordering is policy
    here, and it matches the budget scheduler's."""

    budgeted = False

    def __init__(self, token_budget: int = 0, chunk_align: int = 16):
        # accepted for registry-signature uniformity; the phase loop has
        # no per-step token budget.
        self.token_budget = 0
        self.chunk_align = max(int(chunk_align), 1)

    def plan(self, engine) -> StepPlan:
        raise NotImplementedError(
            "phase admission is engine-driven; plan() is never called")

    def victims(self, engine) -> List[int]:
        return [s for s in engine._admit_order
                if s in engine.active or s in engine.prefilling]


@register_policy("slo")
class SloScheduler(TokenBudgetScheduler):
    """Class-aware token-budget packing.

    The step budget is split across SLO classes in STRICT PRIORITY
    order with spill — interactive work is charged first, standard
    takes what interactive left, batch prefill chunks are sized from
    whatever remains. The split is therefore work-conserving (an idle
    interactive class donates its entire share down), which is what
    keeps total throughput within a hair of the FIFO packer while
    interactive TTFT collapses.

    Within one class: continuation chunks first (admit order — finish
    what was started), then fresh admissions ordered by deadline
    (earliest ``deadline_ms`` first, deadline-less requests after, FIFO
    among ties — Python's stable sort gives this for free).

    Fresh admission stops globally the moment any class's next-in-line
    cannot fit (alignment or budget): lower classes may not steal the
    free SLOT that the blocked higher-class request needs next step.
    That is the scheduling half of "interactive is never stalled by
    batch work"; the preemption half is ``victims`` putting batch slots
    youngest-first at the preferred end, so pool pressure never evicts
    an interactive stream while any batch slot still holds blocks."""

    def _deadline_key(self, req):
        d = getattr(req, "deadline_ms", None)
        return (0, d) if d is not None else (1, 0.0)

    def plan(self, engine) -> SloStepPlan:
        n_decode = len(engine.active)
        remaining = self.token_budget - n_decode
        grants: List[ChunkGrant] = []
        class_tokens = {c: 0 for c in SLO_CLASSES}
        free = len(engine._free_slots())
        fresh_blocked = False       # a higher class couldn't admit: no
        partial_used = False        # lower class may take its slot
        for cls in SLO_CLASSES:
            if remaining <= 0:
                break
            # continuations of this class, oldest first
            for slot in list(engine._admit_order):
                req = engine.prefilling.get(slot)
                if req is None or _slo_class(req) != cls:
                    continue
                if remaining <= 0:
                    break
                left = engine.prefill_total(req) - req.prefill_pos
                n = left if left <= remaining else self._align(remaining)
                if n <= 0:
                    continue
                grants.append(ChunkGrant(req, slot, req.prefill_pos, n,
                                         final=(n == left)))
                class_tokens[cls] += n
                remaining -= n
            # fresh admissions of this class, deadline order (stable)
            if fresh_blocked or partial_used:
                continue
            waiting = [r for r in engine.queue if _slo_class(r) == cls]
            waiting.sort(key=self._deadline_key)
            for req in waiting:
                if free <= 0 or remaining <= 0:
                    break
                total = engine.prefill_total(req)
                n = total if total <= remaining else self._align(remaining)
                if n <= 0:
                    fresh_blocked = True
                    break           # within a class: never skip ahead
                grants.append(ChunkGrant(req, None, 0, n,
                                         final=(n == total)))
                class_tokens[cls] += n
                remaining -= n
                free -= 1
                if n < total:       # at most ONE partial fresh grant
                    partial_used = True
                    break
        return SloStepPlan(n_decode, grants, self.token_budget,
                           class_tokens)

    def victims(self, engine) -> List[int]:
        """Preemption order: batch slots are sacrificed youngest-first,
        then standard, and interactive streams only when nothing else
        holds blocks. The engine preempts from the TAIL, so the list is
        [interactive oldest..youngest, standard ..., batch ...]."""
        held = [s for s in engine._admit_order
                if s in engine.active or s in engine.prefilling]

        def req_of(s):
            return engine.active.get(s) or engine.prefilling.get(s)

        rank = {c: i for i, c in enumerate(SLO_CLASSES)}
        # stable sort: admit order (oldest first) preserved within a
        # class, batch classes pushed toward the tail
        return sorted(held, key=lambda s: rank[_slo_class(req_of(s))])
