"""Deterministic fault injection for the distributed serving plane.

The failure-domain layer (DESIGN.md §9) is only trustworthy if its
recovery paths can be *driven*, repeatably, through the same wire the
real failures arrive on. This module is that driver: a ``FaultPlan`` is
a seeded schedule of per-peer fault events, and a ``FaultInjector``
replays it inside ``transport.Connection.send`` via the
``set_fault_hook`` seam — so a "partition" is literally frames that
never reach the wire, not a mock.

Determinism: events are keyed on each peer's *send-op index* (the n-th
frame sent to that peer since the injector was installed), never on
wall-clock time — the same plan against the same driving sequence
faults the same frames, byte for byte. The one wall-clock-shaped event,
``kill``, is keyed on an orchestrator *step index* and executed by the
driving loop (``kills_due``), not by the hook, because killing a
process is not a send-side effect.

Fault kinds:

* ``delay``     — sleep ``delay_s`` before delivering one frame;
* ``drop``      — swallow exactly one frame (a lost request: the peer
                  stays healthy, only that call never happens);
* ``half_open`` — from ``at_op`` on, swallow EVERY frame to the peer
                  while its socket stays open (the classic blackhole:
                  deadline-detection territory, never TransportClosed);
* ``partition`` — swallow frames for a window of ``span`` ops, then
                  heal (a transient partition a probe may outwait);
* ``kill``      — SIGKILL the peer's process at step ``at_step``
                  (driver-executed; real process death, real EOF).

Peers are addressed by ``Connection.peer_label`` — ``launch_pod``
labels its proxies ``w0..wN-1`` and a respawned worker gets an
incarnation suffix (``w1~r1``), so a static plan never re-targets the
replacement of a peer it already killed.

``REPRO_FAULTS=<plan.json>`` installs a serialized plan at transport
import (see ``transport._install_env_faults``). Worker processes
inherit the variable but only hold unlabeled connections, so the plan
is inert in them.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

KINDS = ("delay", "drop", "half_open", "partition", "kill")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault against one peer. ``at_op`` is the per-peer
    send-op index (ignored for ``kill``); ``at_step`` is the driving
    loop's step index (``kill`` only); ``span`` is the op-window width
    (``partition`` only); ``delay_s`` (``delay`` only)."""
    peer: str
    kind: str
    at_op: int = 0
    span: int = 1
    delay_s: float = 0.0
    at_step: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {KINDS})")


@dataclasses.dataclass
class FaultPlan:
    """A reproducible schedule of fault events (JSON round-trippable
    for the ``REPRO_FAULTS`` environment hook)."""
    events: List[FaultEvent] = dataclasses.field(default_factory=list)
    seed: Optional[int] = None

    @classmethod
    def seeded(cls, seed: int, peers: Sequence[str], *,
               kill_window: Tuple[int, int] = (2, 6),
               hang_window: Tuple[int, int] = (8, 16),
               partition_window: Tuple[int, int] = (8, 16),
               partition_span: int = 64,
               n_delays: int = 4,
               delay_s: float = 0.02,
               delay_window: Tuple[int, int] = (0, 40)) -> "FaultPlan":
        """The ISSUE-6 chaos mix — ONE kill (at a step drawn from
        ``kill_window``), ONE hang (half-open from an op in
        ``hang_window``), ONE partition (op window), plus ``n_delays``
        sprinkled delays per peer — drawn deterministically from
        ``seed``. Peer roles are a seeded shuffle of ``peers``; with
        fewer than three peers roles overlap (first fault to fire
        wins)."""
        rng = np.random.default_rng(seed)
        order = list(peers)
        rng.shuffle(order)
        kill = order[0]
        hang = order[1 % len(order)]
        part = order[2 % len(order)]
        events = [
            FaultEvent(peer=kill, kind="kill",
                       at_step=int(rng.integers(*kill_window))),
            FaultEvent(peer=hang, kind="half_open",
                       at_op=int(rng.integers(*hang_window))),
            FaultEvent(peer=part, kind="partition",
                       at_op=int(rng.integers(*partition_window)),
                       span=partition_span),
        ]
        for peer in peers:
            for _ in range(n_delays):
                events.append(FaultEvent(
                    peer=peer, kind="delay",
                    at_op=int(rng.integers(*delay_window)),
                    delay_s=delay_s))
        return cls(events=events, seed=seed)

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "events": [dataclasses.asdict(e) for e in self.events]}

    @classmethod
    def from_json(cls, doc: dict) -> "FaultPlan":
        return cls(events=[FaultEvent(**e) for e in doc.get("events", [])],
                   seed=doc.get("seed"))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


class FaultInjector:
    """Replays a ``FaultPlan`` against labeled connections. One op
    counter per peer label, advanced on every send the hook sees —
    including swallowed ones, so the schedule is insensitive to its own
    effects. ``arm`` adds events dynamically (tests aim a fault at "the
    very next send" without precomputing op indices)."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self._sent: Dict[str, int] = {}
        self._delays: Dict[str, Dict[int, float]] = {}
        self._drops: Dict[str, set] = {}
        self._half_open: Dict[str, int] = {}
        self._partitions: Dict[str, List[Tuple[int, int]]] = {}
        self._kills: Dict[int, List[str]] = {}
        self.injected = {k: 0 for k in KINDS}
        for ev in self.plan.events:
            self._index(ev)

    def _index(self, ev: FaultEvent):
        if ev.kind == "delay":
            self._delays.setdefault(ev.peer, {})[ev.at_op] = ev.delay_s
        elif ev.kind == "drop":
            self._drops.setdefault(ev.peer, set()).add(ev.at_op)
        elif ev.kind == "half_open":
            cur = self._half_open.get(ev.peer)
            self._half_open[ev.peer] = (ev.at_op if cur is None
                                        else min(cur, ev.at_op))
        elif ev.kind == "partition":
            self._partitions.setdefault(ev.peer, []).append(
                (ev.at_op, ev.at_op + ev.span))
        elif ev.kind == "kill":
            self._kills.setdefault(ev.at_step, []).append(ev.peer)

    def arm(self, peer: str, kind: str, at_op: Optional[int] = None, **kw):
        """Schedule one more event; ``at_op=None`` targets the peer's
        NEXT send."""
        if at_op is None and kind != "kill":
            at_op = self._sent.get(peer, 0)
        ev = FaultEvent(peer=peer, kind=kind, at_op=at_op or 0, **kw)
        self.plan.events.append(ev)
        self._index(ev)

    def on_send(self, peer: str) -> bool:
        """The hook body: advance ``peer``'s op counter, apply any
        delay, and return False if the frame must be swallowed."""
        op = self._sent.get(peer, 0)
        self._sent[peer] = op + 1
        deliver = True
        start = self._half_open.get(peer)
        if start is not None and op >= start:
            self.injected["half_open"] += 1
            deliver = False
        elif any(lo <= op < hi
                 for lo, hi in self._partitions.get(peer, ())):
            self.injected["partition"] += 1
            deliver = False
        elif op in self._drops.get(peer, ()):
            self.injected["drop"] += 1
            deliver = False
        delay = self._delays.get(peer, {}).get(op)
        if delay:
            self.injected["delay"] += 1
            time.sleep(delay)
        return deliver

    def kills_due(self, step: int) -> List[str]:
        """Peers whose ``kill`` event fires at ``step`` (consumed:
        asking again returns []). The DRIVER executes these — process
        death is not a send-side effect."""
        peers = self._kills.pop(step, [])
        self.injected["kill"] += len(peers)
        return peers

    def ops_sent(self, peer: str) -> int:
        return self._sent.get(peer, 0)

    def total_injected(self) -> int:
        return sum(self.injected.values())


# ------------------------------------------------------ global install
_ACTIVE: Optional[FaultInjector] = None


def _hook(conn) -> bool:
    inj = _ACTIVE
    if inj is None or conn.peer_label is None:
        return True
    return inj.on_send(conn.peer_label)


def install(plan_or_injector) -> FaultInjector:
    """Activate fault injection process-wide (labeled connections
    only). Returns the live injector so drivers can ``arm`` /
    ``kills_due`` / read counters."""
    global _ACTIVE
    inj = (plan_or_injector if isinstance(plan_or_injector, FaultInjector)
           else FaultInjector(plan_or_injector))
    _ACTIVE = inj
    from repro.serving import transport as TR
    TR.set_fault_hook(_hook)
    return inj


def install_from_file(path: str) -> FaultInjector:
    return install(FaultPlan.load(path))


def uninstall():
    global _ACTIVE
    _ACTIVE = None
    from repro.serving import transport as TR
    TR.set_fault_hook(None)


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def injected_total() -> int:
    """Process-wide injected-fault count (0 with no injector) — the
    ``faults_injected`` gauge in ``MetricsSnapshot``."""
    return _ACTIVE.total_injected() if _ACTIVE is not None else 0
