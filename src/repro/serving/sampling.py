"""Fused batched token sampling — entirely on device, jit-friendly.

One call samples next tokens for every batch slot at once: greedy rows
(``temperature <= 0``) take an argmax, stochastic rows use the Gumbel-max
trick (argmax of ``logits/T + Gumbel noise`` equals a categorical draw) so
no row ever needs a host round-trip or a per-slot ``jax.random.choice``.

Determinism is counter-based: each row's PRNG key is
``fold_in(fold_in(PRNGKey(0), seed), counter)`` where ``counter`` is the
number of tokens the request has already generated. Replaying a request —
including after a preemption/resume cycle in the paged engine — reproduces
the exact same continuation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# This sampler is pure argmax (Gumbel-max, no softmax), so masks use real
# -inf: a finite large-negative would stop masking once temperature scales
# it below the Gumbel noise spread, letting padding/top-k-masked ids win.
NEG_INF = float("-inf")


def sample_tokens(logits, temps, top_ks, seeds, counters, vocab_size: int,
                  stochastic: bool = True, max_top_k: int = -1):
    """Sample one token per row.

    logits: [B, Vpad] float; temps: [B] float32 (<=0 means greedy);
    top_ks: [B] int32 (0 means full distribution); seeds/counters: [B]
    uint32/int32 per-row RNG state. Returns [B] int32 token ids < vocab_size.

    ``stochastic`` and ``max_top_k`` are static jit args in the engine's
    fused step: ``stochastic=False`` skips the top-k + Gumbel work
    entirely when the whole batch is greedy (the common case on the
    benchmark/parity workloads), and ``max_top_k`` (the host-known batch
    max of ``top_ks``; 0 = no row masks, -1 = unknown) bounds the per-row
    k-th-largest threshold to an O(V·k) ``lax.top_k`` instead of a
    full-vocab sort.
    """
    B, V = logits.shape
    lg = logits.astype(jnp.float32)
    if vocab_size < V:  # mask vocab padding rows
        lg = jnp.where(jnp.arange(V) < vocab_size, lg, NEG_INF)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    if not stochastic:
        return greedy

    # per-row top-k mask via the k-th largest logit (k=0 -> no mask)
    if max_top_k == 0:
        masked = lg
    else:
        if 0 < max_top_k < V:
            sorted_desc, _ = jax.lax.top_k(lg, max_top_k)  # [B, max_top_k]
        else:
            sorted_desc = -jnp.sort(-lg, axis=-1)
        kth_idx = jnp.clip(top_ks.astype(jnp.int32) - 1, 0,
                           sorted_desc.shape[-1] - 1)
        kth = jnp.take_along_axis(sorted_desc, kth_idx[:, None], axis=-1)
        masked = jnp.where((top_ks[:, None] > 0) & (lg < kth), NEG_INF, lg)

    keys = jax.vmap(
        lambda s, c: jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), s), c)
    )(seeds.astype(jnp.uint32), counters.astype(jnp.uint32))
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)
    temp = jnp.maximum(temps.astype(jnp.float32), 1e-6)[:, None]
    sampled = jnp.argmax(masked / temp + gumbel, axis=-1).astype(jnp.int32)

    return jnp.where(temps > 0.0, sampled, greedy)
