"""Serving orchestrator — the paper's §5 control loop closed over LIVE
paged engines instead of synthetic traces.

One Orchestrator owns N ``Engine(cache_kind="paged")`` instances (the
deployment's model replicas), routes incoming requests, and every
``telemetry_every`` steps:

1. **telemetry**  — folds each engine's real counters (block-pool
   vacancy, queue depth, per-step wall latency from
   ``serving.instrument.EngineTelemetry``, SLO violations measured on
   finished requests, prefix-sharing hit rate and blocks saved) into a
   ``core.monitor.MetricsSnapshot``;
2. **decision**   — runs ``core.controller.Controller.tick()`` (Alg. 1
   scale-up on vacancy, Alg. 2 scale-down on SLO violation / pool
   pressure) against a Cluster whose devices mirror the instances;
3. **execution**  — applies the decision to the RUNNING instances,
   mid-decode, without draining:

   * scale-up: the plan's per-layer replication degrees go to every
     engine via ``Engine.apply_plan`` (the ``layer_hook_from_degrees``
     batch-sharding constraints on the live fused decode step);
   * scale-down / rebalance: KV BLOCKS of live requests migrate between
     instances' pools — ``Engine.pause_request`` exports blocks +
     position + counter-based sampling state, ``resume_request`` rebinds
     them at the same block-table columns on the destination, so the
     continuation is token-identical (greedy AND sampled). A destination
     that can't hold the blocks re-queues the request instead of
     dropping it (deterministic replay), keeping the loop zero-drop by
     construction.

The telemetry -> controller -> operation dataflow and the block-migration
wire format are documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from repro.configs.base import ModelConfig
from repro.core import migration as MIG
from repro.core.cluster import Cluster, Device, layer_weight_bytes
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.serving.engine import Engine, Request
from repro.serving.instrument import EngineTelemetry


@dataclasses.dataclass
class MigrationRecord:
    """One executed live KV-block migration (bench + test evidence)."""
    rid: int
    src: int
    dst: int
    n_blocks: int
    bytes_moved: int
    seconds: float
    est_seconds: float
    resumed: bool           # False = destination re-queued (replay) instead


class Orchestrator:
    def __init__(self, cfg: ModelConfig, params, *, n_instances: int = 2,
                 max_batch: int = 4, max_len: int = 128,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 dtype="float32", slo_latency: float = 50.0,
                 telemetry_every: int = 4,
                 controller_cfg: Optional[ControllerConfig] = None,
                 link_bandwidth: float = 50e9, **engine_kw):
        assert n_instances >= 1
        self.cfg = cfg
        self.slo_latency = slo_latency
        self.telemetry_every = telemetry_every
        self.link_bandwidth = link_bandwidth
        self.engines: List[Engine] = [
            Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                   dtype=dtype, cache_kind="paged", block_size=block_size,
                   n_blocks=n_blocks, **engine_kw)
            for _ in range(n_instances)]
        self.telemetry = [EngineTelemetry() for _ in range(n_instances)]
        self._preempt_seen = [0] * n_instances

        # one Device per live instance; capacity = its pool + headroom for
        # layer replicas so Alg. 1's free-mem gate has room to say yes
        pool_bytes = self.engines[0].pstate.pool_bytes()
        ccfg = controller_cfg or ControllerConfig(
            replica_size=layer_weight_bytes(cfg, dtype_bytes=4))
        if ccfg.module_bytes is None:
            # REAL footprints for scale-down destination fitting: a
            # kv_cache migrant is one slot's share of the live pool
            rs = ccfg.replica_size
            ccfg = dataclasses.replace(
                ccfg, module_bytes={
                    "layer": rs, "attn": rs / 3, "ffn": 2 * rs / 3,
                    "kv_cache": pool_bytes / max(max_batch, 1)})
        cap = pool_bytes + 2 * cfg.num_layers * ccfg.replica_size
        self.cluster = Cluster(
            devices=[Device(i, mem_capacity=cap, compute_flops=1.0)
                     for i in range(n_instances)],
            link_bandwidth=link_bandwidth)
        self.plan = PlacementPlan.initial(cfg.num_layers)
        self.monitor = Monitor()
        self.controller = Controller(
            ccfg, self.cluster, self.plan, self.monitor,
            batch_size=max_batch,
            # the live loop can't re-measure inside one tick: each
            # scale-down applies ONE remediation and re-evaluates at the
            # next telemetry snapshot (graduated response over ticks)
            is_violating=lambda plan, bs: False,
            on_plan_change=self._on_plan_change)
        self.finished: List[Request] = []
        self.migrations: List[MigrationRecord] = []
        self.dropped = 0                    # never incremented: zero-drop
        self._tick = 0
        self._home: Dict[int, int] = {}     # rid -> instance

    # -------------------------------------------------------------- intake
    def submit(self, req: Request):
        """Route to the instance with the most free pool blocks (ties:
        shortest queue, lowest id) — block vacancy is the live resource
        the paper's admission reasons about. The count includes
        cached-free blocks (refcount-0 prefix-cache residents): they are
        evictable on demand, so they ARE vacancy."""
        i = self._route()
        self._home[req.rid] = i
        self.engines[i].submit(req)

    def _route(self) -> int:
        def score(i: int):
            e = self.engines[i]
            return (-e.pstate.free_block_count(), len(e.queue), i)
        return min(range(len(self.engines)), key=score)

    # ------------------------------------------------------------ main loop
    def step(self) -> List[Request]:
        """One orchestrator iteration: step every engine (measuring real
        wall latency), collect finishes, and on telemetry ticks run the
        monitor -> controller -> execute pipeline."""
        fin: List[Request] = []
        for i, eng in enumerate(self.engines):
            t0 = time.perf_counter()
            done = eng.step() or []
            self.telemetry[i].record_step(time.perf_counter() - t0,
                                          len(eng.active) + len(done))
            self.telemetry[i].record_finished(done)
            fin.extend(done)
        self.finished.extend(fin)
        self._tick += 1
        if self._tick % self.telemetry_every == 0:
            self.control_tick()
        return fin

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        out: List[Request] = []
        steps = 0
        while any(e.queue or e.active for e in self.engines) \
                and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    # ------------------------------------------------------------ telemetry
    def snapshot(self) -> MetricsSnapshot:
        """Fold live engine counters into the Monitor's schema. All
        quantities are measured, none synthetic: utilization is occupied
        decode slots, memory is pool blocks in use (shared blocks counted
        ONCE — prefix sharing directly inflates the vacancy signal the
        controller scales on, with prefix_hit_rate/blocks_saved gauges
        saying how much), latency/SLO come from finished requests'
        engine-clock timestamps."""
        util, memf, vac = [], [], []
        new_preempts = 0
        for i, eng in enumerate(self.engines):
            util.append(len(eng.active) / eng.max_batch)
            used = eng.pstate.blocks_in_use() / eng.pstate.n_blocks
            memf.append(used)
            vac.append(1.0 - used)
            n = eng.preempt_count
            new_preempts += n - self._preempt_seen[i]
            self._preempt_seen[i] = n
            ps = eng.prefix_stats()
            self.telemetry[i].record_prefix(ps["queries"], ps["hits"],
                                            ps["blocks_saved_now"])
        # fleet sharing gauges are READ BACK from the telemetry mirrors
        # just written — EngineTelemetry is the metrics source of record
        pq = sum(t.prefix_queries for t in self.telemetry)
        ph = sum(t.prefix_hits for t in self.telemetry)
        saved = sum(t.blocks_saved for t in self.telemetry)
        lats = [t.latency_quantile(0.5) for t in self.telemetry]
        tps = sum(t.tokens_per_s() for t in self.telemetry)
        viol = [t.slo_violation_rate(self.slo_latency)
                for t in self.telemetry]
        return MetricsSnapshot(
            t=self.engines[0].clock,
            tokens_per_s=tps,
            p50_latency=max(lats) if lats else 0.0,
            p95_latency=max(t.latency_quantile(0.95)
                            for t in self.telemetry),
            slo_violation_rate=max(viol) if viol else 0.0,
            queue_len=sum(len(e.queue) for e in self.engines),
            device_util=util, device_mem_frac=memf, block_vacancy=vac,
            step_seconds=max(t.mean_step_s() for t in self.telemetry),
            preemptions=new_preempts,
            prefix_hit_rate=ph / pq if pq else 0.0,
            blocks_saved=saved)

    def _sync_cluster(self, snap: MetricsSnapshot):
        for d, u, m in zip(self.cluster.devices, snap.device_util,
                           snap.device_mem_frac):
            pool = self.engines[d.device_id].pstate.pool_bytes()
            d.util_compute = u
            d.used_mem = m * pool

    # ------------------------------------------------------------- control
    def control_tick(self) -> Optional[str]:
        """One monitor -> controller -> execute round (also callable
        directly by tests/benchmarks to inject a decision point)."""
        snap = self.snapshot()
        self.controller.observe(snap)
        self._sync_cluster(snap)
        action = self.controller.tick()
        if action and action.startswith("scale-down"):
            self._execute_scale_down()
        self.plan = self.controller.plan
        return action

    def _on_plan_change(self, plan: PlacementPlan, batch_size: int):
        """Controller callback: push the new replication degrees to every
        LIVE instance — the next decode step of each engine runs under
        the plan's per-layer batch sharding, no drain, no restart."""
        self.plan = plan
        for eng in self.engines:
            eng.apply_plan(plan)

    def _execute_scale_down(self):
        """Realize the controller's Phase-1 module migrations as KV-block
        transfers: whatever module the plan nominally moves, what a live
        instance can shed mid-decode is the memory-intensive module —
        its requests' paged KV (§3.3's preferred migrant). One rebalance
        per (src, dst) pair per tick."""
        res = self.controller.last_scale_down
        if res is None:
            return
        seen = set()
        for layer, comp, src, dst in res.migrations:
            if (src, dst) in seen or src == dst:
                continue
            seen.add((src, dst))
            self.migrate_requests(src, dst)

    # ------------------------------------------------------------ migration
    def migrate_requests(self, src: int, dst: int,
                         max_requests: Optional[int] = None
                         ) -> List[MigrationRecord]:
        """Move active requests' KV blocks from instance ``src`` to
        ``dst``, mid-stream. Never drops: a request the destination pool
        can't hold is re-queued there and replays deterministically
        (counter-based sampling keys). Requests holding SHARED
        (refcounted) blocks migrate safely: the export materializes
        shared content into the payload and carries the prefix keys, so
        the stream stays token-identical and the destination's prefix
        cache learns the migrated prompt."""
        seng, deng = self.engines[src], self.engines[dst]
        slots = sorted(seng.active.keys())
        if max_requests is not None:
            slots = slots[:max_requests]
        out: List[MigrationRecord] = []
        for slot in slots:
            t0 = time.perf_counter()
            payload = seng.pause_request(slot)
            req = payload["request"]
            ok = deng.resume_request(payload)
            if not ok:
                deng.queue.appendleft(req)   # zero-drop fallback: replay
            jax.block_until_ready((deng.pstate.k, deng.pstate.v))
            dt = time.perf_counter() - t0
            nbytes = payload["kv"]["nbytes"]
            rec = MigrationRecord(
                rid=req.rid, src=src, dst=dst,
                n_blocks=len(payload["kv"]["cols"]),
                bytes_moved=nbytes, seconds=dt,
                est_seconds=MIG.estimate_cost(nbytes, self.link_bandwidth),
                resumed=ok)
            self._home[req.rid] = dst
            self.migrations.append(rec)
            out.append(rec)
        return out

    def drain_instance(self, idx: int) -> List[MigrationRecord]:
        """Scale-down consolidation: move EVERYTHING (active KV blocks +
        queued requests) off instance ``idx`` onto the least-loaded other
        instance, leaving ``idx`` empty and removable."""
        others = [i for i in range(len(self.engines)) if i != idx]
        assert others, "cannot drain a single-instance deployment"
        dst = min(others, key=lambda i: (len(self.engines[i].active),
                                         len(self.engines[i].queue)))
        recs = self.migrate_requests(idx, dst)
        src = self.engines[idx]
        while src.queue:                     # preserve submit_time: no
            req = src.queue.popleft()        # re-submit, straight handoff
            self._home[req.rid] = dst
            self.engines[dst].queue.append(req)
        return recs

    # -------------------------------------------------------------- summary
    def stats(self) -> Dict:
        ps = [e.prefix_stats() for e in self.engines]
        pq = sum(p["queries"] for p in ps)
        ph = sum(p["hits"] for p in ps)
        return {
            "finished": len(self.finished),
            "dropped": self.dropped,
            "migrations": len(self.migrations),
            "migrated_bytes": sum(m.bytes_moved for m in self.migrations),
            "preemptions": sum(self._preempt_seen),
            "prefix_hit_rate": ph / pq if pq else 0.0,
            "blocks_saved_now": sum(p["blocks_saved_now"] for p in ps),
            "controller_log": list(self.controller.log),
            "plan_p": list(self.plan.p),
        }
