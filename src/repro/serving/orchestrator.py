"""Serving orchestrator — the paper's §5 control loop closed over LIVE
paged engines, local or in other processes, behind one interface.

One Orchestrator owns N serving instances (the deployment's model
replicas) as ``serving.instance.InstanceHandle``s: a handle is either a
``LocalInstance`` (an in-process ``Engine``) or a
``remote_engine.EngineProxy`` (a real paged Engine in a CHILD PROCESS
behind the RPC wire protocol of serving/transport.py — the distributed
serving plane; ``remote=True`` or an explicit ``handles=[...]`` mix
selects the topology). The orchestrator itself contains no transport
knowledge: everything it does goes through the handle surface, so the
same control loop drives one process or a fleet. Every
``telemetry_every`` steps:

1. **telemetry**  — folds each instance's counters (block-pool vacancy,
   queue depth, per-step wall latency from
   ``serving.instrument.EngineTelemetry`` — recorded in-process for
   local instances, mirrored from the engine server's serialized
   snapshots for remote ones — SLO violations measured on finished
   requests, prefix-sharing hit rate and blocks saved) into a
   ``core.monitor.MetricsSnapshot``;
2. **decision**   — runs ``core.controller.Controller.tick()`` (Alg. 1
   scale-up on vacancy, Alg. 2 scale-down on SLO violation / pool
   pressure) against a Cluster whose devices mirror the instances.
   After a scale-down executes, the POST-ACTION snapshot is fed back
   and Alg. 2 iterates further phases within the same burst (bounded by
   ``max_phases``) instead of waiting a full tick per remediation;
3. **execution**  — applies the decision to the RUNNING instances,
   mid-decode, without draining:

   * scale-up: the plan's per-layer replication degrees go to every
     instance via ``InstanceHandle.apply_plan`` (for a remote instance
     the degree list rides an RPC frame);
   * scale-down / rebalance: KV BLOCKS of live requests migrate between
     instances' pools — OVERLAPPED and two-phase by default
     (``migrate_requests_overlapped``): a phase-1 snapshot of the
     victim's blocks streams to the destination and is staged there
     WHILE THE SOURCE KEEPS DECODING (the destination import is
     pipelined; the source steps in between), then phase 2
     pause-copies only the short dirty-set delta (blocks written since
     the snapshot, tracked by paged_kv write epochs) and resumes at the
     destination — the victim stream leaves decode rotation only for
     the delta, at most one decode step. A destination that can't hold
     the blocks re-queues the request instead of dropping it
     (deterministic counter-based replay), keeping the loop zero-drop
     by construction.

Crash recovery: a remote instance that dies (its next RPC raises
``transport.TransportClosed``) has its in-flight streams re-queued on
surviving instances from the proxy's pristine-clone mirror; replay is
deterministic, so a worker loss costs recompute, never output or drops.

The telemetry -> controller -> operation dataflow, the block-migration
wire format, and the two-phase migration timeline are documented in
DESIGN.md (§3, §4, §7).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core import migration as MIG
from repro.core.cluster import Cluster, Device, layer_weight_bytes
from repro.core.controller import (Controller, ControllerConfig,
                                   PodElasticityConfig)
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.serving import faults as FLT
from repro.serving import observe as OBS
from repro.serving import transport as TR
from repro.serving.engine import Engine, Request
from repro.serving.instance import InstanceHandle, LocalInstance
from repro.serving.instrument import FaultCounters
from repro.serving.request import RequestSpec
from repro.serving.router import (PrefixAffinityRouter, RouteDecision,
                                  RouterPolicy)


@dataclasses.dataclass
class MigrationRecord:
    """One executed live KV-block migration (bench + test evidence)."""
    rid: int
    src: int
    dst: int
    n_blocks: int
    bytes_moved: int
    seconds: float          # end-to-end wall (begin -> resumed)
    est_seconds: float
    resumed: bool           # False = destination re-queued (replay) instead
    mode: str = "stw"       # "stw" (stop-the-world) | "overlapped"
    stall_s: float = 0.0    # wall time the stream was in NO decode rotation
    delta_blocks: int = 0   # overlapped only: blocks in the phase-2 delta
    delta_bytes: int = 0


@dataclasses.dataclass
class RespawnPolicy:
    """Supervised-respawn knobs (DESIGN.md §9). A dead/quarantined
    respawnable worker is restarted after a capped exponential backoff
    (``backoff_base * 2^attempt``, at most ``backoff_cap`` seconds) and
    re-admitted through the normal two-phase bring-up handshake. The
    flap detector is a circuit breaker: ``max_failures`` failures of
    the same instance inside ``window_s`` evict it permanently —
    a crash-looping worker must not soak the fleet in bring-up cost
    forever."""
    backoff_base: float = 0.25
    backoff_cap: float = 5.0
    max_failures: int = 3
    window_s: float = 60.0
    start_timeout: float = 120.0


class Orchestrator:
    def __init__(self, cfg: ModelConfig, params, *, n_instances: int = 2,
                 max_batch: int = 4, max_len: int = 128,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 dtype="float32", slo_latency: float = 50.0,
                 telemetry_every: int = 4,
                 controller_cfg: Optional[ControllerConfig] = None,
                 link_bandwidth: float = 50e9, remote: bool = False,
                 handles: Optional[List[InstanceHandle]] = None,
                 max_phases: int = 3,
                 rpc_deadline: Optional[float] = None,
                 respawn_policy: Optional[RespawnPolicy] = None,
                 router: Optional[RouterPolicy] = None,
                 max_queue: Optional[int] = None,
                 worker_factory=None,
                 pod_cfg: Optional[PodElasticityConfig] = None,
                 tracer: Optional[OBS.Tracer] = None,
                 flightrec_path: Optional[str] = None,
                 **engine_kw):
        self.cfg = cfg
        # observability plane (serving/observe.py): the tracer is opt-in
        # (the ingress installs one, or tests pass it); the flight
        # recorder is ALWAYS on — a bounded ring of control-plane
        # decisions is cheap and is exactly the thing you need after
        # the incident you didn't plan for
        self.tracer = tracer
        self.flightrec = OBS.FlightRecorder(capacity=512,
                                            dump_path=flightrec_path)
        self.slo_latency = slo_latency
        self.telemetry_every = telemetry_every
        self.link_bandwidth = link_bandwidth
        self.max_phases = max_phases
        # routing policy (serving/router.py): prefix-affinity by default
        # — falls back to the historical vacancy order when no chain
        # matches, so non-shared workloads route exactly as before
        self.router = router if router is not None else PrefixAffinityRouter()
        # per-instance admission ceiling for the ingress (None = no
        # backpressure; route() returns None -> HTTP 429 + Retry-After)
        self.max_queue = max_queue
        # pod elasticity: a factory (idx -> InstanceHandle) arms
        # grow_pod; pod_cfg arms the controller's pod decisions
        self.worker_factory = worker_factory
        self.pod_cfg = pod_cfg
        if handles is not None:
            self.instances: List[InstanceHandle] = list(handles)
        elif remote:
            from repro.serving.remote_engine import EngineProxy
            self.instances = [
                EngineProxy(cfg, params, max_batch=max_batch,
                            max_len=max_len, dtype=dtype,
                            block_size=block_size, n_blocks=n_blocks,
                            **engine_kw)
                for _ in range(n_instances)]
        else:
            self.instances = [
                LocalInstance(Engine(cfg, params, max_batch=max_batch,
                                     max_len=max_len, dtype=dtype,
                                     cache_kind="paged",
                                     block_size=block_size,
                                     n_blocks=n_blocks, **engine_kw))
                for _ in range(n_instances)]
        assert self.instances, "need at least one instance"
        n_instances = len(self.instances)
        self.telemetry = [h.telemetry for h in self.instances]
        self._preempt_seen = [0] * n_instances

        # one Device per live instance; capacity = its pool + headroom for
        # layer replicas so Alg. 1's free-mem gate has room to say yes
        pool_bytes = self.instances[0].pool_bytes()
        mb = self.instances[0].max_batch
        ccfg = controller_cfg or ControllerConfig(
            replica_size=layer_weight_bytes(cfg, dtype_bytes=4))
        if ccfg.module_bytes is None:
            # REAL footprints for scale-down destination fitting: a
            # kv_cache migrant is one slot's share of the live pool
            rs = ccfg.replica_size
            ccfg = dataclasses.replace(
                ccfg, module_bytes={
                    "layer": rs, "attn": rs / 3, "ffn": 2 * rs / 3,
                    "kv_cache": pool_bytes / max(mb, 1)})
        self._ccfg = ccfg   # kept: grow_pod sizes new Devices from it
        cap = pool_bytes + 2 * cfg.num_layers * ccfg.replica_size
        self.cluster = Cluster(
            devices=[Device(i, mem_capacity=cap, compute_flops=1.0)
                     for i in range(n_instances)],
            link_bandwidth=link_bandwidth)
        self.plan = PlacementPlan.initial(cfg.num_layers)
        self.monitor = Monitor()
        self.controller = Controller(
            ccfg, self.cluster, self.plan, self.monitor,
            batch_size=mb,
            # the live loop can't re-measure inside ONE scale_down call;
            # instead control_tick feeds the post-action snapshot back
            # and iterates Alg. 2's phases across the same burst
            is_violating=lambda plan, bs: False,
            on_plan_change=self._on_plan_change,
            pod_cfg=pod_cfg)
        self.finished: List[Request] = []
        self.migrations: List[MigrationRecord] = []
        self.recoveries: List[dict] = []    # crash-recovery audit trail
        self.dropped = 0                    # never incremented: zero-drop
        self._tick = 0
        self._home: Dict[int, int] = {}     # rid -> instance
        self._recovered: set = set()        # instances already recovered
        # --- pod elasticity state (DESIGN.md §11) ---
        # indices deliberately drained + reaped by shrink_pod. Index
        # slots are NEVER reused or shifted (_home/_respawn/_evicted are
        # idx-keyed); a retired slot just goes dark everywhere.
        self._retired: set = set()
        self._grown_at: Dict[int, float] = {}   # idx -> monotonic birth
        self.pod_log: List[dict] = []           # grow/shrink audit trail
        # rid -> longest token list observed for slot-holding streams
        # (the ingress feed; full lists make migration overlap and
        # crash replay idempotent — longest == most progressed)
        self._stream_acc: Dict[int, List[int]] = {}
        # finishes collected by migrate_requests_overlapped's internal
        # overlap steps: already in self.finished, surfaced through the
        # NEXT step()'s return so run_until_done callers never miss one
        self._orphans: List[Request] = []
        # control-plane accounting for the batched poll (benchmarks):
        # ticks   = _step_all invocations,
        # polls   = multiplexed drains issued (1 per tick with any
        #           remote instance — the "one poll per tick" invariant),
        # step_rpcs = step RPCs fanned out across those polls
        self.rpc_stats = {"ticks": 0, "polls": 0, "step_rpcs": 0}
        # --- failure domain (DESIGN.md §9) ---
        self.faults = FaultCounters()
        self.respawn_policy = respawn_policy
        self._respawn: Dict[int, dict] = {}   # idx -> supervisor state
        self._evicted: set = set()            # flap-detector removals
        self.respawn_log: List[dict] = []     # audit trail (bench/tests)
        # cold-start grace: a respawned replica's first ACTIVE step may
        # include XLA compiles that dwarf any sane RPC deadline — its
        # deadline stays disarmed until that step completes, so a fresh
        # worker is never misclassified as hung while it warms up
        self._grace: set = set()
        self._fanout_t = 0.0                  # last control fan-out start
        self.rpc_deadline: Optional[float] = None
        self.set_rpc_deadline(rpc_deadline)

    def set_rpc_deadline(self, seconds: Optional[float]):
        """Arm (or disarm, with None — the default: zero behavior
        change) the per-call deadline on every instance handle. With a
        deadline set, a hung peer resolves to a ``hung`` poll entry in
        at most ``seconds`` and is then classified by a heartbeat probe
        bounded by the same budget — detection wall ≤ 2x the deadline,
        never an unbounded control-tick stall."""
        self.rpc_deadline = seconds
        for i, h in enumerate(self.instances):
            if i not in self._grace:    # warming replicas arm later
                h.set_rpc_deadline(seconds)

    # ------------------------------------------------------------ topology
    @property
    def engines(self) -> List[Engine]:
        """The raw in-process Engines (tests / single-host tooling).
        Remote instances have no local engine — use the handle surface."""
        return [h.engine for h in self.instances
                if isinstance(h, LocalInstance)]

    def _alive(self) -> List[int]:
        return [i for i, h in enumerate(self.instances)
                if i not in self._retired and h.alive()]

    def clock(self) -> float:
        alive = self._alive()
        return self.instances[alive[0]].clock() if alive else 0.0

    def close(self):
        for h in self.instances:
            try:
                h.close()
            except TR.TransportError:
                pass

    # -------------------------------------------------------------- intake
    def submit(self, spec: RequestSpec):
        """Route through the policy (serving/router.py — default:
        prefix-affinity on the prompt's content-chain keys, falling back
        to most free pool blocks / shortest queue / lowest id) and admit.
        Takes the construction-time ``RequestSpec`` — the chosen
        instance's engine mints the mutable ``Request``.

        A routed peer that fails DURING the submit (died, or hung past
        its deadline) does not lose the request: the handle mirrors the
        pristine clone before sending, so failing the peer replays the
        clone — with everything else it held — onto a survivor."""
        self.submit_to(self._route(spec=spec), spec)

    def submit_to(self, idx: int, spec: RequestSpec):
        """Admit on a SPECIFIC instance — the ingress routes on its own
        thread (``route``) and hands (idx, spec) to the pump, which must
        not re-route; bookkeeping and failure handling stay here either
        way."""
        self._home[spec.rid] = idx
        # trace context rides the submit itself (piggybacked on the RPC
        # frame for a remote instance) so engine-side spans record from
        # the request's very first hook
        trace = self.tracer.ctx(spec.rid) if self.tracer else None
        t_obs = time.monotonic()
        try:
            # positional call when untraced: handle subclasses predating
            # the trace kwarg (tests stub the surface) keep working
            if trace is None:
                self.instances[idx].submit(spec)
            else:
                self.instances[idx].submit(spec, trace=trace)
        except (TR.TransportClosed, TR.RpcTimeout) as e:
            self._fail_instance(idx, hung=isinstance(e, TR.RpcTimeout),
                                t_obs=t_obs)

    def route(self, spec: Optional[RequestSpec] = None, prompt=None,
              pending: Optional[Dict[int, int]] = None
              ) -> Optional[RouteDecision]:
        """Admission-checked routing for the ingress: the policy's full
        verdict, or None when every alive instance is at ``max_queue``
        (counting ``pending`` — accepted-but-not-yet-submitted requests)
        — the HTTP 429 + Retry-After signal. The ``spec`` makes the
        verdict class-aware (batch traffic is shed one seat early).
        Reads only cached gauges: safe to call off the orchestrator's
        thread."""
        alive = self._alive()
        if not alive:
            self.flightrec.record("route", verdict="no-alive-instance")
            return None
        d = self.router.select(self.instances, alive, spec=spec,
                               prompt=prompt,
                               pending=pending, max_queue=self.max_queue)
        if d is None:
            self.flightrec.record("route", verdict="shed",
                                  alive=len(alive),
                                  max_queue=self.max_queue)
        else:
            self.flightrec.record("route", verdict="admit",
                                  **d.as_event())
        return d

    def _route(self, among: Optional[List[int]] = None,
               prompt=None, spec=None) -> int:
        cands = among if among is not None else self._alive()
        assert cands, "no alive instance to route to"
        return self.router.select(self.instances, cands,
                                  spec=spec, prompt=prompt).idx

    # ------------------------------------------------------------ main loop
    def _step_all(self) -> List[Request]:
        """Step every alive instance through ONE batched control-plane
        poll: the step request fans out to all of them via
        ``step_async`` (remote servers start computing concurrently; a
        local handle executes inline during the fan-out), then a single
        ``transport.drain_pendings`` wait collects the replies as they
        land — per-tick wall time is bounded by the SLOWEST instance's
        step, not the sum of N sequential round trips. Crash detection
        folds into the same poll: a ``closed`` entry (the instance died
        before replying) triggers the same idempotent re-queue + replay
        path as a TransportClosed raised anywhere else."""
        fin: List[Request] = []
        idxs: List[int] = []
        pendings: List = []
        self._fanout_t = time.monotonic()
        for i, h in enumerate(self.instances):
            if i in self._retired:
                continue       # deliberately reaped: nothing to step
            if not h.alive():
                if i not in self._recovered:
                    # died silently since the last tick (nothing raised
                    # TransportClosed because no op was in flight — e.g.
                    # a SIGKILLed worker): same replay path, same
                    # idempotency guard
                    self.handle_instance_failure(i)
                continue
            try:
                pendings.append(h.step_async())
            except TR.TransportClosed:
                self.handle_instance_failure(i)
                continue
            idxs.append(i)
        if not pendings:
            return fin
        n_remote = sum(isinstance(p, TR.Pending) for p in pendings)
        self.rpc_stats["ticks"] += 1
        self.rpc_stats["step_rpcs"] += n_remote
        if n_remote:
            self.rpc_stats["polls"] += 1
        errors = []
        for (i, p), (status, val) in zip(zip(idxs, pendings),
                                         TR.drain_pendings(pendings)):
            h = self.instances[i]
            if status == "closed":
                h.mark_dead()
                self.handle_instance_failure(i)
            elif status == "hung":
                try:
                    fin.extend(self._on_hung_step(i, p))
                except TR.RemoteError as e:
                    errors.append(e)   # salvaged reply was an error reply
            elif status == "error":
                # don't raise yet: later entries hold other instances'
                # ALREADY-RECEIVED step replies — skipping finish_step
                # would lose their finished requests and desync the
                # inflight mirrors crash replay depends on
                errors.append(val)
            else:
                fin.extend(h.finish_step(val))
                if i in self._grace and h.active_count():
                    # first step with real work done: compiles are paid,
                    # the replica now answers on normal latency — arm it
                    h.set_rpc_deadline(self.rpc_deadline)
                    self._grace.discard(i)
        if errors:
            # this tick's finishes must survive the raise too — the
            # callers' extend never runs, so route them through the
            # orphan path the overlap steps already use
            self.finished.extend(fin)
            self._orphans.extend(fin)
            raise errors[0]
        return fin

    def _on_hung_step(self, idx: int, pending) -> List[Request]:
        """A step RPC missed its deadline with the socket still open.
        Classify with the heartbeat probe (bounded by the same deadline
        budget, so total detection wall stays ≤ 2x the deadline):

        * ``alive``  — the peer answers. In-order serving then proves
          one of two things: the step reply already arrived while we
          probed (merely-slow peer — salvage it, nothing was lost), or
          the step REQUEST frame itself was lost (injected drop /
          healed partition) and the step never executed — skipping this
          tick is safe, the peer stays admitted;
        * ``hung``   — heartbeat unanswered too: blackholed/half-open.
          Quarantine (sever + kill) and replay its inflight mirror;
        * ``dead``   — it died while we looked: normal crash path."""
        self.faults.rpc_timeouts += 1
        h = self.instances[idx]
        verdict = h.probe(self.rpc_deadline or 1.0)
        if verdict == "alive":
            if pending.ready():
                return h.finish_step(pending.wait())
            return []
        self._fail_instance(idx, hung=(verdict == "hung"))
        return []

    def _fail_instance(self, idx: int, *, hung: bool,
                       t_obs: Optional[float] = None):
        """Fold one observed peer failure into quarantine + replay. A
        HUNG peer is quarantined first (socket severed, owned process
        killed) so the idempotent replay can never race a zombie's late
        effects; a dead one just gets marked. ``t_obs`` is when the
        failing call was issued — the start of the observation window
        for the detection-latency gauge; callers classifying outside
        the step fan-out (submit, migration RPCs, recovery replay) must
        pass it, else the gauge would charge this peer with wall time
        from before it was even observable as faulty."""
        h = self.instances[idx]
        if hung and idx not in self._recovered:
            self.faults.quarantines += 1
            self.flightrec.record("quarantine", instance=idx)
            try:
                h.quarantine()
            except TR.TransportError:
                pass
        else:
            h.mark_dead()
        self.handle_instance_failure(idx, reason="hung" if hung
                                     else "dead", t_obs=t_obs)

    def step(self) -> List[Request]:
        """One orchestrator iteration: step every alive instance through
        the batched poll (each records real wall latency into its
        telemetry), collect finishes, recover any instance whose
        transport died, and on telemetry ticks run the monitor ->
        controller -> execute pipeline."""
        self._tick_respawns()
        fin = self._step_all()
        self.finished.extend(fin)
        self._tick += 1
        if self._tick % self.telemetry_every == 0:
            self.control_tick()
        out = self._drain_orphans() + fin
        self._collect_streams(out)
        self._collect_spans(out)
        return out

    def _collect_spans(self, fin: List[Request]):
        """Drain each instance's engine-recorded spans into the tracer
        (remote handles buffer them off the step replies, already
        skew-corrected onto this clock), then close the trace of every
        request that finished this step — AFTER the drain, so a finish's
        own decode/finish spans ride the same reply and land in the tree
        before the root closes."""
        if self.tracer is None:
            return
        for i in self._alive():
            spans = self.instances[i].drain_spans()
            if spans:
                self.tracer.ingest(spans)
        for r in fin:
            # SLO attainment rides the root span: class + deadline are
            # echoed, and the tracer stamps deadline_met from the root's
            # own wall-clock extent at close time
            self.tracer.finish(r.rid, instance=self._home.get(r.rid),
                               tokens=len(r.generated),
                               slo_class=getattr(r, "slo_class",
                                                 "standard"),
                               deadline_ms=getattr(r, "deadline_ms",
                                                   None))

    # ------------------------------------------------------ token streams
    def _collect_streams(self, fin: List[Request]):
        """Fold every instance's per-step stream feed into the rid ->
        tokens accumulator the ingress flushes from. Keeping the LONGEST
        list seen makes the fold idempotent under migration overlap
        (source and destination may both report the stream for a step)
        and under crash replay (a restarted stream re-emits a prefix of
        itself — token-identical replay means longest == truth).
        Finished rids leave the accumulator: their full token lists
        travel on the finished Request objects."""
        for i in self._alive():
            for rid, toks in self.instances[i].stream_view().items():
                cur = self._stream_acc.get(rid)
                if cur is None or len(toks) > len(cur):
                    self._stream_acc[rid] = list(toks)
        for r in fin:
            self._stream_acc.pop(r.rid, None)

    def stream_view(self) -> Dict[int, List[int]]:
        """rid -> tokens generated so far for every LIVE stream, as of
        the last step — consumers (the ingress pump) keep a per-rid
        high-water mark and flush only the tail."""
        return self._stream_acc

    def _drain_orphans(self) -> List[Request]:
        """Finishes collected inside migrate_requests_overlapped's
        overlap steps (already in ``self.finished``), handed to the next
        step()/run_until_done() return so no caller misses one."""
        out, self._orphans = self._orphans, []
        return out

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        out: List[Request] = self._drain_orphans()
        steps = 0
        while steps < max_steps and any(
                self.instances[i].queue_len()
                or self.instances[i].active_rids()
                for i in self._alive()):
            out.extend(self.step())
            steps += 1
        return out

    # ------------------------------------------------------------ telemetry
    def snapshot(self) -> MetricsSnapshot:
        """Fold live instance counters into the Monitor's schema. All
        quantities are measured, none synthetic: utilization is occupied
        decode slots, memory is pool blocks in use (shared blocks counted
        ONCE — prefix sharing directly inflates the vacancy signal the
        controller scales on, with prefix_hit_rate/blocks_saved gauges
        saying how much), latency/SLO come from finished requests'
        engine-clock timestamps. A dead instance reports full/busy so the
        controller neither targets it nor counts it as vacancy."""
        util, memf, vac = [], [], []
        new_preempts = 0
        for i, h in enumerate(self.instances):
            if i in self._retired:
                # deliberately reaped: a None entry keeps the per-device
                # lists index-aligned with the cluster Devices without
                # poisoning the fleet vacancy averages forever (unlike a
                # dead instance, a retired one is never coming back)
                util.append(None)
                memf.append(None)
                vac.append(None)
                continue
            if not h.alive():
                util.append(1.0)
                memf.append(1.0)
                vac.append(0.0)
                continue
            util.append(h.active_count() / h.max_batch)
            used = h.blocks_in_use() / h.n_blocks
            memf.append(used)
            vac.append(1.0 - used)
            n = h.preempt_count()
            new_preempts += n - self._preempt_seen[i]
            self._preempt_seen[i] = n
            ps = h.prefix_stats()
            h.telemetry.record_prefix(ps["queries"], ps["hits"],
                                      ps["blocks_saved_now"])
        # fleet sharing gauges are READ BACK from the telemetry mirrors
        # just written — EngineTelemetry is the metrics source of record.
        # Folds cover ALIVE instances only: a dead worker's frozen mirror
        # (e.g. a pinned SLO-violation rate) must not drive the
        # controller after its streams were replayed elsewhere.
        tel = [self.telemetry[i] for i in self._alive()]
        pq = sum(t.prefix_queries for t in tel)
        ph = sum(t.prefix_hits for t in tel)
        saved = sum(t.blocks_saved for t in tel)
        lats = [t.latency_quantile(0.5) for t in tel]
        tps = sum(t.tokens_per_s() for t in tel)
        viol = [t.slo_violation_rate(self.slo_latency) for t in tel]
        # budget utilization averages over BUDGETED engines only — a
        # phase-scheduled instance has no budget to pack and would drag
        # the fleet gauge toward zero
        buds = [t.budget_utilization() for t in tel if t.budget]
        return MetricsSnapshot(
            t=self.clock(),
            tokens_per_s=tps,
            p50_latency=max(lats) if lats else 0.0,
            p95_latency=max((t.latency_quantile(0.95) for t in tel),
                            default=0.0),
            slo_violation_rate=max(viol) if viol else 0.0,
            queue_len=sum(self.instances[i].queue_len()
                          for i in self._alive()),
            device_util=util, device_mem_frac=memf, block_vacancy=vac,
            step_seconds=max((t.mean_step_s() for t in tel), default=0.0),
            preemptions=new_preempts,
            prefix_hit_rate=ph / pq if pq else 0.0,
            blocks_saved=saved,
            budget_utilization=(sum(buds) / len(buds) if buds else 0.0),
            ttft_p50=max((t.ttft_quantile(0.5) for t in tel),
                         default=0.0),
            ttft_p95=max((t.ttft_quantile(0.95) for t in tel),
                         default=0.0),
            queue_delay_p95=max((t.queue_delay_quantile(0.95)
                                 for t in tel), default=0.0),
            faults_injected=FLT.injected_total(),
            rpc_timeouts=self.faults.rpc_timeouts,
            quarantines=self.faults.quarantines,
            respawns=self.faults.respawns,
            pod_size=len(self._alive()))

    def _sync_cluster(self, snap: MetricsSnapshot):
        for d, u, m in zip(self.cluster.devices, snap.device_util,
                           snap.device_mem_frac):
            if u is None:     # retired slot: full + idle, never a target
                d.util_compute = 0.0
                d.used_mem = d.mem_capacity
                continue
            h = self.instances[d.device_id]
            pool = h.pool_bytes() if h.alive() else d.mem_capacity
            d.util_compute = u
            d.used_mem = m * pool

    # ------------------------------------------------------------- control
    def control_tick(self, max_phases: Optional[int] = None
                     ) -> Optional[str]:
        """One monitor -> controller -> execute BURST (also callable
        directly by tests/benchmarks to inject a decision point).

        Scale-down iterates: after executing a remediation, the
        post-action MetricsSnapshot — which already reflects the moved
        blocks, queue handoffs and cleared preemption pressure — is fed
        back into the Controller and Alg. 2 runs another phase within
        the same burst, until it stops demanding one, a phase moves
        nothing, or ``max_phases`` is hit. This is the live analogue of
        Alg. 2's "re-check after each phase": measure, act, re-measure —
        not one optimistic remediation per tick."""
        phases = self.max_phases if max_phases is None else max_phases
        last = None
        for phase in range(phases):
            snap = self.snapshot()
            self.controller.observe(snap)
            self._sync_cluster(snap)
            action = self.controller.tick(in_burst=phase > 0)
            # every verdict — including "no action" — lands in the
            # flight recorder WITH the inputs that produced it, so a
            # post-incident reader sees why the controller did nothing
            self.flightrec.record(
                "controller", phase=phase, action=action,
                inputs={"slo_violation_rate": snap.slo_violation_rate,
                        "queue_len": snap.queue_len,
                        "tokens_per_s": snap.tokens_per_s,
                        "vacancy": self.monitor.vacancy_rate(),
                        "block_vacancy": self.monitor.block_vacancy_rate(),
                        "pool_pressure": self.monitor.pool_pressure(),
                        "budget_utilization": snap.budget_utilization,
                        "pod_size": snap.pod_size})
            if action:
                last = action
            if not (action and action.startswith("scale-down")):
                break
            if self._execute_scale_down() == 0:
                break       # nothing left to move: the burst is done
        self.plan = self.controller.plan
        pod_action = self._pod_tick()
        return last or pod_action

    # -------------------------------------------------- pod elasticity
    def pod_size(self) -> int:
        """Alive, non-retired instances — the controller's population."""
        return len(self._alive())

    def _pod_tick(self) -> Optional[str]:
        """Consult the controller's pod-level decision (armed by
        ``worker_factory`` + ``pod_cfg``) and execute it: grow spawns a
        worker through the factory; shrink drains the cheapest eligible
        worker through the zero-drop migration path, then reaps it."""
        if self.worker_factory is None or self.pod_cfg is None:
            return None
        target = self._shrink_target()
        decision = self.controller.pod_tick(
            self.pod_size(),
            est_drain_s=target[1] if target else 0.0)
        if decision:
            self.flightrec.record(
                "pod_decision", decision=decision,
                pod_size=self.pod_size(),
                target=target[0] if target else None,
                est_drain_s=target[1] if target else 0.0)
        if decision == "grow":
            idx = self.grow_pod()
            return f"grow-pod[{idx}]" if idx is not None else None
        if decision == "shrink" and target is not None:
            idx = self.shrink_pod(target[0])
            return f"shrink-pod[{idx}]" if idx is not None else None
        return None

    def grow_pod(self) -> Optional[int]:
        """Spawn ONE fresh instance through the worker factory and admit
        it to the plane: handle + telemetry + a cluster Device sized by
        the same capacity formula as the launch-time fleet. The router
        starts steering to it immediately (it has the most free blocks
        in the pod); under an armed RPC deadline it gets the same
        cold-start grace as a respawned replica. Returns the new index,
        or None when the factory is absent or the pod is at its max."""
        if self.worker_factory is None:
            return None
        if (self.pod_cfg is not None
                and self.pod_size() >= self.pod_cfg.max_instances):
            return None
        idx = len(self.instances)
        h = self.worker_factory(idx)
        self.instances.append(h)
        self.telemetry.append(h.telemetry)
        self._preempt_seen.append(0)
        cap = (h.pool_bytes()
               + 2 * self.cfg.num_layers * self._ccfg.replica_size)
        self.cluster.devices.append(
            Device(idx, mem_capacity=cap, compute_flops=1.0))
        if any(d != 1 for d in self.plan.p):
            h.apply_plan(list(self.plan.p))   # adopt the live plan
        if self.rpc_deadline is not None:
            # cold-start grace (same as respawn): arm the deadline only
            # after its first completed ACTIVE step
            h.set_rpc_deadline(None)
            self._grace.add(idx)
        self._grown_at[idx] = time.monotonic()
        self.pod_log.append({"event": "grow", "instance": idx,
                             "pod_size": self.pod_size()})
        self.flightrec.record("pod_grow", instance=idx,
                              pod_size=self.pod_size())
        return idx

    def _shrink_candidates(self) -> List[int]:
        """Instances eligible for reaping: alive, warmed up (not in
        cold-start grace — flap protection: a grow immediately followed
        by a shrink must not orphan a BOOTING worker), and older than
        the flap-guard window."""
        now = time.monotonic()
        guard = self.pod_cfg.flap_guard_s if self.pod_cfg else 0.0
        return [i for i in self._alive()
                if i not in self._grace
                and now - self._grown_at.get(i, float("-inf")) >= guard]

    def _shrink_target(self) -> Optional[tuple]:
        """(index, estimated drain seconds) of the cheapest eligible
        shrink victim — the cost the controller's Table-2-style gate
        prices — or None when the pod cannot shrink."""
        floor = self.pod_cfg.min_instances if self.pod_cfg else 1
        if len(self._alive()) <= max(floor, 1):
            return None
        cands = self._shrink_candidates()
        if not cands:
            return None
        idx = min(cands, key=lambda i: (self.instances[i].active_count(),
                                        self.instances[i].queue_len(),
                                        -i))
        h = self.instances[idx]
        per_block = h.pool_bytes() / max(h.n_blocks, 1)
        est = MIG.estimate_cost(h.blocks_in_use() * per_block,
                                self.link_bandwidth)
        return idx, est

    def shrink_pod(self, idx: Optional[int] = None) -> Optional[int]:
        """Drain instance ``idx`` (default: the cheapest eligible
        victim) through the existing zero-drop path — queue handoff,
        then overlapped KV-block migration of its active streams — and
        RETIRE it: close the handle, keep the index slot dark forever
        (indices are never reused; _home/_respawn/_evicted are
        idx-keyed). Returns the reaped index, or None when the pod is at
        its floor or the victim is ineligible (booting / flap-guarded).
        """
        floor = self.pod_cfg.min_instances if self.pod_cfg else 1
        if len(self._alive()) <= max(floor, 1):
            return None
        cands = self._shrink_candidates()
        if idx is None:
            if not cands:
                return None
            idx = min(cands,
                      key=lambda i: (self.instances[i].active_count(),
                                     self.instances[i].queue_len(), -i))
        elif idx not in cands:
            return None
        self.drain_instance(idx)
        self._retire_instance(idx)
        return idx

    def _retire_instance(self, idx: int):
        """Take a DRAINED instance out of the plane for good. Also
        registered in ``_recovered``: a deliberate removal must never be
        mistaken for a crash (its streams were migrated, not lost — a
        replay would duplicate them)."""
        self._retired.add(idx)
        self._recovered.add(idx)
        self._grace.discard(idx)
        self._respawn.pop(idx, None)   # a reaped slot is never respawned
        self._grown_at.pop(idx, None)
        try:
            self.instances[idx].close()
        except TR.TransportError:
            pass
        self.pod_log.append({"event": "shrink", "instance": idx,
                             "pod_size": self.pod_size()})
        self.flightrec.record("pod_shrink", instance=idx,
                              pod_size=self.pod_size())

    def _on_plan_change(self, plan: PlacementPlan, batch_size: int):
        """Controller callback: push the new replication degrees to every
        LIVE instance — the next decode step of each engine runs under
        the plan's per-layer batch sharding, no drain, no restart (for a
        remote instance the degree list travels as an RPC frame)."""
        self.plan = plan
        for i in self._alive():
            self.instances[i].apply_plan(list(plan.p))

    def _execute_scale_down(self) -> int:
        """Realize the controller's Phase-1 module migrations as KV-block
        transfers: whatever module the plan nominally moves, what a live
        instance can shed mid-decode is the memory-intensive module —
        its requests' paged KV (§3.3's preferred migrant). One rebalance
        per (src, dst) pair per phase, each OVERLAPPED (the source keeps
        decoding while the bulk snapshot stages at the destination).
        Returns the number of requests actually moved — the feedback
        signal ``control_tick``'s burst iteration keys on."""
        res = self.controller.last_scale_down
        if res is None:
            return 0
        seen = set()
        moved = 0
        for layer, comp, src, dst in res.migrations:
            if (src, dst) in seen or src == dst:
                continue
            if not (self.instances[src].alive()
                    and self.instances[dst].alive()):
                continue
            seen.add((src, dst))
            moved += len(self.migrate_requests_overlapped(src, dst))
        return moved

    # ------------------------------------------------------------ migration
    def migrate_requests(self, src: int, dst: int,
                         max_requests: Optional[int] = None
                         ) -> List[MigrationRecord]:
        """STOP-THE-WORLD migration (the baseline the overlapped path is
        benchmarked against): pause, ship everything, resume — the
        victim stream is out of decode rotation for the full transfer.
        Never drops: a request the destination pool can't hold is
        re-queued there and replays deterministically (counter-based
        sampling keys). Requests holding SHARED (refcounted) blocks
        migrate safely: the export materializes shared content into the
        payload and carries the prefix keys, so the stream stays
        token-identical and the destination's prefix cache learns the
        migrated prompt."""
        hsrc, hdst = self.instances[src], self.instances[dst]
        slots = sorted(hsrc.active_rids().keys())
        if max_requests is not None:
            slots = slots[:max_requests]
        out: List[MigrationRecord] = []
        for slot in slots:
            t0 = time.perf_counter()
            t_hop0 = OBS.server_now()
            t_obs = time.monotonic()
            try:
                payload = hsrc.pause_request(slot)
            except (TR.TransportClosed, TR.RpcTimeout) as e:
                # source died or hung mid-pause: either way its inflight
                # mirror (which still holds this stream — pause never
                # returned) replays on survivors
                self._fail_instance(src, hung=isinstance(e, TR.RpcTimeout),
                                    t_obs=t_obs)
                break
            req = payload["request"]
            # the destination must know the trace BEFORE the resume so
            # its engine records the continuation's spans (the explicit
            # registration path — no submit frame to piggyback on)
            self._register_trace_on(dst, req.rid)
            t_obs = time.monotonic()
            try:
                ok = hdst.resume_request(payload)
                if not ok:
                    hdst.requeue_front(req)  # zero-drop fallback: replay
            except (TR.TransportClosed, TR.RpcTimeout) as e:
                # destination died/hung AFTER the source detached the
                # stream: the payload in hand is the only copy — hand it
                # back to the (alive) source for deterministic replay,
                # then recover whatever else the destination held. A
                # HUNG destination is quarantined (killed) before the
                # replay, so even if it did import the payload it can
                # never decode it — no duplicated stream.
                if hsrc.alive():
                    hsrc.requeue_front(req)
                self._fail_instance(dst, hung=isinstance(e, TR.RpcTimeout),
                                    t_obs=t_obs)
                break
            dt = time.perf_counter() - t0
            nbytes = payload["kv"]["nbytes"]
            rec = MigrationRecord(
                rid=req.rid, src=src, dst=dst,
                n_blocks=len(payload["kv"]["cols"]),
                bytes_moved=nbytes, seconds=dt,
                est_seconds=MIG.estimate_cost(nbytes, self.link_bandwidth),
                resumed=ok, mode="stw", stall_s=dt)
            self._home[req.rid] = dst
            self.migrations.append(rec)
            self._record_migration(rec, t_hop0)
            out.append(rec)
        return out

    def _register_trace_on(self, idx: int, rid: int):
        """Re-associate a live trace with its rid on instance ``idx``
        (migration landing, crash replay). Best-effort: a transport
        failure here surfaces on the very next real op, which owns the
        recovery — tracing must never alter the control flow."""
        if self.tracer is None:
            return
        ctx = self.tracer.ctx(rid)
        if ctx is None:
            return
        try:
            self.instances[idx].register_trace(ctx)
        except (TR.TransportClosed, TR.RpcTimeout):
            pass

    def _record_migration(self, rec: MigrationRecord, t_hop0: float):
        """One executed migration -> a flight-recorder event (phase
        timings included) and, when the stream is traced, a
        ``migration_hop`` span parented under its request root."""
        self.flightrec.record(
            "migration", rid=rec.rid, src=rec.src, dst=rec.dst,
            mode=rec.mode, resumed=rec.resumed, n_blocks=rec.n_blocks,
            bytes_moved=rec.bytes_moved, seconds=rec.seconds,
            stall_s=rec.stall_s, delta_blocks=rec.delta_blocks,
            delta_bytes=rec.delta_bytes)
        if self.tracer is not None:
            self.tracer.span(
                rec.rid, "migration_hop", t_hop0, OBS.server_now(),
                origin="orchestrator",
                attrs={"src": rec.src, "dst": rec.dst, "mode": rec.mode,
                       "stall_s": rec.stall_s, "resumed": rec.resumed})

    def begin_migration(self, src: int, dst: int, slot: int) -> dict:
        """Phase 1 of an overlapped migration: snapshot the victim's
        blocks at the source WITHOUT pausing it, and pipeline the staging
        import at the destination (``prepare_resume_async`` — for a
        remote destination the import runs in its process while this one
        keeps stepping the source). Returns the migration ticket for
        ``finish_migration``."""
        hsrc, hdst = self.instances[src], self.instances[dst]
        t0 = time.perf_counter()
        snap = hsrc.snapshot_request(slot)
        pending = hdst.prepare_resume_async(snap)
        return {"src": src, "dst": dst, "slot": slot, "rid": snap["rid"],
                "epoch": snap["epoch"], "pending": pending,
                "snap_blocks": len(snap["kv"]["cols"]),
                "snap_bytes": snap["kv"]["nbytes"], "t0": t0,
                "t_hop0": OBS.server_now()}

    def finish_migration(self, ticket: dict) -> Optional[MigrationRecord]:
        """Phase 2: pause the victim, ship ONLY the dirty-set delta
        (blocks written since the phase-1 snapshot), commit at the
        destination, rotate the stream back in. The stream is out of
        decode rotation exactly for this window (``stall_s``). Falls
        back zero-drop at every exit: source finished/preempted the
        stream meanwhile -> abort staging; staging failed or the commit
        can't fit -> full re-queue + deterministic replay; a transport
        death -> crash recovery. Returns None when there was nothing
        left to move."""
        src, dst, slot = ticket["src"], ticket["dst"], ticket["slot"]
        hsrc, hdst = self.instances[src], self.instances[dst]
        t_obs = time.monotonic()
        try:
            staged = ticket["pending"].wait()
        except (TR.TransportClosed, TR.RpcTimeout) as e:
            self._fail_instance(dst, hung=isinstance(e, TR.RpcTimeout),
                                t_obs=t_obs)
            return None
        if hsrc.active_rids().get(slot) != ticket["rid"]:
            # finished or preempted at the source in the meantime: its
            # tokens/queue entry live there — nothing to move, but the
            # staged slots at the destination must be reclaimed
            if staged is not None:
                t_obs = time.monotonic()
                try:
                    hdst.abort_resume(staged)
                except (TR.TransportClosed, TR.RpcTimeout) as e:
                    self._fail_instance(
                        dst, hung=isinstance(e, TR.RpcTimeout),
                        t_obs=t_obs)
            return None
        # Each failure window below is handled per-peer so a fault
        # injected ANYWHERE between pause_request and commit_resume
        # leaves the source authoritative and the staged destination
        # slots reclaimed (by abort, or with the quarantined process).
        payload = None
        t_pause = time.perf_counter()
        t_obs = time.monotonic()
        try:
            if staged is None:
                # destination couldn't stage the bulk: classic path
                payload = hsrc.pause_request(slot)
            else:
                payload = hsrc.pause_request(slot,
                                             since_epoch=ticket["epoch"])
        except (TR.TransportClosed, TR.RpcTimeout) as e:
            # the SOURCE failed mid-pause: pause never returned, so its
            # inflight mirror still holds the stream — replay covers
            # it. Reclaim the staged slots at the (alive) destination.
            if staged is not None and hdst.alive():
                t_abort = time.monotonic()
                try:
                    hdst.abort_resume(staged)
                except (TR.TransportClosed, TR.RpcTimeout) as e2:
                    self._fail_instance(
                        dst, hung=isinstance(e2, TR.RpcTimeout),
                        t_obs=t_abort)
            self._fail_instance(src, hung=isinstance(e, TR.RpcTimeout),
                                t_obs=t_obs)
            return None
        self._register_trace_on(dst, ticket["rid"])
        t_obs = time.monotonic()
        try:
            if staged is None:
                ok = hdst.resume_request(payload)
            else:
                ok = hdst.commit_resume(staged, payload)
            req = payload["request"]
            if not ok:
                hdst.requeue_front(req)  # zero-drop fallback: replay
            stall = time.perf_counter() - t_pause
        except (TR.TransportClosed, TR.RpcTimeout) as e:
            # the DESTINATION failed between pause and commit — the
            # rollback-hardening window. The payload in hand is the
            # only copy: the source stays authoritative (requeue +
            # deterministic replay). The staged slots die with the
            # dead/quarantined destination process; a HUNG destination
            # is killed by the quarantine before replay, so a commit
            # that half-landed can never decode — no duplication.
            if hsrc.alive():
                hsrc.requeue_front(payload["request"])
            self._fail_instance(dst, hung=isinstance(e, TR.RpcTimeout),
                                t_obs=t_obs)
            return None
        shipped = payload["kv"]["nbytes"]   # delta, or the full re-ship
        delta_bytes = shipped if staged is not None else 0
        nbytes = ticket["snap_bytes"] + shipped
        rec = MigrationRecord(
            rid=req.rid, src=src, dst=dst,
            n_blocks=ticket["snap_blocks"],
            bytes_moved=nbytes, seconds=time.perf_counter() - ticket["t0"],
            est_seconds=MIG.estimate_cost(nbytes, self.link_bandwidth),
            resumed=ok, mode="overlapped", stall_s=stall,
            delta_blocks=(len(payload["kv"]["cols"])
                          if staged is not None else 0),
            delta_bytes=delta_bytes)
        self._home[req.rid] = dst
        self.migrations.append(rec)
        self._record_migration(rec, ticket["t_hop0"])
        return rec

    def migrate_requests_overlapped(self, src: int, dst: int,
                                    max_requests: Optional[int] = None,
                                    overlap_steps: int = 1
                                    ) -> List[MigrationRecord]:
        """Two-phase migration of the source's active requests: begin
        (snapshot + pipelined staging) for every victim, keep the WORLD
        decoding for ``overlap_steps`` engine steps — the source
        included: that is the overlap, and what the phase-2 dirty-set
        delta exists for — then finish (pause-delta-commit) each. The
        victim streams lose at most the one step in which their delta is
        copied."""
        hsrc = self.instances[src]
        slots = sorted(hsrc.active_rids().keys())
        if max_requests is not None:
            slots = slots[:max_requests]
        tickets = [self.begin_migration(src, dst, slot) for slot in slots]
        for _ in range(overlap_steps):
            # the overlap steps ride the same batched poll as the main
            # loop — the source keeps decoding while the destination's
            # staging import is still in flight on its connection
            done = self._step_all()
            self.finished.extend(done)
            self._orphans.extend(done)      # surfaced by the next step()
        out = []
        for t in tickets:
            rec = self.finish_migration(t)
            if rec is not None:
                out.append(rec)
        return out

    def drain_instance(self, idx: int) -> List[MigrationRecord]:
        """Scale-down consolidation: move EVERYTHING (queued requests +
        active KV blocks, the latter overlapped) off instance ``idx``
        onto the least-loaded other instance, leaving ``idx`` empty and
        removable. The queue hands off FIRST so the overlap steps can't
        re-admit at the source (submit_time is preserved: straight
        handoff, no re-submit)."""
        others = [i for i in self._alive() if i != idx]
        assert others, "cannot drain a single-instance deployment"
        dst = min(others, key=lambda i: (self.instances[i].active_count(),
                                         self.instances[i].queue_len()))
        for req in self.instances[idx].drain_queue():
            self._home[req.rid] = dst
            self.instances[dst].push_queue(req)
        return self.migrate_requests_overlapped(idx, dst)

    # ------------------------------------------------------ crash recovery
    def handle_instance_failure(self, idx: int, reason: str = "dead",
                                t_obs: Optional[float] = None,
                                ) -> List[Request]:
        """A remote instance failed (transport EOF, or quarantined
        hung): re-queue replayable clones of every stream it held —
        queued AND mid-decode — on the surviving instances.
        Counter-based sampling keys make the replays token-identical to
        the lost continuations, so the failure costs recompute, never
        output: the zero-drop invariant survives worker loss.
        Idempotent: one death can surface from several in-flight
        operations (a step, several migration tickets); only the FIRST
        observation replays — a duplicate replay would decode the same
        streams twice. Schedules a supervised respawn when a policy is
        armed and the instance is respawnable. Returns the replayed
        requests."""
        if idx in self._recovered:
            return []
        self._recovered.add(idx)
        self._grace.discard(idx)
        now = time.monotonic()
        # wall from when this peer's failure became OBSERVABLE (the
        # failing call's issue time, or the control fan-out for a step
        # classification) — the "hung peer detected within 2x deadline"
        # evidence
        ref = t_obs if t_obs is not None else self._fanout_t
        detect = max(0.0, now - ref) if ref else 0.0
        self.faults.detect_latencies.append(detect)
        h = self.instances[idx]
        replay = h.inflight_requests()
        try:
            h.close()
        except TR.TransportError:
            pass
        for req in replay:
            # a replay re-runs the request from scratch: rebuild the
            # construction-time spec (SLO class and deadline ride along)
            # and let the survivor's engine mint a fresh Request
            spec = RequestSpec.from_request(req)
            placed = False
            while not placed:
                survivors = self._alive()
                assert survivors, \
                    "every instance died: nothing to recover onto"
                j = self._route(survivors, spec=spec)
                # re-attach the live trace: the replayed continuation's
                # spans belong to the SAME tree as the lost ones
                trace = (self.tracer.ctx(req.rid)
                         if self.tracer else None)
                t_sub = time.monotonic()
                try:
                    if trace is None:
                        self.instances[j].submit(spec)
                    else:
                        self.instances[j].submit(spec, trace=trace)
                except (TR.TransportClosed, TR.RpcTimeout) as e:
                    # the chosen survivor failed DURING recovery. Its
                    # mirror already holds the clone (mirror-first
                    # submit), so failing it replays this stream — and
                    # everything else it held — onto the next survivor.
                    self._fail_instance(
                        j, hung=isinstance(e, TR.RpcTimeout),
                        t_obs=t_sub)
                    placed = True
                    continue
                self._home[req.rid] = j
                placed = True
        self.recoveries.append({"instance": idx, "reason": reason,
                                "detect_s": detect,
                                "rids": sorted(r.rid for r in replay)})
        self.flightrec.record("crash_recovery", instance=idx,
                              reason=reason, detect_s=detect,
                              replayed=len(replay),
                              rids=sorted(r.rid for r in replay))
        # the event that makes the recorder worth having: persist the
        # decision history that LED here before anything else goes wrong
        self.flightrec.auto_dump(f"crash_recovery:instance{idx}:{reason}")
        self._schedule_respawn(idx, now)
        return replay

    # -------------------------------------------------- supervised respawn
    def _schedule_respawn(self, idx: int, now: float):
        """Arm the supervisor for a failed instance: record the flap,
        then set the next bring-up attempt at a capped exponential
        backoff. No-op without a policy, for non-respawnable handles
        (attached servers belong to another host), and for evicted
        instances."""
        pol = self.respawn_policy
        h = self.instances[idx]
        if (pol is None or not getattr(h, "respawnable", False)
                or idx in self._evicted or idx in self._retired):
            return
        st = self._respawn.setdefault(
            idx, {"failures": deque(), "attempts": 0, "due": None,
                  "t_fail": now})
        st["t_fail"] = now
        self._record_flap(idx, st, now)
        if idx in self._evicted:
            return
        delay = min(pol.backoff_base * (2 ** st["attempts"]),
                    pol.backoff_cap)
        st["due"] = now + delay

    def _record_flap(self, idx: int, st: dict, now: float):
        """Flap-detector circuit breaker: ``max_failures`` failures of
        the same instance inside ``window_s`` evict it permanently."""
        pol = self.respawn_policy
        fails = st["failures"]
        fails.append(now)
        while fails and now - fails[0] > pol.window_s:
            fails.popleft()
        if len(fails) >= pol.max_failures:
            self._evicted.add(idx)
            self.faults.evictions += 1
            st["due"] = None
            self.respawn_log.append({
                "instance": idx, "event": "evicted",
                "failures_in_window": len(fails)})
            self.flightrec.record("evicted", instance=idx,
                                  failures_in_window=len(fails))

    def _tick_respawns(self):
        """Run due respawns (called at the top of every ``step()`` —
        the supervisor never blocks the serving loop waiting out a
        backoff). A successful bring-up swaps the fresh handle in
        place: same index, same Device in the controller's cluster
        view, empty pool/queue — the controller re-admits it the same
        way it admits any vacant instance. A failed bring-up counts as
        another flap and re-arms with doubled backoff."""
        if not self._respawn:
            return
        pol = self.respawn_policy
        for idx, st in self._respawn.items():
            if (st["due"] is None or idx in self._evicted
                    or time.monotonic() < st["due"]):
                continue
            st["due"] = None
            st["attempts"] += 1
            old = self.instances[idx]
            try:
                fresh = old.respawn(start_timeout=pol.start_timeout)
            except Exception:  # noqa: BLE001 — ANY bring-up failure flaps
                now = time.monotonic()
                self.flightrec.record("respawn_failed", instance=idx,
                                      attempt=st["attempts"])
                self._record_flap(idx, st, now)
                if idx not in self._evicted:
                    st["due"] = now + min(
                        pol.backoff_base * (2 ** st["attempts"]),
                        pol.backoff_cap)
                continue
            if self.rpc_deadline is not None:
                # cold-start grace (see __init__): arm the deadline only
                # after the replica's first completed ACTIVE step
                fresh.set_rpc_deadline(None)
                self._grace.add(idx)
            self.instances[idx] = fresh
            self.telemetry[idx] = fresh.telemetry
            self._preempt_seen[idx] = 0
            self._recovered.discard(idx)   # re-admitted: may fail anew
            self.faults.respawns += 1
            st["attempts"] = 0
            self.respawn_log.append({
                "instance": idx, "event": "respawned",
                "label": getattr(fresh, "peer_label", None),
                "downtime_s": time.monotonic() - st["t_fail"]})
            self.flightrec.record(
                "respawned", instance=idx,
                downtime_s=time.monotonic() - st["t_fail"])

    # -------------------------------------------------------------- summary
    def stats(self) -> Dict:
        ps = [self.instances[i].prefix_stats() for i in self._alive()]
        pq = sum(p["queries"] for p in ps)
        ph = sum(p["hits"] for p in ps)
        ov = [m for m in self.migrations if m.mode == "overlapped"]
        tel = [self.telemetry[i] for i in self._alive()]
        buds = [t.budget_utilization() for t in tel if t.budget]
        return {
            "budget_utilization": (sum(buds) / len(buds)
                                   if buds else 0.0),
            "ttft_p50": max((t.ttft_quantile(0.5) for t in tel),
                            default=0.0),
            "ttft_p95": max((t.ttft_quantile(0.95) for t in tel),
                            default=0.0),
            "queue_delay_p95": max((t.queue_delay_quantile(0.95)
                                    for t in tel), default=0.0),
            "finished": len(self.finished),
            "dropped": self.dropped,
            "migrations": len(self.migrations),
            "migrated_bytes": sum(m.bytes_moved for m in self.migrations),
            "overlapped_migrations": len(ov),
            "mean_stall_s": (sum(m.stall_s for m in ov) / len(ov)
                             if ov else 0.0),
            "preemptions": sum(self._preempt_seen),
            "recoveries": len(self.recoveries),
            "prefix_hit_rate": ph / pq if pq else 0.0,
            "blocks_saved_now": sum(p["blocks_saved_now"] for p in ps),
            "dedup_imports": sum(p.get("dedup_imports", 0) for p in ps),
            "controller_log": list(self.controller.log),
            "plan_p": list(self.plan.p),
            "control_plane": self.control_plane_stats(),
            "faults": dict(self.faults.as_dict(),
                           injected=FLT.injected_total()),
            "respawn_log": list(self.respawn_log),
            "pod": {"size": self.pod_size(),
                    "retired": sorted(self._retired),
                    "grown": sorted(self._grown_at),
                    "log": list(self.pod_log)},
        }

    def control_plane_stats(self) -> Dict:
        """Batched-poll accounting: with any remote instance, every tick
        issues exactly ONE multiplexed drain (``rpc_polls_per_tick`` ==
        1.0) regardless of how many step RPCs fanned out under it."""
        ticks = self.rpc_stats["ticks"]
        return {
            "ticks": ticks,
            "rpc_polls_per_tick": (self.rpc_stats["polls"] / ticks
                                   if ticks else 0.0),
            "step_rpcs_per_tick": (self.rpc_stats["step_rpcs"] / ticks
                                   if ticks else 0.0),
        }
