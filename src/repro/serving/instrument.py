"""Instrumentation for the serving hot loop.

``count_host_syncs()`` patches ``jax.device_get`` — the one primitive the
engines use for every device→host read — and counts calls. The engines
deliberately never use ``int(arr)`` / ``np.asarray(arr)`` on device arrays
in their steady-state step, so the counter is an exact census of blocking
syncs per ``Engine.step`` (the quantity the paged-engine acceptance bound
"≤ 1 host sync per step" is asserted against in tests and reported by
benchmarks/paged_engine_bench.py).

``EngineTelemetry`` is the LIVE metrics source of the module-scaling loop:
the orchestrator records every engine step (wall seconds, tokens) and
every finished request (engine-clock latency) here, and turns the rolling
windows into ``core.monitor.MetricsSnapshot``s — the paper's NVML+timer
feed, replaced by real engine counters instead of synthetic traces.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Deque, Iterable

import jax
import numpy as np


@dataclasses.dataclass
class SyncCounter:
    n: int = 0


class EngineTelemetry:
    """Rolling-window per-engine counters feeding core/monitor."""

    def __init__(self, window: int = 64):
        self.step_seconds: Deque[float] = deque(maxlen=window)
        self.step_tokens: Deque[int] = deque(maxlen=window)
        self.finished_latencies: Deque[float] = deque(maxlen=window)
        self.total_tokens = 0
        self.total_finished = 0
        self.preemptions_seen = 0
        # prefix-sharing gauges (latest engine counters, not windows):
        # cumulative cache lookups/hits plus the INSTANTANEOUS number of
        # physical blocks sharing is saving — the quantity that inflates
        # the controller's pool-vacancy signal
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.blocks_saved = 0

    def record_step(self, wall_s: float, n_tokens: int):
        self.step_seconds.append(wall_s)
        self.step_tokens.append(n_tokens)
        self.total_tokens += n_tokens

    def record_finished(self, requests: Iterable):
        for r in requests:
            self.finished_latencies.append(r.finish_time - r.submit_time)
            self.total_finished += 1

    def record_preemptions(self, n: int):
        self.preemptions_seen += n

    def record_prefix(self, queries: int, hits: int, blocks_saved_now: int):
        """Overwrite the sharing gauges with the engine's live counters
        (queries/hits are cumulative on the engine side; blocks saved is
        an instantaneous point read)."""
        self.prefix_queries = queries
        self.prefix_hits = hits
        self.blocks_saved = blocks_saved_now

    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up full prompt blocks served by aliasing an
        already-resident block instead of re-prefilling it."""
        return (self.prefix_hits / self.prefix_queries
                if self.prefix_queries else 0.0)

    def tokens_per_s(self) -> float:
        wall = sum(self.step_seconds)
        return sum(self.step_tokens) / wall if wall > 0 else 0.0

    def mean_step_s(self) -> float:
        return (sum(self.step_seconds) / len(self.step_seconds)
                if self.step_seconds else 0.0)

    def latency_quantile(self, q: float) -> float:
        if not self.finished_latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.finished_latencies), q))

    def slo_violation_rate(self, slo_latency: float) -> float:
        """Fraction of recently finished requests whose ENGINE-CLOCK
        latency (finish - submit) blew the SLO — the §5 scale-down
        trigger, measured on real requests rather than a trace."""
        if not self.finished_latencies:
            return 0.0
        lats = np.asarray(self.finished_latencies)
        return float((lats > slo_latency).mean())


@contextlib.contextmanager
def count_host_syncs():
    """Context manager yielding a SyncCounter; every ``jax.device_get``
    inside the block increments it."""
    counter = SyncCounter()
    orig = jax.device_get

    def counted(x):
        counter.n += 1
        return orig(x)

    jax.device_get = counted
    try:
        yield counter
    finally:
        jax.device_get = orig
