"""Instrumentation for the serving hot loop.

``count_host_syncs()`` patches ``jax.device_get`` — the one primitive the
engines use for every device→host read — and counts calls. The engines
deliberately never use ``int(arr)`` / ``np.asarray(arr)`` on device arrays
in their steady-state step, so the counter is an exact census of blocking
syncs per ``Engine.step`` (the quantity the paged-engine acceptance bound
"≤ 1 host sync per step" is asserted against in tests and reported by
benchmarks/paged_engine_bench.py).

``EngineTelemetry`` is the LIVE metrics source of the module-scaling loop:
the orchestrator records every engine step (wall seconds, tokens) and
every finished request (engine-clock latency) here, and turns the rolling
windows into ``core.monitor.MetricsSnapshot``s — the paper's NVML+timer
feed, replaced by real engine counters instead of synthetic traces.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import deque
from typing import Deque, Iterable, Optional

import jax
import numpy as np


@dataclasses.dataclass
class SyncCounter:
    n: int = 0


@dataclasses.dataclass
class FaultCounters:
    """Plane-wide failure-domain counters, owned by the orchestrator
    (one per plane, not per engine — a quarantine is a fleet event).
    Feeds the ``rpc_timeouts`` / ``quarantines`` / ``respawns`` gauges
    of ``core.monitor.MetricsSnapshot`` and the recovery-latency
    percentiles of benchmarks/chaos_bench.py."""
    rpc_timeouts: int = 0     # step/control calls that missed a deadline
    quarantines: int = 0      # hung peers severed (socket open, no reply)
    respawns: int = 0         # supervised restarts that re-admitted
    evictions: int = 0        # flap-detector permanent removals
    # wall seconds from control fan-out to failure classification, one
    # entry per recovery — the "detected within 2x deadline" evidence.
    # Bounded: a week-long chaos soak records one float per recovery
    # forever, and the quantiles only need the recent window anyway.
    detect_latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=512))

    def detect_quantile(self, q: float) -> float:
        if not self.detect_latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.detect_latencies), q))

    def as_dict(self) -> dict:
        return {"rpc_timeouts": self.rpc_timeouts,
                "quarantines": self.quarantines,
                "respawns": self.respawns,
                "evictions": self.evictions,
                "detect_p50_s": self.detect_quantile(0.50),
                "detect_p95_s": self.detect_quantile(0.95)}


@dataclasses.dataclass
class IngressCounters:
    """Front-door counters, owned by ``serving.ingress.Ingress`` (one
    per server). Surfaced by ``GET /stats`` next to the orchestrator's
    MetricsSnapshot, and the evidence benchmarks/ingress_bench.py and
    tests/test_ingress.py assert on (routed_prefix vs routed_vacancy is
    the affinity-hit ledger; rejected_429 the backpressure one)."""
    requests: int = 0         # completions requests accepted
    streamed: int = 0         # of those, served with stream=true
    rejected_429: int = 0     # admissions shed by backpressure
    bad_requests: int = 0     # malformed -> HTTP 400
    tokens_out: int = 0       # tokens flushed to clients
    routed_prefix: int = 0    # admissions routed by chain affinity
    routed_vacancy: int = 0   # admissions routed by vacancy fallback
    aborted_streams: int = 0  # streams cut by shutdown / client hangup

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class EngineTelemetry:
    """Rolling-window per-engine counters feeding core/monitor."""

    def __init__(self, window: int = 64):
        self.step_seconds: Deque[float] = deque(maxlen=window)
        self.step_tokens: Deque[int] = deque(maxlen=window)
        self.finished_latencies: Deque[float] = deque(maxlen=window)
        # continuous-batching signals (token-budget scheduler): tokens
        # PACKED per step (decode + granted prefill chunk tokens) against
        # the engine's fixed budget, time-to-first-token and queue delay
        # per finished request — all on the ENGINE clock, like latencies
        self.packed_tokens: Deque[int] = deque(maxlen=window)
        self.budget = 0
        self.ttfts: Deque[float] = deque(maxlen=window)
        self.queue_delays: Deque[float] = deque(maxlen=window)
        # per-SLO-class rolling windows (class -> deque), populated
        # lazily so a plane that never sends slo_class pays nothing.
        # TTFT as above; ITL is the per-finished-request mean
        # inter-token gap ON THE ENGINE CLOCK — ~1.0 for a stream that
        # decoded every step, >1 when preemption/budget pressure
        # stalled it, which is exactly the per-class fairness signal
        self.class_ttfts: dict = {}
        self.class_itls: dict = {}
        self.total_tokens = 0
        self.total_finished = 0
        self.preemptions_seen = 0
        # prefix-sharing gauges (latest engine counters, not windows):
        # cumulative cache lookups/hits plus the INSTANTANEOUS number of
        # physical blocks sharing is saving — the quantity that inflates
        # the controller's pool-vacancy signal
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.blocks_saved = 0

    def record_step(self, wall_s: float, n_tokens: int,
                    packed: Optional[int] = None,
                    budget: Optional[int] = None):
        self.step_seconds.append(wall_s)
        self.step_tokens.append(n_tokens)
        self.total_tokens += n_tokens
        if packed is not None:
            self.packed_tokens.append(packed)
        if budget:
            self.budget = budget

    def record_finished(self, requests: Iterable):
        w = self.step_seconds.maxlen
        for r in requests:
            self.finished_latencies.append(r.finish_time - r.submit_time)
            self.total_finished += 1
            cls = getattr(r, "slo_class", "standard")
            if r.first_token_time is not None:
                ttft = r.first_token_time - r.submit_time
                self.ttfts.append(ttft)
                self.class_ttfts.setdefault(
                    cls, deque(maxlen=w)).append(ttft)
                n = len(getattr(r, "generated", ()))
                if n > 1:
                    itl = (r.finish_time - r.first_token_time) / (n - 1)
                    self.class_itls.setdefault(
                        cls, deque(maxlen=w)).append(itl)
            start = getattr(r, "prefill_start_time", None)
            if start is not None:
                self.queue_delays.append(start - r.submit_time)

    def record_preemptions(self, n: int):
        self.preemptions_seen += n

    def record_prefix(self, queries: int, hits: int, blocks_saved_now: int):
        """Overwrite the sharing gauges with the engine's live counters
        (queries/hits are cumulative on the engine side; blocks saved is
        an instantaneous point read)."""
        self.prefix_queries = queries
        self.prefix_hits = hits
        self.blocks_saved = blocks_saved_now

    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up full prompt blocks served by aliasing an
        already-resident block instead of re-prefilling it."""
        return (self.prefix_hits / self.prefix_queries
                if self.prefix_queries else 0.0)

    def budget_utilization(self) -> float:
        """Mean fraction of the per-step token budget actually packed
        (decode tokens + granted prefill chunks) over the window — the
        continuous-batching load gauge: ~1.0 means the step loop is
        saturated, low values mean the budget could shrink (latency) or
        traffic is light. 0.0 when the engine runs the phase scheduler
        (no budget to pack)."""
        if not self.budget or not self.packed_tokens:
            return 0.0
        return (sum(self.packed_tokens)
                / (len(self.packed_tokens) * self.budget))

    def ttft_quantile(self, q: float) -> float:
        """Engine-clock time-to-first-token quantile over the window —
        the signal chunked prefill exists to bound: admission no longer
        waits for a whole free slot + full-prompt prefill."""
        if not self.ttfts:
            return 0.0
        return float(np.quantile(np.asarray(self.ttfts), q))

    def queue_delay_quantile(self, q: float) -> float:
        """Engine-clock submit -> first-chunk-admitted delay quantile."""
        if not self.queue_delays:
            return 0.0
        return float(np.quantile(np.asarray(self.queue_delays), q))

    def class_ttft_quantile(self, cls: str, q: float) -> float:
        """Per-SLO-class TTFT quantile (0.0 when the class has no
        finished requests in the window yet)."""
        d = self.class_ttfts.get(cls)
        if not d:
            return 0.0
        return float(np.quantile(np.asarray(d), q))

    def class_itl_quantile(self, cls: str, q: float) -> float:
        """Per-SLO-class mean-inter-token-latency quantile (engine
        clock; 1.0 = never stalled)."""
        d = self.class_itls.get(cls)
        if not d:
            return 0.0
        return float(np.quantile(np.asarray(d), q))

    def tokens_per_s(self) -> float:
        wall = sum(self.step_seconds)
        return sum(self.step_tokens) / wall if wall > 0 else 0.0

    def mean_step_s(self) -> float:
        return (sum(self.step_seconds) / len(self.step_seconds)
                if self.step_seconds else 0.0)

    def latency_quantile(self, q: float) -> float:
        if not self.finished_latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.finished_latencies), q))

    def slo_violation_rate(self, slo_latency: float) -> float:
        """Fraction of recently finished requests whose ENGINE-CLOCK
        latency (finish - submit) blew the SLO — the §5 scale-down
        trigger, measured on real requests rather than a trace."""
        if not self.finished_latencies:
            return 0.0
        lats = np.asarray(self.finished_latencies)
        return float((lats > slo_latency).mean())

    # ------------------------------------------------ wire serialization
    # A remote engine server (serving/remote_engine.py) measures its own
    # steps — wall seconds WITHOUT the RPC round trip — and ships this
    # state back piggybacked on every step reply; the orchestrator-side
    # mirror is refreshed with load_state, so core/monitor sees the same
    # schema whether the engine is a local object or another process.

    def to_state(self) -> dict:
        return {"window": self.step_seconds.maxlen,
                "step_seconds": list(self.step_seconds),
                "step_tokens": list(self.step_tokens),
                "finished_latencies": list(self.finished_latencies),
                "total_tokens": self.total_tokens,
                "total_finished": self.total_finished,
                "preemptions_seen": self.preemptions_seen,
                "prefix_queries": self.prefix_queries,
                "prefix_hits": self.prefix_hits,
                "blocks_saved": self.blocks_saved,
                "packed_tokens": list(self.packed_tokens),
                "budget": self.budget,
                "ttfts": list(self.ttfts),
                "queue_delays": list(self.queue_delays),
                "class_ttfts": {c: list(d)
                                for c, d in self.class_ttfts.items()},
                "class_itls": {c: list(d)
                               for c, d in self.class_itls.items()}}

    def load_state(self, state: dict):
        """Overwrite this telemetry with a serialized snapshot (in place:
        the orchestrator holds a reference to this object)."""
        w = state.get("window") or self.step_seconds.maxlen
        self.step_seconds = deque(state["step_seconds"], maxlen=w)
        self.step_tokens = deque(state["step_tokens"], maxlen=w)
        self.finished_latencies = deque(state["finished_latencies"],
                                        maxlen=w)
        self.total_tokens = state["total_tokens"]
        self.total_finished = state["total_finished"]
        self.preemptions_seen = state["preemptions_seen"]
        self.prefix_queries = state["prefix_queries"]
        self.prefix_hits = state["prefix_hits"]
        self.blocks_saved = state["blocks_saved"]
        # .get defaults: replies from an engine server predating the
        # continuous-batching gauges still load
        self.packed_tokens = deque(state.get("packed_tokens", []),
                                   maxlen=w)
        self.budget = state.get("budget", 0)
        self.ttfts = deque(state.get("ttfts", []), maxlen=w)
        self.queue_delays = deque(state.get("queue_delays", []),
                                  maxlen=w)
        self.class_ttfts = {c: deque(v, maxlen=w) for c, v
                            in state.get("class_ttfts", {}).items()}
        self.class_itls = {c: deque(v, maxlen=w) for c, v
                           in state.get("class_itls", {}).items()}


def timed_step(engine, telemetry: EngineTelemetry):
    """Run one engine step and record it into ``telemetry`` — THE step
    accounting definition, shared by the local handle
    (serving/instance.LocalInstance) and the remote engine server
    (serving/remote_engine.EngineServer) so the two planes' metrics can
    never silently diverge. Returns the finished requests."""
    import time
    t0 = time.perf_counter()
    done = engine.step() or []
    telemetry.record_step(time.perf_counter() - t0,
                          len(engine.active) + len(done),
                          packed=getattr(engine, "last_step_packed", None),
                          budget=getattr(engine, "token_budget", 0))
    telemetry.record_finished(done)
    return done


# count_host_syncs patches the GLOBAL jax.device_get: with nested or
# concurrent contexts (an ingress pump thread stepping engines while a
# test counts its own block), naive save/restore corrupts the chain —
# the inner exit can reinstall an outer context's counted wrapper as
# "the original". Instead: one process-wide patch installed when the
# FIRST context enters and removed when the LAST leaves, every active
# counter incremented per sync.
_sync_lock = threading.Lock()
_sync_active: list = []
_sync_orig = None


@contextlib.contextmanager
def count_host_syncs():
    """Context manager yielding a SyncCounter; every ``jax.device_get``
    anywhere in the process increments it while the block is active.
    Re-entrant and thread-safe: nested/concurrent contexts each get an
    exact count, and the original ``jax.device_get`` is restored only
    when the outermost context exits."""
    global _sync_orig
    counter = SyncCounter()
    with _sync_lock:
        if not _sync_active:
            _sync_orig = jax.device_get

            def counted(x):
                with _sync_lock:
                    active = list(_sync_active)
                for c in active:
                    c.n += 1
                return _sync_orig(x)

            jax.device_get = counted
        _sync_active.append(counter)
    try:
        yield counter
    finally:
        with _sync_lock:
            _sync_active.remove(counter)
            if not _sync_active:
                jax.device_get = _sync_orig
                _sync_orig = None
