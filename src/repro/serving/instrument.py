"""Host-sync instrumentation for the serving hot loop.

``count_host_syncs()`` patches ``jax.device_get`` — the one primitive the
engines use for every device→host read — and counts calls. The engines
deliberately never use ``int(arr)`` / ``np.asarray(arr)`` on device arrays
in their steady-state step, so the counter is an exact census of blocking
syncs per ``Engine.step`` (the quantity the paged-engine acceptance bound
"≤ 1 host sync per step" is asserted against in tests and reported by
benchmarks/paged_engine_bench.py).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax


@dataclasses.dataclass
class SyncCounter:
    n: int = 0


@contextlib.contextmanager
def count_host_syncs():
    """Context manager yielding a SyncCounter; every ``jax.device_get``
    inside the block increments it."""
    counter = SyncCounter()
    orig = jax.device_get

    def counted(x):
        counter.n += 1
        return orig(x)

    jax.device_get = counted
    try:
        yield counter
    finally:
        jax.device_get = orig
