"""Observability for the serving plane: per-request tracing, Prometheus
text-format metrics, and the control-plane flight recorder.

Stdlib-only by design — no tracing SDK, no prometheus client. The three
subsystems share one file because they share one job: turning the
plane's decisions (routing, packing, migration, recovery) into evidence
that survives the process.

Span model (DESIGN.md §12)
--------------------------
A trace is one completion's timeline; the ROOT span's id IS the trace
id. Every other span parents either the root (accept / route / queue /
prefill / decode / migration hops) or a locally generated engine span
(prefill chunk -> its prefill span), so the tree is connected BY
CONSTRUCTION — no cross-process id coordination. Timestamps are
``time.monotonic()`` seconds in the INGRESS process's clock domain:
spans recorded inside a remote engine server are stamped with the
server's clock (``server_now``) and shifted by the proxy's RTT-estimated
offset on ingestion (``estimate_clock_offset`` — NTP-style midpoint of
the minimum-RTT sample), so one timeline holds across processes.

Ownership / thread safety
-------------------------
``Tracer`` and ``FlightRecorder`` are lock-protected (the ingress HTTP
thread records accept/route while the pump thread drains engine spans).
``EngineSpanRecorder`` is deliberately lock-free: it is owned by
whichever single thread steps its engine (the ingress pump, or a remote
engine server's serve loop) and drained from that same thread.
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import re
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional

# test seam: a spawned engine server started with this env var reports a
# skewed clock from server_now() — the proxy's offset estimation must
# correct it back out for the cross-process span test to pass
_SKEW_ENV = "REPRO_TRACE_CLOCK_SKEW"

_span_seq = itertools.count(1)


def server_now() -> float:
    """This process's span clock: monotonic seconds, plus the injected
    artificial skew when ``REPRO_TRACE_CLOCK_SKEW`` is set (inherited
    through spawn by test engine servers)."""
    return time.monotonic() + float(os.environ.get(_SKEW_ENV, 0) or 0)


def _new_span_id() -> str:
    """Process-unique span id (pid prefix makes it plane-unique in
    practice) — ids never coordinate across processes; tree
    connectivity comes from parenting, not id agreement."""
    return f"{os.getpid():x}.{next(_span_seq)}"


def make_span(trace_id: str, name: str, t0: float,
              t1: Optional[float] = None, *, parent: Optional[str] = None,
              origin: str = "", attrs: Optional[dict] = None,
              span_id: Optional[str] = None) -> dict:
    return {"trace": trace_id, "id": span_id or _new_span_id(),
            "parent": trace_id if parent is None else parent,
            "name": name, "t0": t0, "t1": t1, "origin": origin,
            "attrs": dict(attrs) if attrs else {}}


def estimate_clock_offset(call: Callable[[], float],
                          samples: int = 5) -> float:
    """Estimate a remote peer's clock offset from round trips: ``call``
    performs one blocking RPC returning the peer's ``server_now()``.
    Keeps the minimum-RTT sample (least queueing noise) and assumes the
    reply was stamped at the round trip's midpoint — classic NTP.
    ``remote_time - offset`` lands on this process's clock."""
    best_rtt, best_off = None, 0.0
    for _ in range(max(1, samples)):
        t0 = time.monotonic()
        ts = call()
        t1 = time.monotonic()
        rtt = t1 - t0
        if best_rtt is None or rtt < best_rtt:
            best_rtt, best_off = rtt, ts - (t0 + t1) / 2.0
    return best_off


def correct_spans(spans: Iterable[dict], offset: float) -> List[dict]:
    """Shift remote-stamped spans onto the local clock (in place)."""
    out = list(spans)
    if offset:
        for s in out:
            s["t0"] -= offset
            if s.get("t1") is not None:
                s["t1"] -= offset
    return out


def span_tree_ok(spans: List[dict]) -> Optional[str]:
    """Structural validation of one finished trace: exactly one root,
    every parent resolves, every span closed with t1 >= t0, children
    inside [root.t0 - eps, root.t1 + eps]. Returns None when the tree is
    sound, else a human-readable violation (test + bench assert on
    this)."""
    if not spans:
        return "empty trace"
    ids = {s["id"] for s in spans}
    roots = [s for s in spans if s["parent"] is None]
    if len(roots) != 1:
        return f"{len(roots)} roots (want exactly 1)"
    root = roots[0]
    eps = 5e-3  # clock-correction residual tolerance
    for s in spans:
        if s["parent"] is not None and s["parent"] not in ids:
            return f"orphan span {s['name']!r}: parent {s['parent']!r}"
        if s.get("t1") is None:
            return f"span {s['name']!r} never closed"
        if s["t1"] < s["t0"]:
            return f"span {s['name']!r} ends before it starts"
        if s is not root and (s["t0"] < root["t0"] - eps
                              or s["t1"] > root["t1"] + eps):
            return (f"span {s['name']!r} [{s['t0']:.4f},{s['t1']:.4f}] "
                    f"outside root [{root['t0']:.4f},{root['t1']:.4f}]")
    return None


# ===================================================================== tracing
class EngineSpanRecorder:
    """Engine-side span hook (``engine.span_hook``): turns lifecycle
    callbacks into closed spans, buffered until ``drain``. Installed on
    a LocalInstance's engine by the orchestrator and on a remote
    server's engine the first time a trace context arrives over RPC.
    Only REGISTERED rids record (tracing off => every hook is a dict
    miss and nothing allocates)."""

    def __init__(self, origin: str = "engine"):
        self.origin = origin
        self._traces: Dict[int, str] = {}         # rid -> trace id
        self._open: Dict[int, Dict[str, dict]] = {}   # rid -> name -> span
        self._prefill_done: set = set()
        self._buf: List[dict] = []                # closed, awaiting drain

    def now(self) -> float:
        return server_now()

    def register(self, rid: int, trace_id: str):
        self._traces[rid] = trace_id

    def _forget(self, rid: int):
        self._traces.pop(rid, None)
        self._open.pop(rid, None)
        self._prefill_done.discard(rid)

    def _start(self, rid: int, name: str, t0: float,
               parent: Optional[str] = None) -> dict:
        span = make_span(self._traces[rid], name, t0, parent=parent,
                         origin=self.origin)
        self._open.setdefault(rid, {})[name] = span
        return span

    def _close(self, rid: int, name: str, t1: float, **attrs):
        span = self._open.get(rid, {}).pop(name, None)
        if span is not None:
            span["t1"] = t1
            span["attrs"].update(attrs)
            self._buf.append(span)

    # ------------------------------------------------- engine lifecycle
    def on_submit(self, req):
        if req.rid in self._traces:
            self._start(req.rid, "queue", self.now())

    def on_chunk(self, rid: int, start: int, n: int, t0: float, t1: float):
        """One executed prefill chunk [start, start+n); chunks parent
        the rid's prefill span (opened at the first chunk)."""
        if rid not in self._traces:
            return
        self._close(rid, "queue", t0)
        pre = self._open.get(rid, {}).get("prefill")
        if pre is None:
            pre = self._start(rid, "prefill", t0)
        chunk = make_span(self._traces[rid], "prefill_chunk", t0, t1,
                          parent=pre["id"], origin=self.origin,
                          attrs={"start": start, "n": n})
        self._buf.append(chunk)

    def on_activate(self, req, fresh_first: bool):
        """Request entered decode rotation (or finished at admission).
        ``fresh_first`` is True only when this activation SAMPLED the
        first token — a resumed/migrated continuation reopens decode
        without re-emitting first_token."""
        rid = req.rid
        if rid not in self._traces:
            return
        t = self.now()
        self._close(rid, "queue", t)
        if rid not in self._prefill_done:
            if "prefill" not in self._open.get(rid, {}):
                # wave path: whole prompt in one forward, no chunk spans
                self._start(rid, "prefill", t)
            self._close(rid, "prefill", t)
            self._prefill_done.add(rid)
        else:
            self._close(rid, "prefill", t)
        if fresh_first:
            self._buf.append(make_span(self._traces[rid], "first_token",
                                       t, t, origin=self.origin))
        self._start(rid, "decode", t)

    def on_resume(self, req, phase: str):
        """Migrated-in continuation bound on THIS engine: reopen the
        span the destination now owns (decode, or prefill for a
        mid-prefill hop — its remaining chunks reopen prefill anyway)."""
        if req.rid in self._traces and phase == "decode":
            self._prefill_done.add(req.rid)
            self._start(req.rid, "decode", self.now())

    def on_finish(self, req):
        rid = req.rid
        if rid not in self._traces:
            return
        t = self.now()
        for name in list(self._open.get(rid, {})):
            self._close(rid, name, t)
        self._forget(rid)

    def on_pause(self, rid: int):
        """Request paused for migration off this engine: close whatever
        is open here — the destination opens its own continuation."""
        if rid not in self._traces:
            return
        t = self.now()
        for name in list(self._open.get(rid, {})):
            self._close(rid, name, t, paused=True)
        self._forget(rid)

    def on_preempt(self, rid: int):
        """Preempted back to this engine's own queue: close open spans
        (the replay re-opens them) but keep the registration."""
        if rid not in self._traces:
            return
        t = self.now()
        for name in list(self._open.get(rid, {})):
            self._close(rid, name, t, preempted=True)
        self._prefill_done.discard(rid)

    def drain(self) -> List[dict]:
        """Closed spans since the last drain (open spans stay put)."""
        if not self._buf:
            return []
        out, self._buf = self._buf, []
        return out


class Tracer:
    """Ingress/orchestrator-side trace aggregator: owns trace ids, the
    root span, ingress-local spans (accept/route/migration hops), and
    ingestion of engine-recorded spans; finished traces go to the JSONL
    sink plus a bounded in-memory ring (tests, debugging)."""

    def __init__(self, out_path: Optional[str] = None, keep: int = 256):
        self._lock = threading.Lock()
        self._out_path = out_path
        self._out = None
        self._live: Dict[int, dict] = {}        # rid -> record
        self._by_trace: Dict[str, int] = {}     # trace id -> rid
        self.finished: collections.deque = collections.deque(maxlen=keep)
        self.exported = 0
        self.dropped_spans = 0   # spans for unknown/finished traces

    # ------------------------------------------------------- lifecycle
    def begin(self, rid: int, t0: Optional[float] = None,
              **attrs) -> str:
        """Open a trace for ``rid``; returns the trace id (also the
        response's X-Request-Id). ``t0`` backdates the root to when the
        request actually arrived (the ingress parses before it
        begins — children must stay inside the root window)."""
        trace_id = f"req-{rid}-{_new_span_id()}"
        root = make_span(trace_id, "request",
                         server_now() if t0 is None else t0,
                         origin="ingress", attrs=attrs, span_id=trace_id)
        root["parent"] = None
        with self._lock:
            self._live[rid] = {"trace_id": trace_id, "rid": rid,
                               "spans": [root]}
            self._by_trace[trace_id] = rid
        return trace_id

    def ctx(self, rid: int) -> Optional[dict]:
        """The propagation context that rides RPC frames."""
        with self._lock:
            rec = self._live.get(rid)
            return ({"trace_id": rec["trace_id"], "rid": rid}
                    if rec else None)

    def trace_id(self, rid: int) -> Optional[str]:
        with self._lock:
            rec = self._live.get(rid)
            return rec["trace_id"] if rec else None

    def span(self, rid: int, name: str, t0: float,
             t1: Optional[float] = None, *, origin: str = "ingress",
             attrs: Optional[dict] = None) -> Optional[dict]:
        """Record one root-parented span (t1 defaults to now)."""
        with self._lock:
            rec = self._live.get(rid)
            if rec is None:
                self.dropped_spans += 1
                return None
            s = make_span(rec["trace_id"], name, t0,
                          server_now() if t1 is None else t1,
                          origin=origin, attrs=attrs)
            rec["spans"].append(s)
            return s

    def ingest(self, spans: Iterable[dict]):
        """Bulk-add engine-recorded spans (already clock-corrected by
        the proxy); spans whose trace has finished/never existed are
        counted and dropped, never raised."""
        with self._lock:
            for s in spans:
                rid = self._by_trace.get(s.get("trace"))
                if rid is None:
                    self.dropped_spans += 1
                    continue
                self._live[rid]["spans"].append(s)

    def annotate(self, rid: int, **attrs):
        with self._lock:
            rec = self._live.get(rid)
            if rec is not None:
                rec["spans"][0]["attrs"].update(attrs)

    def finish(self, rid: int, **attrs) -> Optional[dict]:
        """Close the root span, export the trace as one JSONL line, and
        move it to the finished ring. Returns the record (None if the
        rid has no live trace).

        SLO attainment: when the closing attrs carry a ``deadline_ms``
        (the request's wall-clock completion target), the tracer stamps
        ``latency_ms`` and ``deadline_met`` from the root span's own
        extent — the one clock that saw both the accept and the finish."""
        with self._lock:
            rec = self._live.pop(rid, None)
            if rec is None:
                return None
            del self._by_trace[rec["trace_id"]]
            root = rec["spans"][0]
            root["t1"] = server_now()
            if attrs.get("deadline_ms") is not None:
                lat_ms = (root["t1"] - root["t0"]) * 1e3
                attrs["latency_ms"] = lat_ms
                attrs["deadline_met"] = bool(lat_ms <= attrs["deadline_ms"])
            root["attrs"].update(attrs)
            self.finished.append(rec)
            self._export(rec)
            return rec

    def _export(self, rec: dict):
        if not self._out_path:
            return
        if self._out is None:
            self._out = open(self._out_path, "a", encoding="utf-8")
        self._out.write(json.dumps(rec) + "\n")
        self._out.flush()
        self.exported += 1

    def live_rids(self) -> List[int]:
        with self._lock:
            return list(self._live)

    def close(self):
        with self._lock:
            if self._out is not None:
                self._out.close()
                self._out = None


# ================================================== Prometheus text format
_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label(v) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class MetricsRegistry:
    """A scrape's worth of metric families, rendered to Prometheus text
    exposition format. Rebuilt per scrape from the pump's immutable
    mirror — there is no background mutation, so rendering needs no
    locks. Histogram bucket counts reflect the telemetry's rolling
    windows (valid exposition format; scrape-to-scrape monotonicity is
    not promised, and DESIGN.md §12 says so)."""

    def __init__(self):
        self._families: Dict[str, dict] = {}
        self._order: List[str] = []

    def _family(self, name: str, kind: str, help_text: str) -> dict:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        fam = self._families.get(name)
        if fam is None:
            fam = {"type": kind, "help": help_text,
                   "samples": [], "hists": []}
            self._families[name] = fam
            self._order.append(name)
        elif fam["type"] != kind:
            raise ValueError(f"{name}: redeclared {fam['type']} as {kind}")
        return fam

    def _sample(self, name, kind, help_text, value, labels):
        labels = labels or {}
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"bad label name {k!r}")
        self._family(name, kind, help_text)["samples"].append(
            (dict(labels), float(value)))

    def counter(self, name, help_text, value, labels=None):
        self._sample(name, "counter", help_text, value, labels)

    def gauge(self, name, help_text, value, labels=None):
        self._sample(name, "gauge", help_text, value, labels)

    def histogram(self, name, help_text, observations, buckets,
                  labels=None):
        """One labelset's histogram from raw observations; ``buckets``
        are finite upper bounds (+Inf is appended by the renderer)."""
        bounds = sorted(float(b) for b in buckets)
        obs = [float(x) for x in observations]
        self._family(name, "histogram", help_text)["hists"].append(
            (dict(labels or {}), bounds, obs))

    def render(self) -> str:
        lines = []
        for name in self._order:
            fam = self._families[name]
            lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for labels, value in fam["samples"]:
                lines.append(
                    f"{name}{_fmt_labels(labels)} {_fmt_value(value)}")
            for labels, bounds, obs in fam["hists"]:
                acc = 0
                for b in bounds:
                    acc = sum(1 for x in obs if x <= b)
                    lb = dict(labels, le=_fmt_value(b))
                    lines.append(f"{name}_bucket{_fmt_labels(lb)} {acc}")
                lb = dict(labels, le="+Inf")
                lines.append(f"{name}_bucket{_fmt_labels(lb)} {len(obs)}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(sum(obs))}")
                lines.append(f"{name}_count{_fmt_labels(labels)} "
                             f"{len(obs)}")
        return "\n".join(lines) + "\n"


def _parse_label_block(s: str, lineno: int) -> dict:
    labels = {}
    i = 0
    while i < len(s):
        m = re.match(r"([a-zA-Z_][a-zA-Z0-9_]*)=\"", s[i:])
        if not m:
            raise ValueError(f"line {lineno}: bad label syntax at {s[i:]!r}")
        key = m.group(1)
        i += m.end()
        val, closed = [], False
        while i < len(s):
            ch = s[i]
            if ch == "\\":
                if i + 1 >= len(s):
                    raise ValueError(f"line {lineno}: dangling escape")
                nxt = s[i + 1]
                if nxt not in ('"', "\\", "n"):
                    raise ValueError(
                        f"line {lineno}: bad escape \\{nxt} in label value")
                val.append("\n" if nxt == "n" else nxt)
                i += 2
            elif ch == '"':
                i += 1
                closed = True
                break
            else:
                val.append(ch)
                i += 1
        if not closed:
            raise ValueError(f"line {lineno}: unterminated label value")
        labels[key] = "".join(val)
        if i < len(s):
            if s[i] != ",":
                raise ValueError(f"line {lineno}: expected ',' after label")
            i += 1
    return labels


def _split_sample(line: str, lineno: int):
    m = re.match(r"([a-zA-Z_:][a-zA-Z0-9_:]*)", line)
    if not m:
        raise ValueError(f"line {lineno}: bad sample name: {line!r}")
    name, rest = m.group(1), line[m.end():]
    labels = {}
    if rest.startswith("{"):
        i, in_q, esc = 1, False, False
        while i < len(rest):
            ch = rest[i]
            if esc:
                esc = False
            elif ch == "\\":
                esc = True
            elif ch == '"':
                in_q = not in_q
            elif ch == "}" and not in_q:
                break
            i += 1
        if i >= len(rest):
            raise ValueError(f"line {lineno}: unterminated label block")
        labels = _parse_label_block(rest[1:i], lineno)
        rest = rest[i + 1:]
    parts = rest.split()
    if len(parts) not in (1, 2):
        raise ValueError(f"line {lineno}: want 'name[labels] value "
                         f"[timestamp]', got {line!r}")
    try:
        value = float(parts[0])
    except ValueError:
        raise ValueError(f"line {lineno}: bad value {parts[0]!r}") from None
    if len(parts) == 2 and not re.match(r"-?\d+$", parts[1]):
        raise ValueError(f"line {lineno}: bad timestamp {parts[1]!r}")
    return name, labels, value


def parse_prometheus(text: str) -> dict:
    """Strict parser/validator for Prometheus text exposition format —
    the conformance gate CI scrapes ``GET /metrics`` through. Enforces:
    every sample belongs to a ``# TYPE``-declared family (declared
    before its samples, once), names/labels/values are well-formed, and
    each histogram labelset has sorted buckets with non-decreasing
    cumulative counts, a ``+Inf`` bucket, and ``_count`` == the +Inf
    bucket. Returns ``{family: {type, help, samples}}``; raises
    ValueError with the offending line on any violation."""
    families: Dict[str, dict] = {}
    seen_samples: set = set()

    def _owner(name: str, lineno: int) -> str:
        if name in families:
            return name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                if families.get(base, {}).get("type") == "histogram":
                    return base
        raise ValueError(f"line {lineno}: sample {name!r} has no "
                         f"# TYPE declaration")

    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            m = re.match(r"#\s+(HELP|TYPE)\s+([a-zA-Z_:][a-zA-Z0-9_:]*)"
                         r"(?:\s+(.*))?$", line)
            if not m:
                continue   # plain comment
            kind, name, arg = m.group(1), m.group(2), m.group(3) or ""
            fam = families.setdefault(
                name, {"type": None, "help": None, "samples": []})
            if kind == "HELP":
                if fam["help"] is not None:
                    raise ValueError(f"line {lineno}: duplicate HELP {name}")
                fam["help"] = arg
            else:
                if fam["type"] is not None:
                    raise ValueError(f"line {lineno}: duplicate TYPE {name}")
                if arg not in ("counter", "gauge", "histogram", "summary",
                               "untyped"):
                    raise ValueError(f"line {lineno}: bad type {arg!r}")
                if name in seen_samples:
                    raise ValueError(
                        f"line {lineno}: TYPE {name} after its samples")
                fam["type"] = arg
            continue
        name, labels, value = _split_sample(line, lineno)
        base = _owner(name, lineno)
        seen_samples.add(base)
        families[base]["samples"].append((name, labels, value))

    for name, fam in families.items():
        if fam["type"] is None:
            raise ValueError(f"family {name}: HELP without TYPE")
        if fam["type"] != "histogram":
            continue
        groups: Dict[tuple, dict] = {}
        for sname, labels, value in fam["samples"]:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            g = groups.setdefault(key, {"buckets": [], "sum": None,
                                        "count": None})
            if sname == f"{name}_bucket":
                if "le" not in labels:
                    raise ValueError(f"{name}: bucket without le label")
                g["buckets"].append((float(labels["le"]), value))
            elif sname == f"{name}_sum":
                g["sum"] = value
            elif sname == f"{name}_count":
                g["count"] = value
            else:
                raise ValueError(f"{name}: stray sample {sname}")
        for key, g in groups.items():
            bk = sorted(g["buckets"])
            if not bk or bk[-1][0] != float("inf"):
                raise ValueError(f"{name}{dict(key)}: no +Inf bucket")
            counts = [c for _, c in bk]
            if any(b > a for a, b in zip(counts[1:], counts)):
                raise ValueError(f"{name}{dict(key)}: bucket counts "
                                 f"not cumulative")
            if g["count"] is None or g["sum"] is None:
                raise ValueError(f"{name}{dict(key)}: missing _sum/_count")
            if g["count"] != counts[-1]:
                raise ValueError(f"{name}{dict(key)}: _count "
                                 f"{g['count']} != +Inf bucket {counts[-1]}")
    return families


# ======================================================== flight recorder
class FlightRecorder:
    """Bounded ring of structured control-plane events — WHY the plane
    did what it did (controller votes with their inputs, grow/shrink,
    migration phase timings, quarantines, respawns, routing verdicts).
    ``GET /debug/flightrec`` serves ``dump()``; crash-recovery events
    auto-dump to ``dump_path`` when one is configured, so a dead soak
    still leaves evidence on disk."""

    def __init__(self, capacity: int = 512,
                 dump_path: Optional[str] = None):
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._seq = itertools.count(1)
        self.dump_path = dump_path
        self.dumps = 0

    def record(self, kind: str, **fields) -> dict:
        evt = dict(seq=next(self._seq), t=time.monotonic(),
                   wall=time.time(), kind=kind, **fields)
        with self._lock:
            self._ring.append(evt)
        return evt

    def events(self, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            evts = list(self._ring)
        return evts if kind is None else [e for e in evts
                                          if e["kind"] == kind]

    def dump(self) -> dict:
        with self._lock:
            evts = list(self._ring)
        return {"capacity": self._ring.maxlen, "recorded": evts[-1]["seq"]
                if evts else 0, "events": evts}

    def auto_dump(self, reason: str) -> Optional[str]:
        """Persist the ring to ``dump_path`` (overwrite: latest crash
        wins). Failures are swallowed — the recorder must never take
        down the recovery it is documenting."""
        if not self.dump_path:
            return None
        try:
            payload = self.dump()
            payload["reason"] = reason
            with open(self.dump_path, "w", encoding="utf-8") as f:
                json.dump(payload, f)
            self.dumps += 1
            return self.dump_path
        except OSError:
            return None
