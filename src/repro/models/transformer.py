"""Composable transformer stacks for every assigned architecture family.

One entry point per phase, uniform across families:

* ``init_params(cfg, key, dtype)``
* ``init_cache(cfg, batch, max_len, dtype)``      (serving state)
* ``forward(params, cfg, tokens, positions, ...)`` with ``mode`` in
  {"train", "prefill", "decode"} -> (logits, new_cache, aux_loss)

Layer stacks run under ``jax.lax.scan`` over stacked parameters so the HLO
stays O(1) in depth — required for the 512-partition dry-run to compile on
one CPU core. CoCoServe's *dynamic* per-layer placement path instead unrolls
layers (``unroll=True``) so each layer can carry its own sharding constraint
(see core/replication.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.parallel.sharding import lshard

BIG_POS = jnp.int32(2 ** 30)


def _dtype(dtype):
    return jnp.dtype(dtype) if not isinstance(dtype, str) else jnp.dtype(dtype)


# ======================================================================= init
def _init_attn(cfg, key, dtype):
    if cfg.attention_kind == "mla":
        return L.init_mla(cfg, key, dtype)
    return L.init_gqa(cfg, key, dtype)


def _init_decoder_layer(cfg: ModelConfig, key, dtype):
    """One layer of a dense/moe/vlm decoder (attention + mlp/moe)."""
    k1, k2 = jax.random.split(key)
    p = {"norm1": L.init_norm(cfg, dtype), "attn": _init_attn(cfg, k1, dtype),
         "norm2": L.init_norm(cfg, dtype)}
    if cfg.num_experts > 0:
        p["moe"] = MOE.init_moe(cfg, k2, dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, k2, dtype)
    return p


def _init_mamba_layer(cfg: ModelConfig, key, dtype):
    return {"norm": L.init_norm(cfg, dtype),
            "mixer": SSM.init_mamba2(cfg, key, dtype)}


def _init_enc_layer(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": L.init_norm(cfg, dtype), "attn": L.init_gqa(cfg, k1, dtype),
            "norm2": L.init_norm(cfg, dtype), "mlp": L.init_mlp(cfg, k2, dtype)}


def _init_encdec_layer(cfg: ModelConfig, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": L.init_norm(cfg, dtype), "attn": L.init_gqa(cfg, k1, dtype),
            "norm_x": L.init_norm(cfg, dtype), "xattn": L.init_gqa(cfg, k2, dtype),
            "norm2": L.init_norm(cfg, dtype), "mlp": L.init_mlp(cfg, k3, dtype)}


def init_params(cfg: ModelConfig, key, dtype="bfloat16"):
    dtype = _dtype(dtype)
    keys = jax.random.split(key, 8)
    emb_scale = 1.0 / math.sqrt(cfg.d_model)
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * emb_scale).astype(dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                         cfg.padded_vocab, dtype)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["layers"] = L.stack_init(
            lambda k: _init_decoder_layer(cfg, k, dtype), keys[2], cfg.num_layers)
    elif fam == "ssm":
        params["layers"] = L.stack_init(
            lambda k: _init_mamba_layer(cfg, k, dtype), keys[2], cfg.num_layers)
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        nb, rem = divmod(cfg.num_layers, every)
        params["blocks"] = L.stack_init(
            lambda k: L.stack_init(
                lambda k2: _init_mamba_layer(cfg, k2, dtype), k, every),
            keys[2], nb)
        if rem:
            params["tail"] = L.stack_init(
                lambda k: _init_mamba_layer(cfg, k, dtype), keys[3], rem)
        params["shared"] = {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_gqa(cfg, keys[4], dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(cfg, keys[5],
                              dtype) if cfg.d_ff else None,
        }
        if params["shared"]["mlp"] is None:
            del params["shared"]["mlp"]
    elif fam == "audio":
        params["layers"] = L.stack_init(
            lambda k: _init_encdec_layer(cfg, k, dtype), keys[2], cfg.num_layers)
        params["encoder"] = {
            "layers": L.stack_init(lambda k: _init_enc_layer(cfg, k, dtype),
                                   keys[3], cfg.num_encoder_layers),
            "final_norm": L.init_norm(cfg, dtype),
        }
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ====================================================================== cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype="bfloat16"):
    """Serving state. ``max_len`` is the cache capacity per request; sliding
    -window archs may pass ``min(logical_len, cfg.sliding_window)`` to get a
    ring buffer. SSM/hybrid caches are O(1) in sequence length."""
    dtype = _dtype(dtype)
    fam = cfg.family
    cache = {"length": jnp.zeros((batch,), jnp.int32)}
    hd = cfg.resolved_head_dim

    def kv(n_ctx, n_layers, kvh, d):
        return {"k": jnp.zeros((n_layers, batch, n_ctx, kvh, d), dtype),
                "v": jnp.zeros((n_layers, batch, n_ctx, kvh, d), dtype)}

    def ssm_state(n_layers_shape):
        P, N = cfg.ssm_head_dim, cfg.ssm_state
        gN = cfg.ssm_ngroups * N
        K1 = cfg.ssm_conv_dim - 1
        return {"conv_x": jnp.zeros((*n_layers_shape, batch, K1,
                                     cfg.ssm_d_inner), dtype),
                "conv_B": jnp.zeros((*n_layers_shape, batch, K1, gN), dtype),
                "conv_C": jnp.zeros((*n_layers_shape, batch, K1, gN), dtype),
                "ssd": jnp.zeros((*n_layers_shape, batch, cfg.ssm_heads, P, N),
                                 dtype)}

    if fam in ("dense", "moe", "vlm", "audio"):
        cache["positions"] = jnp.full((batch, max_len), BIG_POS, jnp.int32)
        if cfg.attention_kind == "mla":
            r, ro = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            cache["layers"] = {
                "c": jnp.zeros((cfg.num_layers, batch, max_len, r), dtype),
                "kr": jnp.zeros((cfg.num_layers, batch, max_len, ro), dtype)}
        else:
            cache["layers"] = kv(max_len, cfg.num_layers, cfg.num_kv_heads, hd)
        if fam == "audio":
            cache["cross"] = kv(cfg.encoder_seq_len, cfg.num_layers,
                                cfg.num_kv_heads, hd)
    elif fam == "ssm":
        cache["layers"] = ssm_state((cfg.num_layers,))
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        nb, rem = divmod(cfg.num_layers, every)
        cache["positions"] = jnp.full((batch, max_len), BIG_POS, jnp.int32)
        cache["blocks"] = ssm_state((nb, every))
        if rem:
            cache["tail"] = ssm_state((rem,))
        cache["shared"] = kv(max_len, nb, cfg.num_kv_heads, hd)
    return cache


# ================================================================= embeddings
def embed_tokens(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, cfg: ModelConfig, x):
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab > cfg.vocab_size:  # mask padding rows
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, L.NEG_INF, logits)
    return lshard(logits, "batch", None, "vocab") if logits.ndim == 3 else logits


# ============================================================== layer bodies
def _residual(x, h):
    """Residual add with an optional materialization barrier.

    Without the barrier, a TP partial output h feeds two consumers (the
    bf16 residual and the fp32 norm of the next sublayer) and GSPMD emits
    DUPLICATE all-reduces — one bf16 + one fp32 (measured: 3x fp32 + 1x
    bf16 per layer on chameleon prefill). The barrier forces one bf16
    reduction point. Enabled via the "residual_barrier" rule
    (EXPERIMENTS §Perf pair B).
    """
    from repro.parallel.sharding import current_rules
    rules = current_rules()
    out = x + h
    if rules and rules.get("residual_barrier"):
        out = jax.lax.optimization_barrier(out)
    return out


def _attn_sublayer(lp, x, cfg, positions, lcache, slots, kpos, mode, window):
    h = L.apply_norm(lp["norm1"], x, cfg)
    if cfg.attention_kind == "mla":
        if mode == "decode":
            h, nc = L.apply_mla_decode(lp["attn"], h, cfg, positions=positions,
                                       cache=lcache, slots=slots,
                                       k_positions=kpos, window=window)
        else:
            # train (lcache None) and prefill (expanded attention over the
            # fresh sequence; latents written into the cache at `slots`)
            h, nc = L.apply_mla_prefill(lp["attn"], h, cfg, positions=positions,
                                        cache=lcache, slots=slots, window=window)
    else:
        if lcache is not None:  # prefill: attend fresh; decode: attend cache
            h, nc = L.apply_gqa(lp["attn"], h, cfg, positions=positions,
                                cache=lcache, slots=slots, k_positions=kpos,
                                window=window,
                                attend_fresh=(mode == "prefill"))
        else:  # train
            h, nc = L.apply_gqa(lp["attn"], h, cfg, positions=positions,
                                window=window)
    h = lshard(h, "batch", "seq", None)
    return _residual(x, h), nc


def _mlp_sublayer(lp, x, cfg, dispatch):
    h = L.apply_norm(lp["norm2"], x, cfg)
    if "moe" in lp:
        h, aux = MOE.apply_moe(lp["moe"], h, cfg, dispatch=dispatch)
    else:
        h = L.apply_mlp(lp["mlp"], h, cfg)
        aux = jnp.float32(0.0)
    h = lshard(h, "batch", "seq", None)
    return _residual(x, h), aux


# ================================================================== forwards
def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _layer_slice(tree, i):
    return jax.tree_util.tree_map(lambda p: p[i], tree)


def _cache_meta(cache, positions):
    """slots [B,S] and updated kpos [B,M] for attention caches.

    Ring buffers (prefill longer than the cache) keep only the LAST M
    tokens: earlier tokens get the out-of-bounds slot M, which every cache
    scatter drops (``mode="drop"``) — avoiding duplicate-index scatters
    whose write order is undefined.
    """
    B, S = positions.shape
    M = cache["positions"].shape[1]
    slots = positions % M
    if S > M:
        keep = jnp.arange(S, dtype=jnp.int32)[None, :] >= S - M
        slots = jnp.where(keep, slots, M)  # M == out-of-bounds -> dropped
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    kpos = cache["positions"].at[bidx, slots].set(positions, mode="drop")
    return slots, kpos


def _forward_decoder(params, cfg, tokens, positions, cache, mode, dispatch,
                     remat, window, unroll, layer_hook, encoder_out=None,
                     last_idx=None):
    """dense / moe / vlm decoder and the whisper decoder (with cross-attn)."""
    has_cache = cache is not None
    is_audio = cfg.family == "audio"
    x = embed_tokens(params, cfg, tokens)
    if not cfg.use_rope:
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = lshard(x, "batch", "seq", None)
    slots = kpos = None
    if has_cache:
        slots, kpos = _cache_meta(cache, positions)

    def body(carry, xs):
        x, aux = carry
        if is_audio:
            if mode == "train":
                lp = xs
                lc = None
                ckv = L.compute_cross_kv(lp["xattn"], encoder_out)
            elif mode == "prefill":
                lp, lc = xs
                ckv = L.compute_cross_kv(lp["xattn"], encoder_out)
            else:
                lp, lc, ckv = xs
                ckv = (ckv["k"], ckv["v"])
        else:
            lp, lc = xs if has_cache else (xs, None)
            ckv = None
        x, nc = _attn_sublayer(lp, x, cfg, positions, lc, slots, kpos, mode,
                               window)
        if is_audio:
            h = L.apply_norm(lp["norm_x"], x, cfg)
            h, _ = L.apply_gqa(lp["xattn"], h, cfg, positions=positions,
                               kv_override=ckv)
            x = x + h
        x, a = _mlp_sublayer(lp, x, cfg, dispatch)
        ys = nc
        if is_audio and mode == "prefill":
            ys = (nc, {"k": ckv[0], "v": ckv[1]})
        return (x, aux + a), ys

    if mode == "train" and remat:
        body = jax.checkpoint(body)

    new_cache = None
    if unroll:
        aux = jnp.float32(0.0)
        ncs = []
        for i in range(cfg.num_layers):
            lp = _layer_slice(params["layers"], i)
            lc = _layer_slice(cache["layers"], i) if has_cache else None
            if layer_hook is not None:
                x = layer_hook(i, x)
            if is_audio:
                if mode == "train":
                    xs = lp
                elif mode == "prefill":
                    xs = (lp, lc)
                else:
                    xs = (lp, lc, _layer_slice(cache["cross"], i))
            else:
                xs = (lp, lc) if has_cache else lp
            (x, aux), ys = body((x, aux), xs)
            ncs.append(ys)
        if has_cache:
            stacked = _stack_trees(ncs)
    else:
        if is_audio:
            if mode == "train":
                xs = params["layers"]
            elif mode == "prefill":
                xs = (params["layers"], cache["layers"])
            else:
                xs = (params["layers"], cache["layers"], cache["cross"])
        else:
            xs = (params["layers"], cache["layers"]) if has_cache \
                else params["layers"]
        (x, aux), stacked = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)

    if has_cache:
        if is_audio and mode == "prefill":
            layers_c, cross_c = stacked
            new_cache = dict(cache, layers=layers_c, cross=cross_c,
                             positions=kpos,
                             length=positions[:, -1] + 1)
        else:
            new_cache = dict(cache, layers=stacked, positions=kpos,
                             length=positions[:, -1] + 1)

    if mode == "train":
        return unembed(params, cfg, x), None, aux
    if last_idx is not None:
        # per-row last REAL token (power-of-two padded prefill buckets:
        # causality keeps positions <= last_idx untouched by the padding)
        x_last = x[jnp.arange(x.shape[0]), last_idx]
    else:
        x_last = x[:, -1]
    return unembed(params, cfg, x_last), new_cache, aux


def forward_paged(params, cfg: ModelConfig, tokens, cache, *, window=None,
                  attn_impl="gather", interpret=False, layer_hook=None):
    """Single-token decode step against a PAGED KV pool (the Engine's
    primary decode path; see serving/paged_kv.py for the pool layout).

    tokens: [B, 1] int32. ``cache`` is the paged handle — a pytree of
    device arrays so the whole step jits with zero host syncs:

    * ``k``/``v``: [L, n_blocks, KV, bs, hd] shared block pools
      (KV-head-major — the decode kernel's native tile layout)
    * ``block_tables``: [B, max_blocks] int32 (-1 = unallocated; may be
      sliced to any prefix that covers every active request)
    * ``lengths``: [B] int32 tokens already in the pool per slot
    * ``active``: [B] bool (inactive slots decode garbage that is masked
      out of every pool write — the shape-stable static-batch trick)

    Positions are derived on device (new token sits at ``lengths[b]``).
    ``layer_hook(i, x) -> x`` (core/replication.layer_hook_from_degrees)
    unrolls the stack so each layer can carry its own batch-sharding
    constraint — CoCoServe's per-layer replication degrees applied to the
    LIVE paged decode step; ``None`` keeps the O(1)-depth lax.scan.
    Returns (logits [B, Vpad], new_cache, aux_loss).
    """
    if not cfg.supports_paged_kv:
        raise ValueError(f"paged decode needs a GQA attention decoder "
                         f"(family={cfg.family}, attn={cfg.attention_kind})")
    lengths = cache["lengths"].astype(jnp.int32)
    active = cache["active"]
    positions = lengths[:, None]                       # [B, 1]
    x = embed_tokens(params, cfg, tokens)
    if not cfg.use_rope:
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = lshard(x, "batch", "seq", None)

    def body(carry, xs):
        x, aux = carry
        lp, kl, vl = xs
        h = L.apply_norm(lp["norm1"], x, cfg)
        h, kl, vl = L.apply_gqa_paged(
            lp["attn"], h, cfg, positions=positions, pool_k=kl, pool_v=vl,
            block_tables=cache["block_tables"], active=active,
            window=window, impl=attn_impl, interpret=interpret)
        x = _residual(x, h)
        x, a = _mlp_sublayer(lp, x, cfg, "auto")
        return (x, aux + a), (kl, vl)

    if layer_hook is None:
        (x, aux), (nk, nv) = jax.lax.scan(
            body, (x, jnp.float32(0.0)),
            (params["layers"], cache["k"], cache["v"]))
    else:
        aux = jnp.float32(0.0)
        nks, nvs = [], []
        for i in range(cfg.num_layers):
            x = layer_hook(i, x)
            (x, aux), (kl, vl) = body(
                (x, aux), (_layer_slice(params["layers"], i),
                           cache["k"][i], cache["v"][i]))
            nks.append(kl)
            nvs.append(vl)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    new_cache = dict(cache, k=nk, v=nv,
                     lengths=lengths + active.astype(jnp.int32))
    return unembed(params, cfg, x[:, -1]), new_cache, aux


def encode_audio(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over precomputed (stubbed) frame embeddings."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = frames + L.sinusoidal_positions(pos, cfg.d_model).astype(frames.dtype)
    x = lshard(x, "batch", None, None)

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], x, cfg)
        h, _ = L.apply_gqa(lp["attn"], h, cfg, positions=pos, causal=False)
        x = x + h
        h = L.apply_norm(lp["norm2"], x, cfg)
        x = x + L.apply_mlp(lp["mlp"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg)


def _forward_ssm(params, cfg, tokens, positions, cache, mode, remat):
    x = embed_tokens(params, cfg, tokens)
    x = lshard(x, "batch", None, None)
    has_cache = cache is not None

    def body(x, xs):
        lp, lc = xs if has_cache else (xs, None)
        h = L.apply_norm(lp["norm"], x, cfg)
        if mode == "decode" and x.shape[1] == 1:
            h, ns = SSM.apply_mamba2_decode(lp["mixer"], h, cfg, state=lc)
        else:  # train / prefill / multi-token extension (chunked prefill)
            h, ns = SSM.apply_mamba2(lp["mixer"], h, cfg, state=lc)
        h = lshard(h, "batch", None, None)
        return x + h, ns

    if mode == "train" and remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], cache["layers"]) if has_cache else params["layers"]
    x, new_states = jax.lax.scan(body, x, xs)

    new_cache = None
    if has_cache:
        new_cache = dict(cache, layers=new_states,
                         length=positions[:, -1] + 1)
    if mode == "train":
        return unembed(params, cfg, x), None, jnp.float32(0.0)
    return unembed(params, cfg, x[:, -1]), new_cache, jnp.float32(0.0)


def _forward_hybrid(params, cfg, tokens, positions, cache, mode, remat,
                    window):
    every = cfg.hybrid_attn_every
    nb, rem = divmod(cfg.num_layers, every)
    has_cache = cache is not None
    x = embed_tokens(params, cfg, tokens)
    x = lshard(x, "batch", None, None)
    slots = kpos = None
    if has_cache:
        slots, kpos = _cache_meta(cache, positions)
    shared = params["shared"]

    def mamba_body(x, xs):
        lp, lc = xs if has_cache else (xs, None)
        h = L.apply_norm(lp["norm"], x, cfg)
        if mode == "decode" and x.shape[1] == 1:
            h, ns = SSM.apply_mamba2_decode(lp["mixer"], h, cfg, state=lc)
        else:
            h, ns = SSM.apply_mamba2(lp["mixer"], h, cfg, state=lc)
        return x + h, ns

    def block_body(x, xs):
        if has_cache:
            bp, bc, skv = xs
            inner_xs = (bp, bc)
        else:
            bp = xs
            inner_xs = bp
            skv = None
        x, new_states = jax.lax.scan(mamba_body, x, inner_xs)
        # shared attention (+ MLP) block — same params every application
        h = L.apply_norm(shared["norm1"], x, cfg)
        if has_cache:
            h, nkv = L.apply_gqa(shared["attn"], h, cfg, positions=positions,
                                 cache=skv, slots=slots, k_positions=kpos,
                                 window=window,
                                 attend_fresh=(mode == "prefill"))
        else:
            h, nkv = L.apply_gqa(shared["attn"], h, cfg, positions=positions,
                                 window=window)
        x = x + h
        if "mlp" in shared:
            h = L.apply_norm(shared["norm2"], x, cfg)
            x = x + L.apply_mlp(shared["mlp"], h, cfg)
        x = lshard(x, "batch", None, None)
        return x, (new_states, nkv) if has_cache else (new_states, None)

    if mode == "train" and remat:
        block_body = jax.checkpoint(block_body)

    if has_cache:
        xs = (params["blocks"], cache["blocks"], cache["shared"])
    else:
        xs = params["blocks"]
    x, ys = jax.lax.scan(block_body, x, xs)
    new_blocks, new_shared = ys if has_cache else (None, None)

    new_tail = None
    if rem:
        tail_xs = (params["tail"], cache["tail"]) if has_cache \
            else params["tail"]
        x, new_tail = jax.lax.scan(mamba_body, x, tail_xs)

    new_cache = None
    if has_cache:
        new_cache = dict(cache, blocks=new_blocks, shared=new_shared,
                         positions=kpos, length=positions[:, -1] + 1)
        if rem:
            new_cache["tail"] = new_tail
    if mode == "train":
        return unembed(params, cfg, x), None, jnp.float32(0.0)
    return unembed(params, cfg, x[:, -1]), new_cache, jnp.float32(0.0)


def forward(params, cfg: ModelConfig, tokens, positions=None, cache=None, *,
            mode="train", encoder_input=None, dispatch="auto", remat=False,
            window=None, unroll=False, layer_hook=None, last_idx=None):
    """Uniform entry point. tokens [B,S] int32; positions [B,S] absolute
    (default arange). Returns (logits, new_cache, aux_loss):
    train -> full-seq logits [B,S,Vpad]; prefill/decode -> last-token [B,Vpad].
    ``last_idx`` [B] (attention decoders, non-train) selects each row's
    last REAL token instead of column -1 — the per-row gather behind the
    engine's power-of-two padded prefill buckets.
    """
    if cache is not None and "block_tables" in cache:
        assert mode == "decode", "paged cache handles are decode-only"
        return forward_paged(params, cfg, tokens, cache, window=window,
                             layer_hook=layer_hook)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _forward_decoder(params, cfg, tokens, positions, cache, mode,
                                dispatch, remat, window, unroll, layer_hook,
                                last_idx=last_idx)
    if fam == "audio":
        enc_out = None
        if mode in ("train", "prefill"):
            assert encoder_input is not None, "audio needs encoder frames"
            enc_out = encode_audio(params, cfg, encoder_input)
        return _forward_decoder(params, cfg, tokens, positions, cache, mode,
                                dispatch, remat, window, unroll, layer_hook,
                                encoder_out=enc_out, last_idx=last_idx)
    assert last_idx is None, f"last_idx unsupported for family {fam}"
    if fam == "ssm":
        return _forward_ssm(params, cfg, tokens, positions, cache, mode, remat)
    if fam == "hybrid":
        return _forward_hybrid(params, cfg, tokens, positions, cache, mode,
                               remat, window)
    raise ValueError(f"unknown family {fam}")
