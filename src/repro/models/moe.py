"""Mixture-of-Experts layer: top-k routing with two dispatch paths.

* ``dense``  — every expert computes every token (used for tiny smoke configs,
  E <= 8; exact but E-times the FLOPs).
* ``scatter`` — Switch-Transformer-style capacity dispatch: tokens are
  scattered into a per-expert [E, C, d] buffer (position = rank within the
  expert via cumsum), experts run as one grouped einsum, results gathered
  back weighted by router probabilities. FLOPs ~= T·k·cf·(3·d·d_ff) — the
  honest active-parameter cost, which is what the roofline needs.

Expert weights live on the ``model`` mesh axis (expert parallelism); padding
experts (qwen2-moe: 60 -> 64) get their router logits masked to -inf so no
token ever routes to them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

EXPERT_AXIS_PAD = 16  # pad expert count to a multiple of the model axis


def init_moe(cfg: ModelConfig, key, dtype):
    E = cfg.padded_experts(EXPERT_AXIS_PAD)
    ks = jax.random.split(key, 8)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, f, d, dtype))(
            jax.random.split(ks[3], E)),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, fs, dtype),
            "w_up": dense_init(ks[5], d, fs, dtype),
            "w_down": dense_init(ks[6], fs, d, dtype),
        }
    if cfg.moe_dense_residual:
        fr = cfg.dense_residual_d_ff
        kr = jax.random.split(ks[7], 3)
        p["residual"] = {
            "w_gate": dense_init(kr[0], d, fr, dtype),
            "w_up": dense_init(kr[1], d, fr, dtype),
            "w_down": dense_init(kr[2], fr, d, dtype),
        }
    return p


def _swiglu(x, w):
    h = jax.nn.silu(x @ w["w_gate"]) * (x @ w["w_up"])
    return h @ w["w_down"]


def route(p, x, cfg: ModelConfig):
    """Router: returns (weights [.., k], idx [.., k], aux_loss scalar)."""
    E = cfg.padded_experts(EXPERT_AXIS_PAD)
    logits = (x.astype(jnp.float32) @ p["router"])
    if E > cfg.num_experts:  # mask padding experts
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss (fraction * mean-prob).
    k = cfg.num_experts_per_tok
    counts = jnp.zeros(logits.shape[:-1] + (E,), jnp.float32)
    for j in range(k):
        counts = counts + jax.nn.one_hot(idx[..., j], E)
    frac = counts.reshape(-1, E).mean(0)
    mean_prob = probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(frac * mean_prob) / k
    return weights.astype(x.dtype), idx, aux


def _moe_dense(p, x, weights, idx, cfg):
    """All-experts einsum; exact, only for tiny E."""
    E = cfg.padded_experts(EXPERT_AXIS_PAD)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    out_e = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    k = cfg.num_experts_per_tok
    sel = jnp.zeros(x.shape[:-1] + (E,), x.dtype)
    for j in range(k):
        sel = sel + jax.nn.one_hot(idx[..., j], E, dtype=x.dtype) * weights[..., j:j + 1]
    return jnp.einsum("bsed,bse->bsd", out_e, sel)


def _moe_scatter(p, x, weights, idx, cfg, capacity_factor=1.25):
    """Capacity-based dispatch (Switch impl): scatter -> grouped mm -> gather."""
    B, S, d = x.shape
    E = cfg.padded_experts(EXPERT_AXIS_PAD)
    k = cfg.num_experts_per_tok
    T = B * S
    cap = int(max(1, round(T * k * capacity_factor / E)))
    cap = -(-cap // 8) * 8  # align
    xf = x.reshape(T, d)
    idx_f = idx.reshape(T, k)
    w_f = weights.reshape(T, k)

    # rank of each (token, slot) within its expert, slot-major
    buf = jnp.zeros((E, cap, d), x.dtype)
    out = jnp.zeros((T, d), jnp.float32)
    positions, keeps = [], []
    counts = jnp.zeros((E,), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(idx_f[:, j], E, dtype=jnp.int32)        # [T,E]
        pos_in = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]       # [T,E]
        counts = counts + oh.sum(0)
        pos = jnp.take_along_axis(pos_in, idx_f[:, j:j + 1], 1)[:, 0]
        keep = pos < cap
        positions.append(jnp.where(keep, pos, cap - 1))
        keeps.append(keep)
        buf = buf.at[idx_f[:, j], positions[j]].add(
            jnp.where(keep[:, None], xf, 0).astype(x.dtype))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])                  # [E,C,d]

    for j in range(k):
        gathered = y[idx_f[:, j], positions[j]].astype(jnp.float32)
        out = out + jnp.where(keeps[j][:, None], gathered, 0) * w_f[:, j:j + 1]
    return out.reshape(B, S, d).astype(x.dtype)


def apply_moe(p, x, cfg: ModelConfig, *, dispatch: str = "auto"):
    """Full MoE block: routed experts (+ shared experts, + dense residual).

    Returns (out, aux_loss).
    """
    from repro.parallel.sharding import current_rules
    rules = current_rules()
    weights, idx, aux = route(p, x, cfg)
    if dispatch == "auto":
        if (rules and rules.get("moe_a2a") and rules.get("experts")
                and cfg.num_experts > 8):
            dispatch = "expert_parallel"
        else:
            dispatch = "dense" if cfg.num_experts <= 8 else "scatter"
    if dispatch == "dense":
        out = _moe_dense(p, x, weights, idx, cfg)
    elif dispatch == "expert_parallel":
        out = _moe_expert_parallel(p, x, weights, idx, cfg, rules)
    else:
        out = _moe_scatter(p, x, weights, idx, cfg)
    if "shared" in p:
        out = out + _swiglu(x, p["shared"])
    if "residual" in p:
        out = out + _swiglu(x, p["residual"])
    return out, aux


# ===================================================================== a2a EP
def _moe_expert_parallel(p, x, weights, idx, cfg: ModelConfig, rules,
                         capacity_factor: float = 1.3):
    """Expert-parallel MoE via explicit all-to-all (beyond-paper
    optimization, EXPERIMENTS §Perf pair A).

    GSPMD's lowering of the scatter-based dispatch moves the full [E, C, d]
    buffer through collective-permutes every layer (~150 GB/device/layer on
    arctic train_4k). This shard_map implementation sends each token
    directly to the data-shard that owns its expert and back:
    2 x tokens·k·cf·d bytes per device per layer (fwd).

    Requires: experts sharded over `exp_axis` (= rules["experts"]), tokens
    batch-sharded over the same axis, d_ff sharded over rules["ffn"].
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules["mesh"]
    exp_axis = rules.get("experts")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_dest = sizes[exp_axis]
    E = cfg.padded_experts(EXPERT_AXIS_PAD)
    E_loc = E // n_dest
    k = cfg.num_experts_per_tok
    B, S, d = x.shape
    batch_spec = rules.get("batch")
    # per-shard token count (batch sharded over batch axes)
    n_batch = 1
    for a in (batch_spec if isinstance(batch_spec, tuple) else (batch_spec,)):
        if a:
            n_batch *= sizes.get(a, 1)
    T_loc = (B // n_batch) * S
    cap = int(max(8, round(T_loc * k * capacity_factor / n_dest)))
    cap = -(-cap // 8) * 8
    cap_e = int(max(8, round(n_dest * cap * 1.3 / E_loc)))
    cap_e = -(-cap_e // 8) * 8

    def block(xb, wb, idxb, wg, wu, wd):
        Bl, Sl, _ = xb.shape
        Tl = Bl * Sl
        xf = xb.reshape(Tl, d)
        w_f = wb.reshape(Tl, k).astype(jnp.float32)
        idx_f = idxb.reshape(Tl, k)
        dest = idx_f // E_loc                     # [Tl,k] destination shard
        e_loc = idx_f % E_loc                     # local expert id at dest
        # slot within each destination bucket (slot-major cumsum)
        send_x = jnp.zeros((n_dest, cap, d), xb.dtype)
        send_e = jnp.full((n_dest, cap), 0, jnp.int32)
        send_g = jnp.zeros((n_dest, cap), jnp.float32)
        send_src = jnp.full((n_dest, cap), 0, jnp.int32)
        counts = jnp.zeros((n_dest,), jnp.int32)
        tpos = jnp.arange(Tl, dtype=jnp.int32)
        keeps, poss, dests = [], [], []
        for j in range(k):
            oh = jax.nn.one_hot(dest[:, j], n_dest, dtype=jnp.int32)
            pos = (jnp.cumsum(oh, 0) - 1 + counts[None, :])
            counts = counts + oh.sum(0)
            pj = jnp.take_along_axis(pos, dest[:, j:j + 1], 1)[:, 0]
            keep = pj < cap
            pj = jnp.where(keep, pj, cap)        # cap == OOB -> dropped
            send_x = send_x.at[dest[:, j], pj].set(
                jnp.where(keep[:, None], xf, 0), mode="drop")
            send_e = send_e.at[dest[:, j], pj].set(e_loc[:, j], mode="drop")
            send_g = send_g.at[dest[:, j], pj].set(
                jnp.where(keep, w_f[:, j], 0.0), mode="drop")
            send_src = send_src.at[dest[:, j], pj].set(tpos, mode="drop")
            keeps.append(keep)
            poss.append(pj)
            dests.append(dest[:, j])
        # ---- exchange tokens with expert owners
        a2a = lambda t: jax.lax.all_to_all(t, exp_axis, 0, 0, tiled=False)  # noqa: E731
        rx = a2a(send_x)                          # [n_src, cap, d]
        re = a2a(send_e)
        rg = a2a(send_g)
        # ---- local expert compute (scatter into per-expert buckets)
        Tr = n_dest * cap
        rxf = rx.reshape(Tr, d)
        ref_ = re.reshape(Tr)
        rgf = rg.reshape(Tr)
        valid = (rgf > 0).astype(jnp.int32)       # unfilled slots are junk
        oh = jax.nn.one_hot(ref_, E_loc, dtype=jnp.int32) * valid[:, None]
        pos_in = jnp.cumsum(oh, 0) - 1
        pe = jnp.take_along_axis(pos_in, ref_[:, None], 1)[:, 0]
        keep_e = (pe < cap_e) & (rgf > 0)
        pe = jnp.where(keep_e, pe, cap_e)
        buf = jnp.zeros((E_loc, cap_e, d), xb.dtype)
        buf = buf.at[ref_, pe].set(jnp.where(keep_e[:, None], rxf, 0),
                                   mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        yb = jnp.einsum("ecf,efd->ecd", h, wd)    # partial over ffn shard
        # gather per-token outputs, then reduce the (smaller) token tensor
        y_tok = yb[ref_, jnp.minimum(pe, cap_e - 1)]
        y_tok = jnp.where(keep_e[:, None], y_tok, 0)
        if rules.get("ffn"):
            y_tok = jax.lax.psum(y_tok, rules["ffn"])
        y_tok = y_tok.astype(xb.dtype)
        ry = a2a(y_tok.reshape(n_dest, cap, d))   # back at the source shard
        # ---- combine at source
        out = jnp.zeros((Tl, d), jnp.float32)
        for j in range(k):
            got = ry[dests[j], jnp.minimum(poss[j], cap - 1)]
            got = jnp.where(keeps[j][:, None], got, 0)
            out = out + got.astype(jnp.float32) * w_f[:, j:j + 1]
        return out.reshape(Bl, Sl, d).astype(xb.dtype)

    bspec = batch_spec
    return shard_map(
        block, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None),
                  P(exp_axis, None, rules.get("ffn")),
                  P(exp_axis, None, rules.get("ffn")),
                  P(exp_axis, rules.get("ffn"), None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(x, weights, idx, p["w_gate"], p["w_up"], p["w_down"])
