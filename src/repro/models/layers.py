"""Model primitives: norms, rotary embeddings, attention (GQA / MLA), MLPs.

Everything is pure-functional: parameters are nested dicts of ``jnp`` arrays,
initialised by ``init_*`` helpers and consumed by ``apply``-style functions.
Attention uses a chunked (flash-style) jnp path so that 32k-sequence prefill
lowers with a bounded live-score footprint — XLA does not flash-ify a naive
``softmax(QK^T)V`` on its own.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -2.0 ** 20  # large-negative that is safe in bf16 softmax


# --------------------------------------------------------------------------- init
def dense_init(key, in_dim: int, out_shape, dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init; out_shape may be a tuple (heads, dim)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    scale = 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, *out_shape), jnp.float32)
    return (w * scale).astype(dtype)


def stack_init(init_fn, key, n: int):
    """Initialise ``n`` stacked copies of a layer's params (leading dim n)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# --------------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_dim(x, eps=1e-6):
    """Scale-free RMS norm over the last dim (for qk-norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D] (D even); positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., :, None, :]                          # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, dim: int):
    """Classic transformer sinusoids; positions [..., S] -> [..., S, dim]."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------- attention core
def _attn_mask(qpos, kpos, kv_len, window, causal):
    """Build [B,Qc,Sk] mask from per-batch positions.

    qpos: [B,Qc]; kpos: [B,Sk]; kv_len: [B] or None.
    """
    if causal:
        mask = kpos[:, None, :] <= qpos[:, :, None]
        if window is not None:
            mask &= kpos[:, None, :] > qpos[:, :, None] - window
    else:
        mask = jnp.ones((qpos.shape[0], qpos.shape[1], kpos.shape[1]), bool)
    if kv_len is not None:
        mask &= kpos[:, None, :] < kv_len[:, None, None]
    return mask


def _attend_block(q, k, v, qpos, kpos, kv_len, window, causal, softcap=0.0):
    """One (q-chunk x full-K) attention step with GQA grouping.

    q:[B,Qc,KV,R,D] k,v:[B,Sk,KV,D] -> out [B,Qc,KV,R,D]; fp32 softmax.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkrd,bskd->bkrqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = _attn_mask(qpos, kpos, kv_len, window, causal)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v.astype(jnp.float32))
    return out


def attention(q, k, v, *, q_positions=None, kv_len=None, window=None,
              causal=True, chunk=512, softcap=0.0, k_positions=None):
    """Chunked multi-head attention with GQA head grouping.

    q: [B,Sq,H,D]; k,v: [B,Sk,KV,D]. ``q_positions`` [B,Sq] are absolute
    positions of the queries (decode passes per-request cache offsets);
    default arange. ``kv_len`` [B] masks partially-filled caches.
    ``k_positions`` ([Sk] or [B,Sk]) overrides K positions (ring buffers).
    Returns [B,Sq,H,D] in q.dtype.
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # MLA: v head dim differs from qk head dim
    R = H // KV
    qg = q.reshape(B, Sq, KV, R, D)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    if k_positions is None:
        kpos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    else:
        kpos = jnp.broadcast_to(k_positions, (B, Sk)).astype(jnp.int32)

    if Sq <= chunk:
        out = _attend_block(qg, k, v, q_positions, kpos, kv_len, window,
                            causal, softcap)
        return out.reshape(B, Sq, H, Dv).astype(q.dtype)

    # pad Sq to a multiple of the chunk and scan over chunks
    nc = -(-Sq // chunk)
    pad = nc * chunk - Sq
    qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qp = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=-1)
    qg = qg.reshape(B, nc, chunk, KV, R, D).transpose(1, 0, 2, 3, 4, 5)
    qp = qp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(_, qs):
        qc, qpc = qs
        return None, _attend_block(qc, k, v, qpc, kpos, kv_len, window,
                                   causal, softcap)

    _, out = jax.lax.scan(step, None, (qg, qp))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nc * chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype)


# ------------------------------------------------------------------ GQA attention
def init_gqa(cfg: ModelConfig, key, dtype):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, (cfg.num_heads, hd), dtype),
        "wk": dense_init(k2, cfg.d_model, (cfg.num_kv_heads, hd), dtype),
        "wv": dense_init(k3, cfg.d_model, (cfg.num_kv_heads, hd), dtype),
        "wo": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def apply_gqa(p, x, cfg: ModelConfig, *, positions, cache=None, slots=None,
              k_positions=None, window=None, kv_override=None, causal=True,
              attend_fresh=False):
    """GQA/MQA attention with optional KV-cache write-then-attend.

    ``attend_fresh`` (prefill): attention runs over this call's own K/V —
    required for ring buffers, where the cache only retains the last W
    tokens but mid-sequence queries still need their full window — while
    the cache write at ``slots`` happens on the side.

    x: [B,S,d]; positions [B,S] absolute. ``cache`` is None (train) or a
    per-layer dict {"k": [B,M,KV,hd], "v": ...}; ``slots`` [B,S] are the
    cache rows to write (ring buffers pass ``positions % M``) and
    ``k_positions`` [B,M] the (already-updated) absolute position of each
    cache row (unwritten rows hold 2**30 so they mask out).
    ``kv_override``: (k, v) for cross-attention (ignores the cache path).
    Returns (out [B,S,d], new_cache_or_None).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is not None:
        k, v = kv_override
        out = attention(q, k, v, causal=False, chunk=512)
        out = out.reshape(B, S, cfg.num_heads * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"]), None
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        q, k = rms_norm_dim(q), rms_norm_dim(k)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = attention(q, k, v, q_positions=positions, window=window,
                        causal=causal, softcap=cfg.logit_softcap)
        new_cache = None
    else:
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        ck = cache["k"].at[bidx, slots].set(k, mode="drop")
        cv = cache["v"].at[bidx, slots].set(v, mode="drop")
        new_cache = {"k": ck, "v": cv}
        if attend_fresh:  # prefill: full fresh sequence, windowed mask
            out = attention(q, k, v, q_positions=positions, window=window,
                            causal=causal, softcap=cfg.logit_softcap)
        else:  # decode: attend the updated cache
            from repro.parallel.sharding import current_rules
            rules = current_rules()
            if (rules and rules.get("flash_decode") and S == 1
                    and rules.get("cache_seq")):
                # distributed flash-decoding over the seq-sharded cache
                # (beyond-paper optimization, EXPERIMENTS §Perf pair C)
                from repro.parallel.distributed_attention import flash_decode
                out = flash_decode(
                    q, ck, cv, positions,
                    jnp.broadcast_to(k_positions, (B, ck.shape[1])),
                    mesh=rules["mesh"], seq_axis=rules["cache_seq"],
                    batch_axis=rules.get("batch"), window=window,
                    softcap=cfg.logit_softcap)
            else:
                out = attention(q, ck, cv, q_positions=positions,
                                k_positions=k_positions, window=window,
                                causal=causal, softcap=cfg.logit_softcap)
    out = out.reshape(B, S, cfg.num_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_cache


def apply_gqa_paged(p, x, cfg: ModelConfig, *, positions, pool_k, pool_v,
                    block_tables, active, window=None,
                    impl="gather", interpret=False):
    """GQA decode attention against a paged block pool (write-then-attend).

    Single-token decode only: x [B,1,d]. ``pool_k/v`` are ONE layer's pool
    slices [n_blocks, KV, bs, hd] (KV-head-major — the Pallas kernel's
    native tile layout); ``block_tables`` [B, max_blocks] int32
    (-1 = unallocated, masked); ``positions`` [B,1] are the pre-write
    token counts (the new token lands at position ``positions[b,0]``);
    ``active`` [B] bool — inactive rows write nothing (their scatter index
    is pushed out of bounds and dropped) and attend over an empty,
    fully-masked context, producing garbage logits the engine ignores.

    ``impl``: "gather" materializes the table's blocks with a batched
    gather and reuses the chunked fp32 attention (jit-friendly anywhere);
    "kernel" calls the Pallas paged-decode kernel (kernels/paged_decode.py)
    whose HBM traffic stops at each request's true length. The kernel
    implements plain causal GQA only, so logit-softcap archs and sliding
    windows silently route back to the gather path — identical numerics,
    no divergence between impls.

    Returns (out [B,1,d], new_pool_k, new_pool_v).
    """
    B, S, _ = x.shape
    assert S == 1, "paged path is decode-only (one token per step)"
    hd = cfg.resolved_head_dim
    n_blocks, KV, bs, _ = pool_k.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        q, k = rms_norm_dim(q), rms_norm_dim(k)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # ---- write the new token's K/V into its block (inactive rows drop)
    pos = positions[:, 0]
    tbl_col = jnp.clip(pos // bs, 0, block_tables.shape[1] - 1)
    blk = jnp.take_along_axis(block_tables, tbl_col[:, None], axis=1)[:, 0]
    blk = jnp.where(active & (blk >= 0), blk, n_blocks)  # OOB -> dropped
    off = pos % bs
    new_k = pool_k.at[blk, :, off].set(k[:, 0].astype(pool_k.dtype),
                                       mode="drop")
    new_v = pool_v.at[blk, :, off].set(v[:, 0].astype(pool_v.dtype),
                                       mode="drop")

    kv_len = jnp.where(active, pos + 1, 0).astype(jnp.int32)
    if impl == "kernel" and (cfg.logit_softcap or window is not None):
        impl = "gather"  # kernel has no softcap/window support
    if impl == "kernel":
        from repro.kernels.paged_decode import paged_decode_attention
        out = paged_decode_attention(q[:, 0], new_k, new_v, block_tables,
                                     kv_len, interpret=interpret)[:, None]
    else:
        max_blocks = block_tables.shape[1]
        tbl = jnp.maximum(block_tables, 0).astype(jnp.int32)
        # gathered blocks are [B, mb, KV, bs, hd]; only the gathered
        # context is re-laid token-major, never the whole pool
        kk = new_k[tbl].transpose(0, 1, 3, 2, 4).reshape(
            B, max_blocks * bs, KV, hd)
        vv = new_v[tbl].transpose(0, 1, 3, 2, 4).reshape(
            B, max_blocks * bs, KV, hd)
        out = attention(q, kk, vv, q_positions=positions, kv_len=kv_len,
                        k_positions=jnp.arange(max_blocks * bs,
                                               dtype=jnp.int32),
                        window=window, causal=True,
                        softcap=cfg.logit_softcap)
    out = out.reshape(B, S, cfg.num_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_k, new_v


def compute_cross_kv(p, enc_out):
    """Cross-attention K/V from encoder output (whisper decoder prefill)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ------------------------------------------------------------------ MLA attention
def init_mla(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 6)
    H = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, (H, qk_dim), dtype)
    else:
        p["wq"] = dense_init(ks[1], cfg.d_model, (H, qk_dim), dtype)
    p["wkv_a"] = dense_init(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype)
    p["wk_b"] = dense_init(ks[3], cfg.kv_lora_rank, (H, cfg.qk_nope_head_dim), dtype)
    p["wv_b"] = dense_init(ks[4], cfg.kv_lora_rank, (H, cfg.v_head_dim), dtype)
    p["wo"] = dense_init(ks[5], H * cfg.v_head_dim, cfg.d_model, dtype)
    return p


def _mla_q(p, x, cfg, positions):
    if cfg.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
        q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p, x, cfg, positions):
    ckr = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = ckr[..., : cfg.kv_lora_rank]
    k_rope = apply_rope(ckr[..., None, cfg.kv_lora_rank:],
                        positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def apply_mla_prefill(p, x, cfg: ModelConfig, *, positions, cache=None,
                      slots=None, window=None):
    """MLA in the expanded (compute-friendly) form for train/prefill.

    cache: None or per-layer {"c": [B,M,r], "kr": [B,M,rope]}; latents of
    this call are written at ``slots``. Returns (out, new_cache_or_None).
    """
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    H = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, cfg.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    out = attention(q, k, v, q_positions=positions, window=window)
    out = out.reshape(B, S, H * cfg.v_head_dim)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    new_cache = None
    if cache is not None:
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        new_cache = {"c": cache["c"].at[bidx, slots].set(c_kv, mode="drop"),
                     "kr": cache["kr"].at[bidx, slots].set(k_rope, mode="drop")}
    return out, new_cache


def apply_mla_decode(p, x, cfg: ModelConfig, *, positions, cache, slots,
                     k_positions, window=None):
    """MLA decode in the ABSORBED form (DeepSeek-V2): attention runs in the
    latent space so the per-step cost is MQA-like over (r + rope) dims.

    cache: per-layer {"c": [B,M,r], "kr": [B,M,rope]} — new latents are
    written at ``slots`` BEFORE attending; ``k_positions`` [B,M] are the
    already-updated absolute row positions. Returns (out, new_cache).
    """
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_new, kr_new = _mla_latents(p, x, cfg, positions)
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    cc = cache["c"].at[bidx, slots].set(c_new, mode="drop")
    ckr = cache["kr"].at[bidx, slots].set(kr_new, mode="drop")
    M = cc.shape[1]
    # absorb W_uk into q: q_lat [B,S,H,r]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    kpos = jnp.broadcast_to(k_positions, (B, M)).astype(jnp.int32)
    from repro.parallel.sharding import current_rules
    rules = current_rules()
    if rules and rules.get("flash_decode") and S == 1 \
            and rules.get("cache_seq"):
        # distributed flash-decoding in latent space (§Perf pair C family)
        from repro.parallel.distributed_attention import flash_decode_mla
        o_lat = flash_decode_mla(
            q_lat, q_rope, cc, ckr, positions, kpos,
            mesh=rules["mesh"], seq_axis=rules["cache_seq"],
            batch_axis=rules.get("batch"), window=window,
            qk_dim=cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        o_lat = o_lat.astype(jnp.float32)
    else:
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        cc.astype(jnp.float32))
             + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                          ckr.astype(jnp.float32))) * scale
        mask = _attn_mask(positions, kpos, None, window, True)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", w, cc.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", o_lat.astype(x.dtype), p["wv_b"])
    out = out.reshape(B, S, cfg.num_heads * cfg.v_head_dim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), {"c": cc, "kr": ckr}


# ------------------------------------------------------------------------- MLPs
def init_mlp(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn_kind == "gelu":
        return {"w_up": dense_init(k1, cfg.d_model, d_ff, dtype),
                "w_down": dense_init(k2, d_ff, cfg.d_model, dtype)}
    return {"w_gate": dense_init(k1, cfg.d_model, d_ff, dtype),
            "w_up": dense_init(k2, cfg.d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, cfg.d_model, dtype)}


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.ffn_kind == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    act = (jax.nn.gelu if cfg.ffn_kind == "geglu" else jax.nn.silu)
    g = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = g * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
