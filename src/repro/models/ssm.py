"""Mamba2 (SSD — state-space duality) mixer, pure-jnp chunked implementation.

Layout follows the SSD paper [arXiv:2405.21060]: tokens are split into chunks
of length Q; within a chunk the dual quadratic (attention-like) form is used,
across chunks a recurrent state h [B, H, P, N] is carried. B/C projections
are per-*group* (ngroups, shared across heads — the MQA analogue).

TPU adaptation (DESIGN.md §5): the usual fused ``in_proj`` is split into
per-part projections (z, x, B, C, dt) so the inner dimension can shard on the
``model`` axis head-aligned (Megatron-style TP for SSMs); B/C are per-group
and replicated. The depthwise conv is likewise per-part.

``ssd_chunked`` is the reference the Pallas kernel (kernels/ssd_scan.py) is
validated against; the model calls through an injectable ``ssd_fn``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.parallel.sharding import lshard


def init_mamba2(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    nh, g, N = cfg.ssm_heads, cfg.ssm_ngroups, cfg.ssm_state
    K = cfg.ssm_conv_dim
    ks = jax.random.split(key, 7)
    conv = lambda k, ch: (jax.random.normal(k, (K, ch), jnp.float32)  # noqa: E731
                          * 0.1).astype(dtype)
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, g * N, dtype),
        "w_C": dense_init(ks[3], d, g * N, dtype),
        "w_dt": dense_init(ks[4], d, nh, dtype),
        "conv_x_w": conv(ks[5], di),
        "conv_B_w": conv(ks[6], g * N),
        "conv_C_w": conv(ks[6], g * N),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_b": jnp.zeros((g * N,), dtype),
        "conv_C_b": jnp.zeros((g * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def segsum(dA):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} dA[..., k] (i >= j)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (pure jnp reference).

    x: [b, L, H, P]; dt: [b, L, H] (already softplus'd); A: [H] (negative);
    B, C: [b, L, G, N]. Returns (y [b, L, H, P], final_state [b, H, P, N]).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, L)
    nc = -(-L // Q)
    pad = nc * Q - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(b, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(b, nc, Q, H).astype(f32)
    Bc = B.reshape(b, nc, Q, G, N).astype(f32)
    Cc = C.reshape(b, nc, Q, G, N).astype(f32)
    dA = dtc * A[None, None, None, :]                       # [b,nc,Q,H]
    dA_cs = jnp.cumsum(dA, axis=2)                          # [b,nc,Q,H]

    # ---- intra-chunk (diagonal blocks): attention-like quadratic form
    Lmat = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))        # [b,nc,H,Q,Q]
    # scores[b,c,h,i,j] = C_i . B_j  (group broadcast to heads)
    CB = jnp.einsum("bcigN,bcjgN->bcgij", Cc, Bc)
    CB = jnp.repeat(CB, rep, axis=2)                        # [b,nc,H,Q,Q]
    scores = CB * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # ---- chunk states: contribution of each chunk to the carried state
    decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)            # [b,nc,Q,H]
    Bh = jnp.repeat(Bc, rep, axis=3)                        # [b,nc,Q,H,N]
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay * dtc, Bh, xc)                # [b,nc,H,P,N]

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # [b,nc,H]
    h0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((b, H, P, N), f32))

    def step(h, inp):
        st, cd = inp
        h_out = h                                            # state BEFORE chunk
        h = h * cd[:, :, None, None] + st
        return h, h_out

    (h_final, h_prev) = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # [b,nc,H,P,N]

    # ---- state -> output within each chunk
    Ch = jnp.repeat(Cc, rep, axis=3)                        # [b,nc,Q,H,N]
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, h_prev, jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(b, nc * Q, H, P)[:, :L]
    return y.astype(x.dtype), h_final.astype(x.dtype)


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token recurrence: x [b,H,P], dt [b,H], B/C [b,G,N],
    state [b,H,P,N] -> (y [b,H,P], new_state)."""
    f32 = jnp.float32
    G = B.shape[1]
    H = x.shape[1]
    rep = H // G
    Bh = jnp.repeat(B.astype(f32), rep, axis=1)             # [b,H,N]
    Ch = jnp.repeat(C.astype(f32), rep, axis=1)
    dA = jnp.exp(dt.astype(f32) * A[None, :])               # [b,H]
    new_state = (state.astype(f32) * dA[:, :, None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt.astype(f32), Bh,
                              x.astype(f32)))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state.astype(state.dtype)


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    out = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(xpart, w, b, conv_state=None):
    """Depthwise causal conv over time. xpart [B,S,Ch]; w [K,Ch].

    conv_state [B,K-1,Ch] (history) or None. Returns (out, new_state)."""
    K = w.shape[0]
    if conv_state is None:
        hist = jnp.zeros((xpart.shape[0], K - 1, xpart.shape[2]), xpart.dtype)
    else:
        hist = conv_state
    full = jnp.concatenate([hist, xpart], axis=1)
    out = jnp.zeros(xpart.shape, dtype=jnp.float32)
    S = xpart.shape[1]
    for i in range(K):  # K is tiny (4): unrolled taps
        out = out + full[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xpart.dtype)
    new_state = full[:, full.shape[1] - (K - 1):]
    return out, new_state


def _project(p, x, cfg):
    """Per-part projections + convs. x [B,S,d] -> (z, xs, Bm, Cm, dt_raw,
    conv_states)."""
    B_, S, _ = x.shape
    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
    xh = jnp.einsum("bsd,dk->bsk", x, p["w_x"])
    Bh = jnp.einsum("bsd,dk->bsk", x, p["w_B"])
    Ch = jnp.einsum("bsd,dk->bsk", x, p["w_C"])
    dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])
    z = lshard(z, "batch", None, "ssm_heads")
    xh = lshard(xh, "batch", None, "ssm_heads")
    return z, xh, Bh, Ch, dt


def apply_mamba2(p, x, cfg: ModelConfig, *, state=None, ssd_fn=None):
    """Full Mamba2 mixer over a sequence (train/prefill).

    x: [B,S,d]. state: None or {"conv_x","conv_B","conv_C", "ssd"}.
    Returns (out [B,S,d], new_state).
    """
    B_, S, _ = x.shape
    di = cfg.ssm_d_inner
    nh, g, N, P = cfg.ssm_heads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_head_dim
    z, xh, Bh, Ch, dt = _project(p, x, cfg)
    st = state or {}
    xh, ncx = _causal_conv(xh, p["conv_x_w"], p["conv_x_b"], st.get("conv_x"))
    Bh, ncB = _causal_conv(Bh, p["conv_B_w"], p["conv_B_b"], st.get("conv_B"))
    Ch, ncC = _causal_conv(Ch, p["conv_C_w"], p["conv_C_b"], st.get("conv_C"))
    xs = xh.reshape(B_, S, nh, P)
    Bm = Bh.reshape(B_, S, g, N)
    Cm = Ch.reshape(B_, S, g, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    fn = ssd_fn or ssd_chunked
    y, final_state = fn(xs, dt, A, Bm, Cm, cfg.ssm_chunk, st.get("ssd"))
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(B_, S, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC,
                 "ssd": final_state}


def apply_mamba2_decode(p, x, cfg: ModelConfig, *, state):
    """Single-token decode: x [B,1,d] with state dict. O(1) in history."""
    B_, S, _ = x.shape
    di = cfg.ssm_d_inner
    nh, g, N, P = cfg.ssm_heads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_head_dim
    z, xh, Bh, Ch, dt = _project(p, x, cfg)
    xh, ncx = _causal_conv(xh, p["conv_x_w"], p["conv_x_b"], state["conv_x"])
    Bh, ncB = _causal_conv(Bh, p["conv_B_w"], p["conv_B_b"], state["conv_B"])
    Ch, ncC = _causal_conv(Ch, p["conv_C_w"], p["conv_C_b"], state["conv_C"])
    xs = xh[:, 0].reshape(B_, nh, P)
    Bm = Bh[:, 0].reshape(B_, g, N)
    Cm = Ch[:, 0].reshape(B_, g, N)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_ssd = ssd_decode_step(xs, dt1, A, Bm, Cm, state["ssd"])
    y = y + p["D"][None, :, None].astype(y.dtype) * xs
    y = y.reshape(B_, 1, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC, "ssd": new_ssd}
