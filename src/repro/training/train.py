"""Loss and train-step construction (with remat and MoE aux loss)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.training import optimizer as OPT


def lm_loss(logits, labels, mask=None):
    """Next-token cross-entropy; labels already shifted by the pipeline."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    return -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 0.01,
                 dispatch: str = "auto", remat: bool = False):
    def loss_fn(params, batch):
        logits, _, aux = T.forward(
            params, cfg, batch["tokens"], mode="train",
            encoder_input=batch.get("frames"), dispatch=dispatch, remat=remat)
        loss = lm_loss(logits, batch["labels"], batch.get("mask"))
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OPT.OptimizerConfig, *,
                    aux_weight: float = 0.01, dispatch: str = "auto",
                    remat: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Pure function of its inputs — suitable for jit with in/out shardings
    (see launch/dryrun.py and launch/train.py).
    """
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, dispatch=dispatch,
                           remat=remat)

    def train_step(params, opt_state, batch):
        (total, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = OPT.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        metrics = dict(parts, total_loss=total, **om)
        return params, opt_state, metrics

    return train_step


def make_train_step_accum(cfg: ModelConfig, opt_cfg: OPT.OptimizerConfig, *,
                          accum_steps: int, aux_weight: float = 0.01,
                          dispatch: str = "auto", remat: bool = False):
    """Gradient-accumulation train step: the batch's leading dim is split
    into ``accum_steps`` microbatches scanned sequentially — the standard
    way to fit large global batches per step without more HBM.

    batch tensors must have global_batch % accum_steps == 0.
    """
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, dispatch=dispatch,
                           remat=remat)

    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            gsum, lsum = carry
            (total, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + total), None

        micros = jax.tree_util.tree_map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)),
                                       micros)
        grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
        params, opt_state, om = OPT.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        return params, opt_state, dict(total_loss=lsum / accum_steps, **om)

    return train_step
