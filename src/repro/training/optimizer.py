"""AdamW with decoupled weight decay + warmup-cosine schedule (pure pytree).

No optax dependency in this environment — this is a minimal, fully-featured
implementation: bias-corrected moments, decoupled decay (skipping norms /
biases / scalars), global-norm clipping, and a warmup-cosine LR schedule.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, moments_dtype="float32"):
    """``moments_dtype="bfloat16"`` halves optimizer memory (8-bit-Adam-style
    compromise) — used for the >100B MoE dry-runs to fit HBM."""
    dt = jnp.dtype(moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }


def _decay_mask(params):
    """True where weight decay applies (2D+ matrices only)."""
    return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state["mu"], grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state["nu"], grads)
    sf = jnp.asarray(step, jnp.float32)
    bc1 = 1 - b1 ** sf
    bc2 = 1 - b2 ** sf
    lr = lr_at(cfg, step)
    decay = _decay_mask(params)

    def upd(p, m, v, d):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + jnp.where(d, cfg.weight_decay, 0.0) * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu, decay)
    new_state = {"step": step, "mu": mu, "nu": nu}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
