"""Checkpointing: pytree <-> msgpack on disk (host-gathered).

Layout: one ``<step>.ckpt`` file holding {path: (dtype, shape, bytes)} plus a
JSON-ish meta dict. Simple, dependency-light, good enough for the example
drivers; a production deployment would swap in a sharded async writer.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out


def save(path: str, tree, meta: dict | None = None):
    flat = _flatten(tree)
    payload = {"__meta__": meta or {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load(path: str, like=None):
    """Load a checkpoint. With ``like`` (a template pytree), restores the
    tree structure and device dtypes; otherwise returns a flat dict."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    meta = payload.pop("__meta__", {})
    arrays = {k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
              for k, v in payload.items()}
    if like is None:
        return arrays, meta
    flat_like = _flatten(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_order = []
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        leaves_order.append(jnp.asarray(arrays[key], dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves_order), meta
