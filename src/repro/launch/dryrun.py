import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.training import optimizer as OPT
from repro.training import train as TR
from repro.launch.mesh import make_production_mesh

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × input-shape) on the
production meshes, with NO device allocation (ShapeDtypeStruct stand-ins).

Per case it records: memory analysis, cost analysis (FLOPs / bytes), and the
collective-op byte histogram parsed from the partitioned HLO — the §Roofline
inputs. Artifacts land in ``dryrun_artifacts/`` as JSON.

Skips (DESIGN.md §4): whisper-medium × long_500k (bounded enc-dec decoder).
Dense/MoE/VLM archs run long_500k with the sliding-window ring cache.
"""

HLO_SHAPE_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([\d,]*)\][^)]*?\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
               "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1}
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")


def collective_bytes(hlo_text: str, layer_trips: int = 1) -> dict:
    """Per-device collective traffic by op kind, from partitioned HLO.

    XLA reports a ``while`` (lax.scan) body ONCE — its collectives execute
    once per trip. We attribute each collective to its enclosing
    computation and scale those inside while-bodies by ``layer_trips``
    (the dominant loop is the layer-stack scan; nested shorter scans are
    conservatively scaled the same — documented in EXPERIMENTS §Roofline).
    Returns {kind: {count, bytes, bytes_scaled}}.
    """
    # split into computations and find while-body names
    comp_of_line = []
    cur = "__top__"
    body_names = set()
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
        comp_of_line.append((cur, line))
        if " while(" in line or "= while(" in line or " while." in line:
            for b in _BODY_RE.finditer(line):
                body_names.add(b.group(1))

    out = {}
    for comp, line in comp_of_line:
        m = HLO_SHAPE_RE.search(line)
        if not m:
            continue
        dt, dims, kind = m.groups()
        n = 1
        for d in (dims.split(",") if dims else []):
            if d:
                n *= int(d)
        b = n * DTYPE_BYTES.get(dt, 4)
        scale = layer_trips if any(bn in comp for bn in body_names) or \
            "while" in comp or "body" in comp else 1
        ent = out.setdefault(kind, {"count": 0, "bytes": 0, "bytes_scaled": 0})
        ent["count"] += 1
        ent["bytes"] += b
        ent["bytes_scaled"] += b * scale
    return out


def model_flops_analytic(cfg, shape) -> dict:
    """Architecture-exact step FLOPs (global, fwd; train multiplies by 3).

    MODEL_FLOPS uses the 6·N_active·D convention (2·N fwd + 4·N bwd per
    token); attention-score FLOPs are reported separately.
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        mult = 3.0  # fwd + bwd
        ctx = S / 2
    elif shape.kind == "prefill":
        tokens = B * S
        mult = 1.0
        ctx = S / 2
    else:  # decode: ONE token per request
        tokens = B * 1
        mult = 1.0
        ctx = S
        if cfg.sliding_window and cfg.family in ("dense", "moe", "vlm") \
                and S > cfg.sliding_window:
            ctx = cfg.sliding_window
    n_total = cfg.param_count()
    n_active = n_total
    if cfg.num_experts:
        expert = 3 * cfg.d_model * cfg.d_ff
        routed_all = cfg.num_experts * expert * cfg.num_layers
        active_routed = cfg.num_experts_per_tok * expert * cfg.num_layers
        n_active = n_total - routed_all + active_routed
    linear = 2.0 * n_active * tokens
    attn_scores = 0.0
    if cfg.attention_kind != "none":
        hd = cfg.resolved_head_dim if cfg.attention_kind == "gqa" else \
            (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        n_attn_layers = (cfg.num_layers if cfg.hybrid_attn_every == 0
                         else cfg.num_layers // cfg.hybrid_attn_every)
        attn_scores = (4.0 * tokens * ctx * cfg.num_heads * hd
                       * n_attn_layers)
    ssd = 0.0
    if cfg.ssm_state:
        # state update + output contraction per token per head
        ssd = (6.0 * tokens * cfg.ssm_heads * cfg.ssm_head_dim
               * cfg.ssm_state * cfg.num_layers)
    total = (linear + attn_scores + ssd) * mult
    return {"model_flops_global": total,
            "model_flops_6nd": 6.0 * n_active * tokens if shape.kind == "train"
            else 2.0 * n_active * tokens,
            "n_active": n_active, "tokens": tokens}


def shaped(tree_structs, specs_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_structs, specs_tree)


def input_specs(arch: str, shape_name: str, mesh, *, dtype="bfloat16",
                rules_override=None, cfg_override=None):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of (arch, shape) plus the step fn."""
    cfg = cfg_override or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    long_ctx = shape_name == "long_500k"
    rules = (SH.long_context_rules(cfg, mesh) if long_ctx
             else SH.rules_for(cfg, mesh))
    if rules_override:
        rules.update(rules_override)
    rules["mesh"] = mesh  # needed by distributed ops (flash_decode, MoE a2a)
    bspec = rules.get("batch")

    params_s = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype))
    pspecs = SH.param_specs(cfg, params_s, rules, mesh)
    params_in = shaped(params_s, pspecs, mesh)

    kind = shape.kind
    window = None
    if kind == "train":
        opt_s = jax.eval_shape(
            lambda p: OPT.init_opt_state(p, moments_dtype="bfloat16"),
            params_s)
        ospecs = SH.opt_state_specs(pspecs)
        opt_in = shaped(opt_s, ospecs, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        bspecs = SH.batch_specs(rules)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
            bspecs = SH.batch_specs(rules, with_frames=True)
        batch_in = shaped(batch, bspecs, mesh)
        step = TR.make_train_step(cfg, OPT.OptimizerConfig(),
                                  dispatch="auto", remat=True)
        args = (params_in, opt_in, batch_in)
        return cfg, rules, step, args

    # serving shapes ------------------------------------------------------
    if kind == "decode" and long_ctx and cfg.family in ("dense", "moe", "vlm"):
        window = cfg.sliding_window
        M = window
    elif kind == "decode":
        M = S
    else:  # prefill
        M = S
    cache_s = jax.eval_shape(lambda: T.init_cache(cfg, B, M, dtype))
    cspecs = SH.cache_specs(cache_s, rules)
    cache_in = shaped(cache_s, cspecs, mesh)

    if kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                      sharding=NamedSharding(mesh, P(bspec, None)))
        frames_in = None
        if cfg.family == "audio":
            frames_in = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, P(bspec, None, None)))

        def step(params, tokens, cache, frames=None):
            logits, new_cache, _ = T.forward(
                params, cfg, tokens, mode="prefill", cache=cache,
                encoder_input=frames, dispatch="auto")
            return logits, new_cache

        args = (params_in, tokens, cache_in) + (
            (frames_in,) if frames_in is not None else ())
        return cfg, rules, step, args

    # decode: ONE new token against a seq_len-deep cache
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(bspec, None)))
    positions = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                     sharding=NamedSharding(mesh, P(bspec, None)))
    w = window

    def step(params, tokens, positions, cache):
        logits, new_cache, _ = T.forward(
            params, cfg, tokens, positions=positions, mode="decode",
            cache=cache, window=w, dispatch="auto")
        return logits, new_cache

    return cfg, rules, step, (params_in, tokens, positions, cache_in)


def should_skip(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        return f"{arch} is encoder-decoder with a bounded decoder; long_500k skipped (DESIGN.md §4)"
    return None


def run_case(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             rules_override=None, cfg_override=None, tag: str = "") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    case = f"{arch}__{shape_name}__{mesh_name}{tag}"
    skip = should_skip(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return _finish(rec, out_dir, case)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        cfg, rules, step, args = input_specs(
            arch, shape_name, mesh, rules_override=rules_override,
            cfg_override=cfg_override)
        with mesh:
            with SH.use_rules(rules):
                lowered = jax.jit(step).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: getattr(ma, k) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            rec["cost_analysis"] = {
                "flops": ca.get("flops", 0.0),
                "bytes_accessed": ca.get("bytes accessed", 0.0),
                "transcendentals": ca.get("transcendentals", 0.0),
            }
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)}
        try:
            txt = compiled.as_text()
            rec["collectives"] = collective_bytes(
                txt, layer_trips=cfg.num_layers)
            rec["hlo_ops"] = len(txt.splitlines())
        except Exception as e:
            rec["collectives"] = {"error": str(e)}
        rec["analytic"] = model_flops_analytic(cfg, INPUT_SHAPES[shape_name])
        rec["num_layers"] = cfg.num_layers
        # per-device input footprint from shardings (proves it fits)
        ndev = mesh.devices.size
        arg_bytes = 0
        for leaf in jax.tree_util.tree_leaves(args):
            shard_elems = leaf.size
            try:
                sh = leaf.sharding
                shard_elems = sh.shard_shape(leaf.shape)
                n = 1
                for d in shard_elems:
                    n *= d
                shard_elems = n
            except Exception:
                pass
            arg_bytes += shard_elems * leaf.dtype.itemsize
        rec["per_device_arg_bytes"] = int(arg_bytes)
        rec["n_devices"] = int(ndev)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return _finish(rec, out_dir, case)


def _finish(rec, out_dir, case):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, case + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec.get("status")
    extra = ""
    if status == "ok":
        ca = rec.get("cost_analysis", {})
        extra = (f" flops={ca.get('flops', 0):.3g}"
                 f" argGB/dev={rec['per_device_arg_bytes']/2**30:.2f}"
                 f" compile={rec.get('compile_s')}s")
    elif status == "error":
        extra = " " + rec.get("error", "")[:160]
    print(f"[dryrun] {case}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_artifacts")
    # beyond-paper optimization toggles (EXPERIMENTS.md §Perf)
    ap.add_argument("--tp-pad", action="store_true",
                    help="head padding / KV replication for TP alignment")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="expert-parallel all-to-all MoE dispatch")
    ap.add_argument("--flash-decode", action="store_true",
                    help="distributed flash-decoding for seq-sharded caches")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rules_override = {}
    tag = ""
    if args.moe_a2a:
        rules_override["moe_a2a"] = True
        tag += "+moe_a2a"
    if args.flash_decode:
        rules_override["flash_decode"] = True
        tag += "+flashdecode"
    n_err = 0
    for arch in archs:
        for shape in shapes:
            cfg_override = get_config(arch).tp_padded(16) if args.tp_pad \
                else None
            for mp in meshes:
                rec = run_case(arch, shape, multi_pod=mp, out_dir=args.out,
                               rules_override=rules_override or None,
                               cfg_override=cfg_override,
                               tag=tag + ("+tppad" if args.tp_pad else ""))
                n_err += rec["status"] == "error"
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
