"""Training driver: real execution on host devices (CPU here, TPU pods via
the same code path with make_production_mesh).

Example (the (b) end-to-end deliverable, ~100M model for a few hundred steps):

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduce --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import transformer as T
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training import train as TR


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduce", action="store_true",
                    help="train the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--ckpt", default=None, help="path to save final ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"family={cfg.family})")
    params = T.init_params(cfg, jax.random.PRNGKey(0), args.dtype)
    ocfg = OPT.OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                               total_steps=args.steps)
    opt = OPT.init_opt_state(params)
    step_fn = jax.jit(TR.make_train_step(cfg, ocfg))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)

    t0 = time.time()
    for i in range(args.steps):
        batch = synth_batch(cfg, dcfg, i)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        params, opt, metrics = step_fn(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"[train] step {i:4d} loss={m['loss']:.4f} "
                  f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        CKPT.save(args.ckpt, params, {"steps": args.steps, "arch": cfg.name})
        print(f"[train] saved {args.ckpt}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
