"""Serving driver: the live CoCoServe loop — Orchestrator over N paged
engines, real telemetry feeding Monitor -> Controller, decisions executed
on the running instances (scale-up replication degrees, scale-down
KV-block migration).

Runs REAL JAX execution with a reduced config (CPU-feasible); on a real
pod the same orchestrator runs the full config under
make_production_mesh(). Families without paged support (SSM/MLA/audio)
fall back to a single dense engine with the same submission loop.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 24 --rps 4 --instances 2

Every flag lives on ``ServeConfig`` — one dataclass, built either from
the command line (``ServeConfig.from_args``) or from a TOML file
(``ServeConfig.from_toml``; pass ``--config serve.toml`` and override
individual keys with normal flags on top). Programmatic callers build
the dataclass directly and hand it to ``run()``.

``--workers N`` lifts the same loop onto the DISTRIBUTED serving plane:
N engine-server processes are spawned (one real paged Engine each,
serving/remote_engine.py) and the orchestrator drives them over the RPC
wire protocol — admissions, telemetry snapshots, controller plans and
block migrations all travel as length-prefixed frames, no shared
memory:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 24 --rps 4 --workers 2 --drain

``--inventory pod.toml`` lifts it across MACHINES: the inventory lists
nodes (host, first port, capacity, spawn-vs-attach), launch/pod.py
brings up one engine server per ``tcp://host:port`` endpoint — spawned
locally or attached where already running — and the SAME orchestrator
loop drives them over TCP frames:

    PYTHONPATH=src python -m repro.launch.serve --inventory pod.toml \
        --requests 24 --rps 4 --drain

``--http`` swaps the synthetic workload for the real front door
(serving/ingress.py): streaming completions over HTTP/1.1 with
prefix-affinity routing, SLO-class admission and 429 backpressure; add
``--elastic`` to let the controller grow/shrink the pod while serving.
``--scheduler slo`` runs the class-aware scheduler (DESIGN.md §13) so
``"slo_class": "interactive"`` completions pre-empt batch traffic:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --instances 2 --http --http-port 8080 --scheduler slo
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import scheduler as SCH
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec


@dataclasses.dataclass
class ServeConfig:
    """Every serve.py knob in one place (module docstring). Field names
    are the CLI flags with ``-`` -> ``_``; the same names key the TOML
    form (flat, or under a ``[serve]`` table)."""
    arch: str = "tinyllama-1.1b"
    requests: int = 16
    rps: float = 4.0
    max_batch: int = 4
    max_new: int = 16
    prompt_len: int = 12
    instances: int = 2
    workers: int = 0
    inventory: Optional[str] = None
    slo: float = 40.0
    rpc_deadline: Optional[float] = None
    supervise: bool = False
    drain: bool = False
    cache: str = "auto"
    token_budget: int = 128
    scheduler: str = "budget"
    http: bool = False
    http_host: str = "127.0.0.1"
    http_port: int = 8080
    http_seconds: Optional[float] = None
    trace_out: Optional[str] = None
    flightrec_out: Optional[str] = None
    max_queue: int = 8
    elastic: bool = False
    max_pod: int = 4
    govern_budget: bool = True

    def validate(self) -> "ServeConfig":
        if self.scheduler not in SCH.POLICIES:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r} "
                f"(registered: {', '.join(sorted(SCH.POLICIES))})")
        if self.cache not in ("auto", "dense", "paged"):
            raise ValueError(f"cache must be auto|dense|paged, "
                             f"got {self.cache!r}")
        if self.requests < 0 or self.token_budget < 1:
            raise ValueError("requests must be >= 0 and "
                             "token_budget >= 1")
        return self

    @classmethod
    def from_toml(cls, path: str) -> "ServeConfig":
        """Load a config file: all keys optional, unknown keys are an
        error (a typo should not silently fall back to a default).
        Reuses launch/pod.py's tomllib/tomli probe."""
        from repro.launch.pod import _toml
        if _toml is None:  # pragma: no cover - tomli/tomllib baked in
            raise RuntimeError("TOML config needs tomllib (py3.11+) or "
                               "tomli")
        with open(path, "rb") as f:
            data = _toml.load(f)
        if "serve" in data and isinstance(data["serve"], dict):
            data = data["serve"]
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - names)
        if unknown:
            raise ValueError("unknown serve config key(s): "
                             + ", ".join(unknown))
        return cls(**data).validate()

    @classmethod
    def from_args(cls, argv=None) -> "ServeConfig":
        """CLI front: ``--config file.toml`` seeds the defaults, every
        other flag overrides field-by-field on top."""
        pre = argparse.ArgumentParser(add_help=False)
        pre.add_argument("--config", default=None,
                         help="TOML file of ServeConfig keys; flags "
                              "given alongside override it")
        known, rest = pre.parse_known_args(argv)
        base = cls.from_toml(known.config) if known.config else cls()
        args = _build_parser(base).parse_args(rest)
        return cls(**{f.name: getattr(args, f.name)
                      for f in dataclasses.fields(cls)}).validate()


def _build_parser(d: ServeConfig) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--arch", default=d.arch)
    ap.add_argument("--requests", type=int, default=d.requests)
    ap.add_argument("--rps", type=float, default=d.rps)
    ap.add_argument("--max-batch", type=int, default=d.max_batch)
    ap.add_argument("--max-new", type=int, default=d.max_new)
    ap.add_argument("--prompt-len", type=int, default=d.prompt_len)
    ap.add_argument("--instances", type=int, default=d.instances)
    ap.add_argument("--workers", type=int, default=d.workers,
                    help="spawn N engine-server PROCESSES and drive them "
                         "over the RPC transport (the distributed serving "
                         "plane); 0 = in-process instances")
    ap.add_argument("--inventory", default=d.inventory,
                    help="pod inventory file (.toml/.json): bring up one "
                         "engine server per tcp:// endpoint it lists "
                         "(launch/pod.py) and drive them as the serving "
                         "plane; overrides --workers/--instances")
    ap.add_argument("--slo", type=float, default=d.slo,
                    help="engine-clock latency SLO (steps)")
    ap.add_argument("--rpc-deadline", type=float, default=d.rpc_deadline,
                    help="per-call RPC deadline in seconds: a hung "
                         "worker (socket open, no reply) is detected "
                         "within 2x this and quarantined instead of "
                         "stalling the control tick (default: off)")
    ap.add_argument("--supervise", action="store_true",
                    default=d.supervise,
                    help="respawn dead/quarantined spawned workers with "
                         "capped exponential backoff (flap detector "
                         "evicts a worker that keeps dying)")
    ap.add_argument("--drain", action="store_true", default=d.drain,
                    help="after the workload, drain instance N-1 "
                         "(scale-down consolidation demo)")
    ap.add_argument("--cache", choices=["auto", "dense", "paged"],
                    default=d.cache)
    ap.add_argument("--token-budget", type=int, default=d.token_budget,
                    help="per-step token budget for the continuous-"
                         "batching scheduler (DESIGN.md §10): decode "
                         "slots are charged first, the remainder admits "
                         "prefill chunks; paged engines only")
    ap.add_argument("--scheduler", choices=sorted(SCH.POLICIES),
                    default=d.scheduler,
                    help="scheduler policy (serving/scheduler.py "
                         "registry): 'budget' = token-budget continuous "
                         "batching, 'slo' adds per-class budget splits + "
                         "deadline ordering, 'phase' pins the legacy "
                         "prefill-wave/decode-step alternation")
    ap.add_argument("--http", action="store_true", default=d.http,
                    help="serve the HTTP front door instead of the "
                         "synthetic workload: POST /v1/completions "
                         "(chunked token streaming), GET /v1/models "
                         "/healthz /stats (serving/ingress.py); paged "
                         "engines only")
    ap.add_argument("--http-host", default=d.http_host)
    ap.add_argument("--http-port", type=int, default=d.http_port,
                    help="ingress port (0 = ephemeral, printed at bind)")
    ap.add_argument("--http-seconds", type=float, default=d.http_seconds,
                    help="serve for N seconds then exit cleanly "
                         "(default: until Ctrl-C)")
    ap.add_argument("--trace-out", default=d.trace_out,
                    help="append one JSONL line per finished request "
                         "trace (the span tree: accept/route/queue/"
                         "prefill chunks/first token/decode/migration "
                         "hops); --http only")
    ap.add_argument("--flightrec-out", default=d.flightrec_out,
                    help="file the control-plane flight recorder "
                         "auto-dumps its event ring to on crash-"
                         "recovery events (also served live at "
                         "GET /debug/flightrec)")
    ap.add_argument("--max-queue", type=int, default=d.max_queue,
                    help="per-instance admission ceiling: when every "
                         "instance's queue is at this, the ingress "
                         "sheds with 429 + Retry-After")
    ap.add_argument("--elastic", action="store_true", default=d.elastic,
                    help="arm pod grow/shrink: the controller may spawn "
                         "a whole extra worker under sustained pressure "
                         "and drain+reap one when the pod runs empty")
    ap.add_argument("--max-pod", type=int, default=d.max_pod,
                    help="pod-size ceiling for --elastic growth")
    ap.add_argument("--no-govern-budget", dest="govern_budget",
                    action="store_false", default=d.govern_budget,
                    help="pin per-instance token budgets (disable the "
                         "ingress budget governor); --http only")
    return ap


def main(argv=None):
    return run(ServeConfig.from_args(argv))


def run(args: ServeConfig):
    args.validate()
    cfg = get_config(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    kind = args.cache
    if kind == "auto":  # primary path where the family supports it
        kind = "paged" if cfg.supports_paged_kv else "dense"
    print(f"[serve] cache_kind={kind}")

    rng = np.random.default_rng(0)

    def make_request(rid):
        return RequestSpec(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_tokens=args.max_new)

    t_start = time.time()

    if args.http and kind != "paged":
        raise SystemExit("[serve] --http needs a paged-cache family "
                         "(prefix-affinity routing keys on the paged "
                         "pool's content chains)")

    if kind == "dense":  # legacy single-engine fallback (no paged pool)
        eng = Engine(cfg, params, max_batch=args.max_batch, max_len=128,
                     cache_kind="dense")
        submitted, finished, step = 0, [], 0
        while len(finished) < args.requests and step < 5000:
            while submitted < args.requests and \
                    submitted <= eng.clock * args.rps:
                eng.submit(make_request(submitted))
                submitted += 1
            finished.extend(eng.step() or [])
            step += 1
        _report(finished, time.time() - t_start)
        return len(finished)

    from repro.serving.orchestrator import Orchestrator, RespawnPolicy
    policy = RespawnPolicy() if args.supervise else None
    sched_kw = dict(scheduler=args.scheduler,
                    token_budget=args.token_budget)
    front_kw = {}
    if args.http:
        # front-door knobs: admission ceiling (-> 429 at the door) and,
        # with --elastic, the runtime worker factory + pod thresholds
        # that let the controller grow/shrink the pod while serving
        front_kw["max_queue"] = args.max_queue
        if args.elastic:
            from repro.core.controller import PodElasticityConfig
            from repro.launch.pod import make_worker_factory
            front_kw["worker_factory"] = make_worker_factory(
                cfg, params, remote=bool(args.workers or args.inventory),
                max_batch=args.max_batch, max_len=128, **sched_kw)
            front_kw["pod_cfg"] = PodElasticityConfig(
                max_instances=args.max_pod)
    if args.flightrec_out:
        front_kw["flightrec_path"] = args.flightrec_out
    if args.inventory:
        from repro.launch.pod import launch_pod, load_inventory
        nodes = load_inventory(args.inventory)
        handles = launch_pod(cfg, params, nodes,
                             max_batch=args.max_batch, max_len=128,
                             **sched_kw)
        n_instances = len(handles)
        orch = Orchestrator(cfg, params, handles=handles,
                            slo_latency=args.slo, telemetry_every=4,
                            rpc_deadline=args.rpc_deadline,
                            respawn_policy=policy, **front_kw)
        print(f"[serve] pod: {n_instances} engine servers over TCP "
              f"({sum(n.spawn for n in nodes)} node(s) spawned, "
              f"{sum(not n.spawn for n in nodes)} attached)")
    else:
        n_instances = args.workers or args.instances
        orch = Orchestrator(cfg, params, n_instances=n_instances,
                            max_batch=args.max_batch, max_len=128,
                            slo_latency=args.slo, telemetry_every=4,
                            remote=bool(args.workers),
                            rpc_deadline=args.rpc_deadline,
                            respawn_policy=policy, **front_kw,
                            **sched_kw)
        if args.workers:
            print(f"[serve] distributed plane: {args.workers} "
                  f"engine-server processes over RPC")
    if args.http:
        from repro.serving.ingress import Ingress
        ing = Ingress(orch, host=args.http_host, port=args.http_port,
                      model_id=args.arch, trace_out=args.trace_out,
                      govern_budget=args.govern_budget).start()
        print(f"[serve] http ingress on http://{ing.host}:{ing.port}  "
              f"(POST /v1/completions; GET /v1/models /healthz /stats "
              f"/metrics /debug/flightrec)"
              + ("  [elastic pod]" if args.elastic else ""), flush=True)
        try:
            if args.http_seconds is not None:
                time.sleep(args.http_seconds)
            else:
                while True:
                    time.sleep(1.0)
        except KeyboardInterrupt:
            print("\n[serve] interrupt; draining streams", flush=True)
        ing.close()
        c = ing.counters
        print(f"[serve] ingress: {c.requests} requests "
              f"({c.streamed} streamed), {c.tokens_out} tokens out, "
              f"routed prefix/vacancy={c.routed_prefix}/"
              f"{c.routed_vacancy}, 429s={c.rejected_429}, "
              f"400s={c.bad_requests}")
        _report(orch.finished, time.time() - t_start)
        orch.close()
        return len(orch.finished)

    submitted, step = 0, 0
    seen_actions = 0
    while len(orch.finished) < args.requests and step < 5000:
        clock = orch.clock()
        while submitted < args.requests and submitted <= clock * args.rps:
            orch.submit(make_request(submitted))
            submitted += 1
        orch.step()
        step += 1
        log = orch.controller.log
        for action in log[seen_actions:]:
            print(f"[serve] t={clock:.1f} controller: {action} "
                  f"P sum={sum(orch.plan.p)}")
        seen_actions = len(log)

    if args.drain and n_instances > 1:
        recs = orch.drain_instance(n_instances - 1)
        for r in recs:
            print(f"[serve] drained rid={r.rid} ({r.mode}) "
                  f"{r.n_blocks} blocks / {r.bytes_moved / 1e6:.2f} MB "
                  f"in {r.seconds * 1e3:.1f} ms, "
                  f"stream stalled {r.stall_s * 1e3:.1f} ms "
                  f"(est {r.est_seconds * 1e3:.0f} ms)")
        orch.run_until_done()

    _report(orch.finished, time.time() - t_start)
    s = orch.stats()
    print(f"[serve] instances={n_instances} dropped={s['dropped']} "
          f"migrations={s['migrations']} "
          f"(overlapped={s['overlapped_migrations']}) "
          f"preemptions={s['preemptions']} recoveries={s['recoveries']}")
    print(f"[serve] budget: utilization={s['budget_utilization']:.2f} "
          f"ttft_p50={s['ttft_p50']:.1f} ttft_p95={s['ttft_p95']:.1f} "
          f"queue_delay_p95={s['queue_delay_p95']:.1f}")
    print(f"[serve] prefix sharing: hit_rate={s['prefix_hit_rate']:.2f} "
          f"blocks_saved_now={s['blocks_saved_now']} "
          f"dedup_imports={s['dedup_imports']}")
    cp = s["control_plane"]
    print(f"[serve] control plane: {cp['rpc_polls_per_tick']:.2f} "
          f"multiplexed polls/tick over "
          f"{cp['step_rpcs_per_tick']:.1f} step RPCs/tick")
    ft = s["faults"]
    print(f"[serve] failure domain: injected={ft['injected']} "
          f"rpc_timeouts={ft['rpc_timeouts']} "
          f"quarantines={ft['quarantines']} respawns={ft['respawns']} "
          f"evictions={ft['evictions']}")
    print(f"[serve] final plan P (first 8): {orch.plan.p[:8]}, "
          f"continuity breaks: {orch.plan.continuity_breaks()}")
    orch.close()
    return len(orch.finished)


def _report(finished, wall):
    toks = sum(len(r.generated) for r in finished)
    lat = [r.finish_time - r.submit_time for r in finished] or [0.0]
    print(f"[serve] {len(finished)} requests, {toks} tokens, "
          f"wall {wall:.1f}s, engine-clock latency p50={np.median(lat):.1f}")


if __name__ == "__main__":
    main()
