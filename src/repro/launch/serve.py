"""Serving driver: continuous-batching engine + CoCoServe controller loop.

Runs REAL JAX execution with a reduced config (CPU-feasible), demonstrating
the full closed loop: Monitor -> Controller -> scale-up (layer replication)
/ scale-down (module reduction) -> Scheduler. On a real pod the same engine
runs the full config under make_production_mesh().

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 24 --rps 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.cluster import Cluster, layer_weight_bytes
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import Monitor, MetricsSnapshot
from repro.core.plan import PlacementPlan
from repro.models import transformer as T
from repro.serving.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rps", type=float, default=4.0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--cache", choices=["auto", "dense", "paged"],
                    default="auto")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    kind = args.cache
    if kind == "auto":  # primary path where the family supports it
        kind = "paged" if cfg.supports_paged_kv else "dense"
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=128,
                 cache_kind=kind)
    print(f"[serve] cache_kind={kind}")

    cluster = Cluster.homogeneous(4)
    plan = PlacementPlan.initial(cfg.num_layers)
    monitor = Monitor()
    ctrl = Controller(ControllerConfig(replica_size=layer_weight_bytes(cfg)),
                      cluster, plan, monitor, batch_size=args.max_batch)

    rng = np.random.default_rng(0)
    t_start = time.time()
    submitted = 0
    finished = []
    step = 0
    while len(finished) < args.requests:
        # Poisson-ish arrivals in engine clock time
        while submitted < args.requests and \
                submitted <= eng.clock * args.rps:
            eng.submit(Request(
                rid=submitted,
                prompt=rng.integers(2, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
            submitted += 1
        fin = eng.step() or []
        finished.extend(fin)
        step += 1
        if step % 8 == 0:
            lat = [r.finish_time - r.submit_time for r in finished] or [0.0]
            monitor.record(MetricsSnapshot(
                t=eng.clock, rps=args.rps,
                p50_latency=float(np.median(lat)),
                slo_violation_rate=0.0,
                queue_len=len(eng.queue),
                device_util=[len(eng.active) / args.max_batch, 0.1, 0.1, 0.1],
                device_mem_frac=[0.4, 0.05, 0.05, 0.05]))
            action = ctrl.tick()
            if action:
                print(f"[serve] t={eng.clock:.1f} controller: {action} "
                      f"P sum={sum(ctrl.plan.p)}")
        if step > 5000:
            break
    wall = time.time() - t_start
    toks = sum(len(r.generated) for r in finished)
    lat = [r.finish_time - r.submit_time for r in finished]
    print(f"[serve] {len(finished)} requests, {toks} tokens, "
          f"wall {wall:.1f}s, engine-clock latency p50={np.median(lat):.1f}")
    print(f"[serve] final plan P (first 8): {ctrl.plan.p[:8]}, "
          f"continuity breaks: {ctrl.plan.continuity_breaks()}")
    return len(finished)


if __name__ == "__main__":
    main()
