"""Node-inventory pod launcher — the multi-HOST deployment unit of the
distributed serving plane.

A *pod* is described by a small TOML (or JSON) inventory of nodes::

    # pod.toml — one [[node]] table per machine
    [[node]]
    host = "127.0.0.1"      # where the engine servers listen
    port = 7101             # first TCP port on that host
    capacity = 2            # engine instances on the node
                            #   -> endpoints port .. port+capacity-1
    spawn = true            # true:  spawn the servers locally (the
                            #        host must be THIS machine)
                            # false: attach to servers already running
                            #        there (started on the node via
                            #        `python -m repro.launch.pod
                            #         --serve tcp://0.0.0.0:7101`)

``load_inventory`` expands that into one ``tcp://host:port`` endpoint
per instance; ``launch_pod`` turns the endpoints into live
``EngineProxy`` handles — spawning listening engine-server processes
for ``spawn`` nodes and dialing (with connect-retry while the remote
bind races the connect) into already-running ones for the rest. The
orchestrator's §5 control loop drives the resulting handles unchanged:
``InstanceHandle`` hides the transport entirely, so scaling decisions,
overlapped two-phase migration, and crash replay behave identically
whether the instances share this process, this machine, or neither.

CLI::

    # on each worker node: one listening engine server per instance
    python -m repro.launch.pod --serve tcp://0.0.0.0:7101

    # on the orchestrator node: drive the whole pod
    python -m repro.launch.serve --inventory pod.toml --requests 24

**Trust boundary**: the wire protocol carries pickle frames (the init
message ships config + params) and performs no authentication — a
listening engine server executes whatever a connecting peer sends, so
endpoints must only be reachable from the trusted network segment the
pod runs on (bind a private interface, not a public one), exactly like
the intra-cluster RPC planes of mainstream serving stacks.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

try:                      # 3.11+ stdlib
    import tomllib as _toml
except ImportError:       # 3.10: the vendored backport
    try:
        import tomli as _toml  # type: ignore
    except ImportError:   # pragma: no cover - one of the two is baked in
        _toml = None


@dataclasses.dataclass
class Node:
    """One inventory row: ``capacity`` engine instances on ``host``,
    listening on consecutive TCP ports starting at ``port``."""
    host: str
    port: int
    capacity: int = 1
    spawn: bool = True

    def endpoints(self) -> List[str]:
        return [f"tcp://{self.host}:{self.port + k}"
                for k in range(self.capacity)]


def parse_inventory(doc: dict, origin: str = "<inventory>") -> List[Node]:
    """Validate one decoded inventory document into ``Node`` rows."""
    rows = doc.get("node")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"{origin}: expected a non-empty [[node]] list")
    nodes = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise ValueError(f"{origin}: node #{i} is not a table")
        unknown = set(row) - {"host", "port", "capacity", "spawn"}
        if unknown:
            raise ValueError(f"{origin}: node #{i} has unknown keys "
                             f"{sorted(unknown)}")
        try:
            node = Node(host=str(row["host"]), port=int(row["port"]),
                        capacity=int(row.get("capacity", 1)),
                        spawn=bool(row.get("spawn", True)))
        except KeyError as e:
            raise ValueError(f"{origin}: node #{i} missing key {e}") from e
        if node.capacity < 1:
            raise ValueError(f"{origin}: node #{i} capacity must be >= 1")
        if not 0 < node.port < 65536:
            raise ValueError(f"{origin}: node #{i} port {node.port} "
                             "out of range")
        nodes.append(node)
    seen: dict = {}
    for i, node in enumerate(nodes):
        for ep in node.endpoints():
            if ep in seen:
                raise ValueError(
                    f"{origin}: endpoint {ep} appears in both node "
                    f"#{seen[ep]} and node #{i} (overlapping port "
                    "ranges) — two servers cannot share it")
            seen[ep] = i
    return nodes


def load_inventory(path: str) -> List[Node]:
    """Read a ``.toml`` or ``.json`` inventory file into ``Node`` rows.
    JSON uses the same shape: ``{"node": [{"host": ..., ...}, ...]}``."""
    if path.endswith(".json"):
        with open(path) as f:
            return parse_inventory(json.load(f), origin=path)
    if _toml is None:  # pragma: no cover - tomli/tomllib is baked in
        raise RuntimeError("TOML inventory needs tomllib (py3.11+) or "
                           "tomli; use a .json inventory instead")
    with open(path, "rb") as f:
        return parse_inventory(_toml.load(f), origin=path)


def launch_pod(cfg, params, nodes: List[Node], *,
               start_timeout: float = 120.0,
               pod_timeout: Optional[float] = None,
               **engine_kw) -> list:
    """Bring up one ``EngineProxy`` per inventory endpoint and return
    the handle list for ``Orchestrator(handles=...)``.

    Two phases so startup tracks the slowest node, not the sum: first
    EVERY ``spawn`` node's server process is started (they boot their
    interpreters, import jax, and bind concurrently), then each
    endpoint is dialed and fed its init frame (the proxy adopts the
    pre-spawned child so liveness/kill still see it). On any failure,
    handles brought up so far are closed and spawned-but-unadopted
    servers are reaped before the error propagates (no orphan
    processes).

    ``start_timeout`` bounds each node's OWN bring-up (dial + init
    handshake); ``pod_timeout`` is the TOTAL wall deadline for the
    whole launch — with it, one never-booting node fails the pod fast
    (per-endpoint budget = whatever remains of the pod deadline)
    instead of serially eating a full ``start_timeout`` per endpoint.

    Proxies are labeled ``w0..wN-1`` in inventory order — the stable
    per-peer identity the fault-injection plans of
    ``serving/faults.py`` target (free-port inventories keep the same
    labels run to run, so a seeded chaos plan stays reproducible)."""
    import multiprocessing as mp
    import time

    from repro.serving.remote_engine import EngineProxy, engine_server_listen
    from repro.serving.transport import TransportError

    deadline = (None if pod_timeout is None
                else time.monotonic() + pod_timeout)
    ctx = mp.get_context("spawn")
    plan = []                       # (endpoint, spawned process | None)
    handles = []
    try:
        for node in nodes:
            for ep in node.endpoints():
                proc = None
                if node.spawn:
                    proc = ctx.Process(target=engine_server_listen,
                                       args=(ep,), daemon=True)
                    proc.start()
                plan.append((ep, proc))
        for k, (ep, proc) in enumerate(plan):
            budget = start_timeout
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"pod bring-up deadline ({pod_timeout:.1f}s) "
                        f"exceeded with {len(handles)}/{len(plan)} "
                        f"instances up (next: {ep})")
                budget = min(budget, remaining)
            handles.append(EngineProxy(
                cfg, params, endpoint=ep, spawn=False, adopt_process=proc,
                start_timeout=budget, peer_label=f"w{k}", **engine_kw))
    except Exception:
        for h in handles:
            try:
                h.close()
            except Exception:  # noqa: BLE001 - best-effort teardown
                pass
        adopted = {id(h.process) for h in handles if h.process is not None}
        for _, proc in plan:
            if proc is not None and id(proc) not in adopted \
                    and proc.is_alive():
                proc.kill()
                proc.join(timeout=5)
        raise
    return handles


def make_worker_factory(cfg, params, *, remote: bool = False,
                        **engine_kw):
    """Return the ``idx -> InstanceHandle`` callable that arms the
    orchestrator's RUNTIME pod growth (``Orchestrator.grow_pod``): the
    controller's grow decision spawns a whole fresh serving instance
    through it mid-flight, after launch. ``remote=True`` spawns an
    engine-server process and returns its ``EngineProxy`` (the same
    plane launch-time ``--workers`` instances live on); the default
    builds an in-process paged ``LocalInstance`` — enough for tests and
    single-host elasticity without process spin-up cost.

    Grown workers are labeled ``g<idx>`` — disjoint from the
    launch-time ``w<k>`` namespace, so fault-injection plans and logs
    can tell a runtime spawn from the original fleet."""
    if remote:
        from repro.serving.remote_engine import EngineProxy

        def factory(idx: int):
            return EngineProxy(cfg, params, peer_label=f"g{idx}",
                               **engine_kw)
    else:
        from repro.serving.engine import Engine
        from repro.serving.instance import LocalInstance

        def factory(idx: int):
            return LocalInstance(Engine(cfg, params, cache_kind="paged",
                                        **engine_kw))
    return factory


def main(argv: Optional[List[str]] = None) -> int:
    """``--serve ENDPOINT``: run ONE listening engine server in this
    process (the per-node worker entry; the orchestrator ships cfg +
    params in its init frame, so the node needs no local copy).
    ``--show INVENTORY``: print the endpoint expansion and exit."""
    import argparse

    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--serve", metavar="ENDPOINT",
                   help="listen on tcp://host:port, serve one "
                        "orchestrator connection, exit")
    g.add_argument("--show", metavar="INVENTORY",
                   help="parse an inventory file and print its "
                        "endpoints")
    args = ap.parse_args(argv)

    if args.show:
        for node in load_inventory(args.show):
            mode = "spawn" if node.spawn else "attach"
            for ep in node.endpoints():
                print(f"{ep}  ({mode})")
        return 0

    from repro.serving.remote_engine import engine_server_listen
    print(f"[pod] engine server listening on {args.serve}", flush=True)
    engine_server_listen(args.serve)
    print("[pod] orchestrator disconnected; exiting", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
