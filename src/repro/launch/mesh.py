"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = None, axes=("data",)):
    """Small CPU mesh for tests/examples (n real host devices)."""
    devs = jax.devices()
    n = n or len(devs)
    shape = (n,) if len(axes) == 1 else None
    return jax.make_mesh(shape, axes, devices=devs[:n])
