"""Synthetic tokenized data pipeline with sharded global batches.

Deterministic PRNG token stream shaped like a packed LM dataset (documents
separated by an EOS id, next-token labels, loss mask). ``sharded_batches``
places each batch directly with the mesh's batch sharding so per-host memory
stays bounded — the same pattern a real array-record loader would use.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    eos_id: int = 1
    mean_doc_len: int = 384


def synth_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> dict:
    """One packed batch: {"tokens","labels","mask"} int32 [B,S]."""
    rng = np.random.default_rng(dcfg.seed * 1_000_003 + step)
    B, S = dcfg.global_batch, dcfg.seq_len
    toks = rng.integers(2, cfg.vocab_size, size=(B, S + 1), dtype=np.int64)
    # sprinkle EOS boundaries to emulate packed documents
    n_eos = max(1, (S + 1) // dcfg.mean_doc_len)
    for b in range(B):
        pos = rng.integers(1, S, size=n_eos)
        toks[b, pos] = dcfg.eos_id
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    mask = (labels != dcfg.eos_id).astype(np.float32)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
            "mask": jnp.asarray(mask)}


def batches(cfg: ModelConfig, dcfg: DataConfig,
            num_steps: Optional[int] = None) -> Iterator[dict]:
    step = 0
    while num_steps is None or step < num_steps:
        yield synth_batch(cfg, dcfg, step)
        step += 1


def sharded_batches(cfg: ModelConfig, dcfg: DataConfig, mesh, batch_spec,
                    num_steps: Optional[int] = None) -> Iterator[dict]:
    """Batches placed with NamedSharding(mesh, batch_spec) on the fly."""
    from jax.sharding import NamedSharding
    sh = NamedSharding(mesh, batch_spec)
    for b in batches(cfg, dcfg, num_steps):
        yield jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), b)
