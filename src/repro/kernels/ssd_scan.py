"""Mamba2 SSD chunked scan (Pallas, TPU-targeted).

State-space duality: within a chunk of Q tokens the quadratic (attention-
like) dual form runs on the MXU; across chunks the recurrent state
h [P, N] is carried in VMEM scratch along the sequential chunk axis.

Grid: (batch*heads, n_chunks). Per step the kernel loads the chunk's
x [Q, P], dt [Q], B/C [Q, N] tiles (the B/C index map folds the
head-to-group mapping, G groups shared MQA-style), computes

  intra:  y_diag = (C B^T ∘ L ∘ dt) x          (Q×Q on the MXU)
  inter:  y_off  = (C h_prev) ∘ exp(dA_cs)
  state:  h     <- h · exp(dA_sum) + Σ decay·dt·B⊗x

with fp32 accumulation. Q defaults to 128 and P/N are 64/128 — the whole
working set (3·Q·N + Q·P + P·N fp32) sits comfortably in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum_exp(dA):
    """exp(segment-sum) lower-triangular [Q,Q] from dA [Q] (fp32)."""
    Q = dA.shape[0]
    cs = jnp.cumsum(dA)
    out = cs[:, None] - cs[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    return jnp.where(mask, jnp.exp(out), 0.0)


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    B = b_ref[0, :, 0].astype(jnp.float32)           # [Q, N]
    C = c_ref[0, :, 0].astype(jnp.float32)           # [Q, N]
    A = a_ref[0]                                     # scalar (this head)

    dA = dt * A                                      # [Q]
    dA_cs = jnp.cumsum(dA)                           # [Q]
    # ---- intra-chunk quadratic form
    L = _segsum_exp(dA)                              # [Q, Q]
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # [Q, Q]
    scores = CB * L * dt[None, :]
    y = jax.lax.dot(scores, x)                       # [Q, P]
    # ---- contribution of the carried state
    h_prev = h_ref[...]                              # [P, N]
    y += jax.lax.dot_general(C * jnp.exp(dA_cs)[:, None], h_prev,
                             (((1,), (1,)), ((), ())))
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    # ---- state update
    decay = jnp.exp(dA_cs[-1] - dA_cs)               # [Q]
    wx = x * (decay * dt)[:, None]                   # [Q, P]
    h_ref[...] = h_prev * jnp.exp(dA_cs[-1]) + \
        jax.lax.dot_general(wx, B, (((0,), (0,)), ((), ())))  # [P, N]

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, A, B, C, chunk: int = 128, initial_state=None, *,
             interpret: bool = False):
    """x: [b,L,H,P]; dt: [b,L,H]; A: [H]; B,C: [b,L,G,N] ->
    (y [b,L,H,P], final_state [b,H,P,N]).

    ``initial_state`` must be None (the kernel owns the scan from zero) —
    the serving path streams prefill through the kernel in one call.
    """
    assert initial_state is None, "kernel path starts from h=0"
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, L)
    assert L % Q == 0, "pad L to a chunk multiple"
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)

    def xh_index(bh, ci):
        return (bh // H, ci, bh % H, 0)

    def bc_index(bh, ci):
        return (bh // H, ci, (bh % H) // rep, 0)

    y, h_fin = pl.pallas_call(
        kernel,
        grid=(b * H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ci: ((bh % H),)),      # A
            pl.BlockSpec((1, Q, 1, P), xh_index),                # x
            pl.BlockSpec((1, Q, 1), lambda bh, ci:
                         (bh // H, ci, bh % H)),                 # dt
            pl.BlockSpec((1, Q, 1, N), bc_index),                # B
            pl.BlockSpec((1, Q, 1, N), bc_index),                # C
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), xh_index),
            pl.BlockSpec((1, 1, P, N), lambda bh, ci:
                         (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt.astype(jnp.float32), B, C)
    return y, h_fin
