"""Paged decode attention (Pallas): one new token against a block-table KV
pool — the vLLM paged-attention mechanism on TPU, with GQA head grouping.

Grid: (batch · kv_heads, max_blocks); the block axis is sequential and
carries online-softmax state for the R = H/KV query heads that share each
KV head (the same flash-decoding layout as kernels/decode_attention.py).
The block table and per-request lengths arrive via scalar prefetch (SMEM)
and drive the K/V BlockSpec index maps — each grid step DMAs exactly one
pool block [block_size, D] for one KV head into VMEM. The serving pool
(serving/paged_kv.py) stores blocks KV-HEAD-MAJOR ([n_blocks, KV, bs, D]),
so that tile is contiguous in HBM and the kernel consumes the pool
natively — no whole-pool transpose per call.

Early termination: the index map clamps the block coordinate to the last
*valid* block of the request (ceil(length / block_size) - 1). Past that
point consecutive grid steps resolve to the same pool block, which the
Pallas pipeline dedups into a no-op DMA, and ``pl.when`` skips the compute
— so a short request pays HBM traffic and MXU time proportional to its
true context length, not to ``max_blocks``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bs, n_blk, kv_heads, scale):
    bk = pl.program_id(0)
    blk = pl.program_id(1)
    b = bk // kv_heads

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(blk * bs < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale      # [R, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [bs, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [R, bs]
        pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.where(jnp.isinf(s), 0.0, jnp.exp(s - safe[:, None]))
        alpha = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(blk == n_blk - 1)
    def _fin():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """q: [B, H, D] with H a multiple of KV (GQA: query heads are grouped
    by their KV head inside the kernel, no caller-side repeat);
    k/v_pool: [n_blocks, KV, bs, D] (the serving pool's native KV-head-
    major layout — each (block, kv-head) tile [bs, D] is contiguous);
    block_tables: [B, max_blocks] int32 (entries < 0 treated as block 0
    and masked by length); lengths: [B] int32 (0 = inactive slot, output
    is zeros). Returns [B, H, D].
    """
    B, H, D = q.shape
    n_blocks, KV, bs, _ = k_pool.shape
    assert H % KV == 0, f"H={H} must be a multiple of KV={KV}"
    rep = H // KV
    max_blocks = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    # group query heads by their kv head: [B*KV, R, D]
    qg = q.reshape(B, KV, rep, D).reshape(B * KV, rep, D)
    kp, vp = k_pool, v_pool                           # native layout
    tbl = jnp.maximum(block_tables, 0).astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(_paged_kernel, bs=bs, n_blk=max_blocks,
                               kv_heads=KV, scale=scale)

    def kv_index(bk, blk, tbl_ref, len_ref):
        b = bk // KV
        kv = bk % KV
        # clamp to the last valid block: pruned steps re-reference the same
        # pool block (DMA elided) and pl.when skips their compute.
        last = jnp.maximum((len_ref[b] + bs - 1) // bs - 1, 0)
        return (tbl_ref[b, jnp.minimum(blk, last)], kv, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * KV, max_blocks),
        in_specs=[
            pl.BlockSpec((1, rep, D), lambda bk, blk, tbl, ln: (bk, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), kv_index),
            pl.BlockSpec((1, 1, bs, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, rep, D),
                               lambda bk, blk, tbl, ln: (bk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, D), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, rep, D), q.dtype),
        interpret=interpret,
    )(tbl, lengths, qg, kp, vp)
    return out.reshape(B, H, D)
