"""Paged decode attention (Pallas): one new token against a block-table KV
pool — the vLLM paged-attention mechanism on TPU.

Grid: (batch, max_blocks); the block axis is sequential and carries
online-softmax state. The block table arrives via scalar prefetch (SMEM) and
drives the K/V BlockSpec index maps — each grid step DMAs exactly one pool
block [block_size, KV·hd] into VMEM, so HBM traffic equals the request's
true context length rounded up to a block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bs, n_blk, scale):
    b = pl.program_id(0)
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    H, D = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale          # [H, D]
    k = k_ref[0].astype(jnp.float32).reshape(bs, H, D)
    v = v_ref[0].astype(jnp.float32).reshape(bs, H, D)
    length = len_ref[b]
    s = jnp.einsum("hd,shd->hs", q, k)                # [H, bs]
    pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.where(jnp.isinf(s), 0.0, jnp.exp(s - safe[:, None]))
    alpha = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - safe))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.einsum("hs,shd->hd", p, v)
    m_ref[...] = m_new

    @pl.when(blk == n_blk - 1)
    def _fin():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """q: [B, H, D] (KV-repeated by the caller: H == KV here for simplicity,
    or pass q already grouped); k/v_pool: [n_blocks, bs, KV, D];
    block_tables: [B, max_blocks] int32 (entries < 0 treated as block 0 and
    masked by length); lengths: [B] int32. Returns [B, H, D].

    GQA: repeat q's KV groups outside or pass KV == H pools; the per-request
    loop over blocks is the memory-access pattern that matters here.
    """
    B, H, D = q.shape
    n_blocks, bs, KV, _ = k_pool.shape
    assert H == KV, "caller repeats/groups heads (oracle parity)"
    max_blocks = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kp = k_pool.reshape(n_blocks, bs, KV * D)
    vp = v_pool.reshape(n_blocks, bs, KV * D)
    tbl = jnp.maximum(block_tables, 0).astype(jnp.int32)

    kernel = functools.partial(_paged_kernel, bs=bs, n_blk=max_blocks,
                               scale=scale)

    def kv_index(b, blk, tbl_ref, len_ref):
        return (tbl_ref[b, blk], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_blocks),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, blk, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, KV * D), kv_index),
            pl.BlockSpec((1, bs, KV * D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, blk, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(tbl, lengths.astype(jnp.int32), q, kp, vp)
    return out
