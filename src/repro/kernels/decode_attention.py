"""Single-token decode attention against a long KV cache (Pallas).

This is the memory-bound phase CoCoServe's migration targets (§3.3): per
step the kernel streams the KV cache once through VMEM. Flash-decoding
layout: grid (batch*kv_heads, k_blocks); the k-block axis is sequential and
carries online-softmax state for the R=H/KV query heads that share each KV
head. Per-request cache lengths come in as a scalar-prefetch operand (SMEM).

Block shapes: [blk_k, D] K/V tiles (blk_k=128, MXU-aligned), the R×D query
tile stays resident in VMEM across the whole stream.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
DEFAULT_BLK_K = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale, blk_k, n_k, kv_heads):
    bk = pl.program_id(0)
    ki = pl.program_id(1)
    b = bk // kv_heads

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [R, D]
    k = k_ref[0].astype(jnp.float32)                  # [blk_k, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [R, blk_k]
    length = len_ref[b]
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    safe_m = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.where(jnp.isinf(s), 0.0, jnp.exp(s - safe_m[:, None]))
    alpha = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - safe_m))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, blk_k: int = DEFAULT_BLK_K,
                     interpret: bool = False):
    """q: [B,H,D]; k,v: [B,KV,S,D]; lengths: [B] int32 -> [B,H,D]."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    rep = H // KV
    blk_k = min(blk_k, S)
    assert S % blk_k == 0, "pad cache length to a block multiple"
    n_k = S // blk_k
    scale = 1.0 / math.sqrt(D)
    # group query heads by their kv head: [B*KV, R, D]
    qg = q.reshape(B, KV, rep, D).reshape(B * KV, rep, D)

    kernel = functools.partial(_decode_kernel, scale=scale, blk_k=blk_k,
                               n_k=n_k, kv_heads=KV)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths [B]
            pl.BlockSpec((1, rep, D), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((1, blk_k, D), lambda bk, ki: (bk, ki, 0)),
            pl.BlockSpec((1, blk_k, D), lambda bk, ki: (bk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, D), lambda bk, ki: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, rep, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, D), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg,
      k.reshape(B * KV, S, D), v.reshape(B * KV, S, D))
    return out.reshape(B, H, D)
