"""Pure-jnp oracles for every kernel — independent implementations used by
the allclose sweeps in tests/test_kernels.py and as the scan-path fallback."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_flash_attention(q, k, v, *, causal: bool = True):
    """q: [B,H,Sq,D]; k,v: [B,KV,Sk,D] -> [B,H,Sq,D] (naive softmax)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    rep = H // KV
    kh = jnp.repeat(k, rep, axis=1)
    vh = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), Sk - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_decode_attention(q, k, v, lengths):
    """q: [B,H,D]; k,v: [B,KV,S,D]; lengths: [B] -> [B,H,D]."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    rep = H // KV
    kh = jnp.repeat(k, rep, axis=1)
    vh = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) / math.sqrt(D)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_ssd(x, dt, A, B, C, chunk=None, initial_state=None):
    """Token-by-token SSD recurrence (independent of the chunked form).

    x: [b,L,H,P]; dt: [b,L,H]; A: [H]; B,C: [b,L,G,N].
    Returns (y [b,L,H,P], final_state [b,H,P,N]).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B, rep, axis=2).astype(f32)
    Ch = jnp.repeat(C, rep, axis=2).astype(f32)
    h0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((b, H, P, N), f32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # [b,H,P], [b,H], [b,H,N], [b,H,N]
        dA = jnp.exp(dtt * A[None, :])
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtt, Bt, xt.astype(f32))
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.astype(f32).transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)
    return y.astype(x.dtype), h_fin.astype(x.dtype)
