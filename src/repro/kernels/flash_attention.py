"""Blocked causal flash attention (Pallas, TPU-targeted).

Grid: (batch*q_heads, q_blocks, k_blocks) — the last axis is sequential on
TPU, carrying the online-softmax state (m, l, acc) in VMEM scratch. Block
shapes are MXU-aligned (q/k blocks of 128, head_dim padded to a multiple of
128 by the wrapper when needed). GQA is handled in the K/V index maps
(kv_head = q_head // rep), so K/V are never materialized per-q-head.

On this CPU container the kernel is validated with ``interpret=True``
against kernels/ref.py; on TPU the same code runs compiled. A TPU
deployment would additionally prune fully-masked (k > q) blocks from the
grid — here they are masked, which is correctness-equivalent.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, blk_q, blk_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [blk_q, D]
    k = k_ref[0].astype(jnp.float32)                  # [blk_k, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [blk_q, blk_k]
    if causal:
        q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(-inf - -inf))
    safe_m = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(jnp.where(jnp.isinf(s), NEG_INF, s) - safe_m[:, None])
    p = jnp.where(jnp.isinf(s), 0.0, p)
    alpha = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - safe_m))
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    blk_q: int = DEFAULT_BLK_Q, blk_k: int = DEFAULT_BLK_K,
                    interpret: bool = False):
    """q: [B, H, Sq, D]; k, v: [B, KV, Sk, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    rep = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, "pad seqs to block multiples"
    n_q, n_k = Sq // blk_q, Sk // blk_k
    scale = 1.0 / math.sqrt(D)

    qr = q.reshape(B * H, Sq, D)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               blk_q=blk_q, blk_k=blk_k, n_k=n_k)

    def kv_index(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * KV + h // rep, ki, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, blk_k, D), kv_index),
            pl.BlockSpec((1, blk_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            # VMEM accumulators (fp32) carried across the k-block axis
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k.reshape(B * KV, Sk, D), v.reshape(B * KV, Sk, D))
    return out.reshape(B, H, Sq, D)
