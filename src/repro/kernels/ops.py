"""Jit'd public wrappers for the Pallas kernels.

Each op picks ``interpret=True`` automatically off-TPU (this container), so
the same call sites run the compiled kernel on real hardware and the
Python-interpreted kernel body here. Wrappers also handle padding to block
multiples and layout conversion from the model's [B, S, H, D] convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(override):
    return (not on_tpu()) if override is None else override


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention_bshd(q, k, v, *, causal: bool = True, interpret=None):
    """Model-layout flash attention: q [B,Sq,H,D], k/v [B,Sk,KV,D]."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    blk_q = min(_fa.DEFAULT_BLK_Q, max(16, Sq))
    blk_k = min(_fa.DEFAULT_BLK_K, max(16, Sk))
    pad_q = (-Sq) % blk_q
    pad_k = (-Sk) % blk_k
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    # padded K rows must not attend: causal masking handles pad at the end
    # only when pad_k rows sit beyond every real q position — enforce by
    # masking via an explicit large-negative trick: zero K rows attend with
    # score 0; instead we rely on causal mask (pad_q rows discarded) and
    # for non-causal pad_k must be 0.
    if not causal:
        assert pad_k == 0, "non-causal path requires block-aligned Sk"
    out = _fa.flash_attention(qt, kt, vt, causal=causal, blk_q=blk_q,
                              blk_k=blk_k, interpret=_interpret(interpret))
    return out.transpose(0, 2, 1, 3)[:, :Sq]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_bshd(q, k, v, lengths, *, interpret=None):
    """Decode: q [B,1,H,D], cache k/v [B,S,KV,D], lengths [B] -> [B,1,H,D]."""
    B, _, H, D = q.shape
    S = k.shape[1]
    blk_k = min(_da.DEFAULT_BLK_K, S)
    pad_k = (-S) % blk_k
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    out = _da.decode_attention(q[:, 0], kt, vt, lengths, blk_k=blk_k,
                               interpret=_interpret(interpret))
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, chunk: int = 128, initial_state=None, *,
        interpret=None):
    """SSD scan with the model's signature (see models/ssm.ssd_chunked).

    Falls back to the jnp reference when an initial state is supplied
    (incremental prefill continuation) — the kernel owns zero-state scans.
    """
    if initial_state is not None:
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, A, B, C, chunk, initial_state)
    L = x.shape[1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h = _ssd.ssd_scan(x, dt, A, B, C, chunk=Q,
                         interpret=_interpret(interpret))
    return y[:, :L], h
