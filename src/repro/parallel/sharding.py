"""Logical-axis sharding rules — the SPMD embodiment of CoCoServe placement.

Model code annotates activations with *logical* axes (``lshard(x, "batch",
"seq", None)``); a rule table maps logical axes to mesh axes. The rule table
is what a CoCoServe ``PlacementPlan`` compiles down to: module-level
replication = batch-axis rules over a sub-group, migration = changing a
parameter's spec. Rules are installed with ``use_rules`` (context manager).

Per-arch fallbacks (DESIGN.md §5) are computed in :func:`rules_for`:
architectures whose head counts don't divide the model axis replicate
attention on ``model`` and shard only FFN/experts/vocab.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

_state = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[dict]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def spec_for(logical_axes, rules=None) -> P:
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P()
    return P(*[rules.get(a) if a else None for a in logical_axes])


def lshard(x, *logical_axes):
    """Annotate activation x with logical axes; no-op outside a rule context."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(logical_axes, rules))


# ---------------------------------------------------------------- rule tables
def _divides(n: int, axis_size: int) -> bool:
    return n > 0 and n % axis_size == 0


def rules_for(cfg: ModelConfig, mesh: Mesh, *, batch_axes=None) -> dict:
    """Logical->mesh rules for an arch on a mesh (the baseline placement)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_ax = "model" if "model" in sizes else None
    m = sizes.get("model", 1)
    if batch_axes is None:
        batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    d = sizes.get("data", 1)
    # experts shard over `data` (expert parallelism) so that d_ff can shard
    # over `model` at the same time — required for arctic-480b to fit HBM.
    E = cfg.padded_experts()
    experts_ax = ("data" if (E and E % d == 0 and "data" in sizes)
                  else (model_ax if E and E % m == 0 else None))
    rules = {
        "batch": batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
        "seq": None,
        "vocab": model_ax,
        "ffn": model_ax if _divides(cfg.d_ff, m) or cfg.d_ff == 0 else None,
        "experts": experts_ax,
        "d_model": None,
        "cache_seq": None,
    }
    # attention heads shard on `model` only when both H and KV divide (or KV
    # replicates cleanly): Megatron-style GQA needs H % m == 0.
    heads_ok = _divides(cfg.num_heads, m)
    rules["heads"] = model_ax if heads_ok else None
    rules["kv_heads"] = model_ax if (heads_ok and _divides(cfg.num_kv_heads, m)) else None
    # ssm heads
    rules["ssm_heads"] = model_ax if _divides(cfg.ssm_heads, m) else None
    # KV-cache fallback: when KV heads cannot shard on `model` (GQA with
    # kv % m != 0, MLA latent caches, arctic's 56 heads), shard the cache's
    # sequence dim there instead — required to fit HBM at 32k contexts.
    if cfg.attention_kind != "none" and rules["kv_heads"] is None:
        rules["cache_seq"] = model_ax
    return rules


def long_context_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    """batch=1 decode: shard the cache sequence dim over `data` instead."""
    rules = rules_for(cfg, mesh, batch_axes=())
    rules["batch"] = None
    rules["cache_seq"] = "data"
    return rules


# ----------------------------------------------------------- parameter specs
def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params, rules: dict, mesh: Mesh):
    """PartitionSpec tree for a params pytree (by name pattern).

    Leading stacked-layer dims are replicated; routed-expert weights shard
    their leading E dim on the `experts` rule; Mamba in/out projections stay
    replicated in the baseline (mixed channel layout - see DESIGN.md section 5
    and EXPERIMENTS.md Perf for the sharded variant).
    """
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    E = cfg.padded_experts()

    def ax_ok(logical, dim):
        mesh_ax = rules.get(logical)
        return mesh_ax if (mesh_ax and dim % m == 0) else None

    def spec(path, leaf):
        ps = _path_str(path)
        nd, shape = leaf.ndim, leaf.shape
        lead = lambda n: [None] * (nd - n)  # noqa: E731
        routed = ("shared" not in ps and "residual" not in ps
                  and cfg.num_experts > 0)
        if re.search(r"(w_gate|w_up)$", ps) and routed \
                and nd >= 3 and shape[-3] == E:
            return P(*(lead(3) + [rules.get("experts"), None,
                                  ax_ok("ffn", shape[-1])]))
        if re.search(r"w_down$", ps) and routed and nd >= 3 and shape[-3] == E:
            return P(*(lead(3) + [rules.get("experts"),
                                  ax_ok("ffn", shape[-2]), None]))
        if re.search(r"(w_gate|w_up)$", ps):
            return P(*(lead(2) + [None, ax_ok("ffn", shape[-1])]))
        if re.search(r"w_down$", ps):
            return P(*(lead(2) + [ax_ok("ffn", shape[-2]), None]))
        if re.search(r"embed$", ps):
            return P(ax_ok("vocab", shape[0]), None)
        if re.search(r"lm_head$", ps):
            return P(None, ax_ok("vocab", shape[1]))
        if re.search(r"(wq|wq_b)$", ps):
            return P(*(lead(3) + [None, ax_ok("heads", shape[-2]), None]))
        if re.search(r"(wk|wv|wk_b|wv_b)$", ps):
            return P(*(lead(3) + [None, ax_ok("kv_heads", shape[-2]), None]))
        if re.search(r"wo$", ps):
            return P(*(lead(2) + [ax_ok("heads", cfg.num_heads), None]))
        # --- Mamba2 per-part projections (head-aligned TP, DESIGN.md §5)
        if re.search(r"(w_z|w_x)$", ps):
            return P(*(lead(2) + [None, ax_ok("ssm_heads", shape[-1])]))
        if re.search(r"w_dt$", ps):
            return P(*(lead(2) + [None, ax_ok("ssm_heads", shape[-1])]))
        if re.search(r"conv_x_w$", ps):
            return P(*(lead(2) + [None, ax_ok("ssm_heads", shape[-1])]))
        if re.search(r"(conv_x_b|norm_scale)$", ps):
            return P(*(lead(1) + [ax_ok("ssm_heads", shape[-1])]))
        if re.search(r"out_proj$", ps):
            return P(*(lead(2) + [ax_ok("ssm_heads", shape[-2]), None]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, params)



def shard_params(params, specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)


# ------------------------------------------------------------- cache specs
def cache_specs(cache_shapes, rules: dict):
    """PartitionSpec tree for a serving cache (from jax.eval_shape of
    init_cache). Dispatch by leaf name + rank (hybrid block states carry an
    extra leading dim)."""
    b = rules.get("batch")
    seq = rules.get("cache_seq")
    kvh = rules.get("kv_heads")
    ssh = rules.get("ssm_heads")

    def spec(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        name = ps.rsplit("/", 1)[-1]
        cross = "cross" in ps
        if name in ("k", "v"):
            s = None if cross else seq
            return P(None, b, s, kvh, None)
        if name in ("c", "kr"):
            return P(None, b, seq, None)
        if name == "conv_x":
            return P(*([None] * (nd - 3) + [b, None, ssh]))
        if name in ("conv_B", "conv_C"):
            return P(*([None] * (nd - 3) + [b, None, None]))
        if name == "ssd":
            return P(*([None] * (nd - 4) + [b, ssh, None, None]))
        if name == "positions":
            return P(b, seq)
        if name == "length":
            return P(b)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def batch_specs(rules: dict, with_frames: bool = False):
    b = rules.get("batch")
    out = {"tokens": P(b, None), "labels": P(b, None), "mask": P(b, None)}
    if with_frames:
        out["frames"] = P(b, None, None)
    return out


def opt_state_specs(pspecs):
    """Optimizer-state specs mirror the parameter specs (step is scalar)."""
    return {"step": P(), "mu": pspecs, "nu": pspecs}
