"""Distributed flash-decoding over a sequence-sharded KV cache.

Beyond-paper optimization (EXPERIMENTS.md §Perf, pair C): when the KV cache
is sharded along its sequence dimension (the fallback for archs whose KV
heads don't divide the model axis — tinyllama kv=4, chameleon kv=8, arctic
kv=8, MLA latents), naive GSPMD lowering of ``softmax(qK^T)V`` all-reduces
full fp32 score rows per layer. The flash-decoding identity lets each shard
reduce its local slice to (m, l, o) — a per-head max, denominator and
weighted partial output — and combine with a single tiny ``psum``:

    o = Σ_shards exp(m_s - m*) · o_s / Σ_shards exp(m_s - m*) · l_s

Per-layer collective traffic drops from O(B·H·S_local) scores to
O(B·H·D) partials.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import NEG_INF


def _local_partial(q, k, v, qpos, kpos, window, softcap):
    """Shard-local attention partials.

    q: [B,1,KV,R,D]; k,v: [B,Sl,KV,D]; qpos [B,1]; kpos [B,Sl].
    Returns m [B,KV,R], l [B,KV,R], o [B,KV,R,Dv] (fp32).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkrd,bskd->bkrqs", q.astype(jnp.float32),
                   k.astype(jnp.float32))[:, :, :, 0] * scale  # [B,KV,R,Sl]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = qpos[:, 0]                                           # [B]
    mask = kpos <= qp[:, None]                                # [B,Sl]
    if window is not None:
        mask &= kpos > qp[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [B,KV,R]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkrs,bskd->bkrd", p, v.astype(jnp.float32))
    return m, l, o


def flash_decode(q, k_cache, v_cache, q_positions, k_positions, *,
                 mesh: Mesh, seq_axis: str = "model", batch_axis="data",
                 window=None, softcap=0.0):
    """Distributed flash-decoding.

    q: [B,1,H,D]; k/v_cache: [B,M,KV,Dk/Dv]; q_positions [B,1];
    k_positions [B,M]. Cache sharded: P(batch_axis, seq_axis, None, None).
    Returns [B,1,H,Dv] sharded P(batch_axis, None, None, None).
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    R = H // KV
    qg = q.reshape(B, 1, KV, R, D)

    def kernel(qg, k, v, qp, kp):
        m, l, o = _local_partial(qg, k, v, qp, kp, window, softcap)
        m_max = jax.lax.pmax(m, seq_axis)                     # [B,KV,R]
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_max, NEG_INF))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        l_sum = jax.lax.psum(l * corr, seq_axis)
        o_sum = jax.lax.psum(o * corr[..., None], seq_axis)
        denom = jnp.where(l_sum == 0.0, 1.0, l_sum)
        return (o_sum / denom[..., None]).astype(q.dtype)     # [B,KV,R,Dv]

    bspec = batch_axis
    out = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(bspec, None, None, None, None),
                  P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None),
                  P(bspec, None),
                  P(bspec, seq_axis)),
        out_specs=P(bspec, None, None, None),
        check_rep=False,
    )(qg, k_cache, v_cache, q_positions, k_positions)
    Dv = v_cache.shape[-1]
    return out.reshape(B, 1, H, Dv)


def _mla_local_partial(q_lat, q_rope, c, kr, qpos, kpos, window, scale):
    """Shard-local absorbed-MLA partials.

    q_lat: [B,H,r]; q_rope: [B,H,ro]; c: [B,Sl,r]; kr: [B,Sl,ro];
    qpos [B,1]; kpos [B,Sl]. Returns m,l [B,H], o_lat [B,H,r] (fp32).
    """
    f32 = jnp.float32
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(f32), c.astype(f32))
         + jnp.einsum("bhk,btk->bht", q_rope.astype(f32),
                      kr.astype(f32))) * scale
    qp = qpos[:, 0]
    mask = kpos <= qp[:, None]
    if window is not None:
        mask &= kpos > qp[:, None] - window
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", p, c.astype(f32))
    return m, l, o_lat


def flash_decode_mla(q_lat, q_rope, c_cache, kr_cache, q_positions,
                     k_positions, *, mesh: Mesh, seq_axis: str = "model",
                     batch_axis="data", window=None, qk_dim: int = 128):
    """Distributed flash-decoding in MLA's absorbed latent space.

    q_lat: [B,1,H,r]; q_rope: [B,1,H,ro]; c_cache: [B,M,r];
    kr_cache: [B,M,ro] — caches sharded P(batch, seq_axis, None).
    Returns o_lat [B,1,H,r] (multiply by W_uv outside).
    """
    B, _, H, r = q_lat.shape
    scale = 1.0 / math.sqrt(qk_dim)

    def kernel(ql, qr, c, kr, qp, kp):
        m, l, o = _mla_local_partial(ql[:, 0], qr[:, 0], c, kr, qp, kp,
                                     window, scale)
        m_max = jax.lax.pmax(m, seq_axis)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_max), 0.0)
        l_sum = jax.lax.psum(l * corr, seq_axis)
        o_sum = jax.lax.psum(o * corr[..., None], seq_axis)
        denom = jnp.where(l_sum == 0.0, 1.0, l_sum)
        return (o_sum / denom[..., None])[:, None].astype(q_lat.dtype)

    b = batch_axis
    return shard_map(
        kernel, mesh=mesh,
        in_specs=(P(b, None, None, None), P(b, None, None, None),
                  P(b, seq_axis, None), P(b, seq_axis, None),
                  P(b, None), P(b, seq_axis)),
        out_specs=P(b, None, None, None),
        check_rep=False,
    )(q_lat, q_rope, c_cache, kr_cache, q_positions, k_positions)
