"""Chaos soak: the failure-domain acceptance gate (DESIGN.md §9).

A 4-instance TCP pod (launch/pod.py inventory nodes, labels w0..w3)
serves a paced workload while a SEEDED ``serving/faults.FaultPlan``
injects the ISSUE-6 chaos mix against w1..w3 (w0 stays clean so the
plane always has an anchor):

* one ``kill``      — SIGKILL of a spawned node at a scheduled driver
                      step (real process death, real EOF);
* one ``half_open`` — a peer whose socket stays open but answers
                      nothing (deadline + heartbeat-probe territory);
* one ``partition`` — a transient op-window blackhole;
* sprinkled ``delay`` events on every faulted peer.

The soak passes only if the plane absorbs all of it:

* **zero dropped streams** — every request finishes exactly once;
* **token-identical**      — every stream (surviving, replayed, and
  post-respawn) matches a fault-free single-engine reference;
* **bounded detection**    — a hung peer is classified within 2x the
  RPC deadline (drain expiry + heartbeat probe), never a full-tick
  stall;
* **supervised respawn**   — the killed node is respawned by the
  orchestrator's supervisor and RE-ADMITTED: a fresh request pinned to
  the replacement completes correctly.

Faults ride the real wire (``transport.Connection.send``) and the plan
is seeded — the same seed faults the same frames, byte for byte.

Emits ``benchmarks/BENCH_chaos.json`` (keys: config / fault_plan /
events / streams / recovery / acceptance) and contributes rows to
``benchmarks/run.py``'s summary CSV. ``tests/test_chaos.py`` imports
``run_soak`` directly at smoke sizes — the tier-2 gate and the nightly
bench assert the same criteria on the same code path.

    PYTHONPATH=src:. python benchmarks/chaos_bench.py --smoke
"""
import json
import os
import time

import numpy as np

from benchmarks._smoke import ENV, is_smoke, pick

ARCH = "tinyllama-1.1b"
OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_chaos.json")

# rid namespaces, disjoint by construction
RID_WARMUP = 9000
RID_POST = 5000


def _requests(cfg, n, rid0=0, seed=0, prompt_len=24, max_new=10):
    from repro.serving.request import RequestSpec, SamplingParams
    rng = np.random.default_rng(seed)
    return [RequestSpec(rid=rid0 + i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=prompt_len)
                        .astype(np.int32),
                        max_tokens=max_new,
                        sampling=SamplingParams(temperature=0.7, top_k=8,
                                                seed=131 + rid0 + i))
            for i in range(n)]


def _reference(cfg, params, reqs, *, max_len, block_size):
    """Fault-free oracle: each request decoded alone on a pristine
    single engine — counter-based sampling keys make this the exact
    token sequence every chaos-side replay must reproduce."""
    from repro.serving.engine import Engine
    from repro.serving.request import RequestSpec
    out = {}
    for r in reqs:
        e = Engine(cfg, params, max_batch=1, max_len=max_len,
                   cache_kind="paged", block_size=block_size)
        e.submit(RequestSpec.from_request(r))
        out[r.rid] = e.run_until_done()[0].generated
    return out


def _label_index(orch, peer):
    """Instance index currently carrying EXACTLY ``peer`` as its label
    (a respawned replacement is suffixed ``~rN`` and never matches —
    static plans must not re-target it)."""
    for i, h in enumerate(orch.instances):
        if getattr(h, "peer_label", None) == peer:
            return i
    return None


def run_soak(cfg, params, *, n_workers=4, seed=7, n_requests=8,
             prompt_len=24, max_new=10, max_len=256, max_batch=2,
             block_size=16, n_blocks=32, min_deadline=1.0,
             kill_window=(2, 6), hang_window=(8, 16),
             partition_window=(8, 16), partition_span=64,
             respawn_wait_s=180.0, max_steps=3000) -> dict:
    """One full chaos soak; returns the BENCH_chaos report dict.

    Fully parameterized so tests/test_chaos.py drives it at smoke sizes
    — the tier-2 gate and the nightly bench share this exact path."""
    from repro.launch.pod import Node, launch_pod
    from repro.serving import faults as FLT
    from repro.serving import transport as TR
    from repro.serving.orchestrator import Orchestrator, RespawnPolicy

    nodes = [Node(host="127.0.0.1",
                  port=int(TR.free_tcp_endpoint().rsplit(":", 1)[1]))
             for _ in range(n_workers)]
    t0 = time.perf_counter()
    handles = launch_pod(cfg, params, nodes, max_batch=max_batch,
                         max_len=max_len, block_size=block_size,
                         n_blocks=n_blocks)
    bringup_s = time.perf_counter() - t0
    policy = RespawnPolicy(backoff_base=0.25, backoff_cap=2.0,
                           max_failures=5, window_s=120.0,
                           start_timeout=120.0)
    orch = Orchestrator(cfg, params, handles=handles,
                        telemetry_every=10_000, respawn_policy=policy)
    labels = [h.peer_label for h in orch.instances]
    events = []
    inj = None
    try:
        # -------------------------------------------------- warm-up
        # compile every shape the soak will touch (prefill bucket,
        # decode widths) on EVERY worker BEFORE faults and deadlines
        # exist — an XLA compile inside a deadline window would read as
        # a hang. Also calibrates the deadline off real warm step time.
        warm = _requests(cfg, n_workers, rid0=RID_WARMUP, seed=99,
                         prompt_len=prompt_len, max_new=4)
        for i, r in enumerate(warm):
            orch._home[r.rid] = i
            orch.instances[i].submit(r)
        orch.run_until_done()
        warm_steps = [s for h in orch.instances
                      for s in h.telemetry.step_seconds]
        warm_p95 = float(np.quantile(np.asarray(warm_steps), 0.95))
        rpc_deadline = max(min_deadline, 8.0 * warm_p95)

        # ------------------------------------- arm faults + deadline
        plan = FLT.FaultPlan.seeded(
            seed, labels[1:],           # w0 stays clean: the anchor
            kill_window=kill_window, hang_window=hang_window,
            partition_window=partition_window,
            partition_span=partition_span)
        killed_peer = next(e.peer for e in plan.events if e.kind == "kill")
        inj = FLT.install(plan)
        orch.set_rpc_deadline(rpc_deadline)

        # --------------------------------------------- the soak loop
        reqs = _requests(cfg, n_requests, rid0=0, seed=seed,
                         prompt_len=prompt_len, max_new=max_new)
        ref = _reference(cfg, params, reqs, max_len=max_len,
                         block_size=block_size)
        workload_rids = set(ref)
        done_rids = set()
        submitted = 0
        s = 0
        while len(done_rids) < n_requests and s < max_steps:
            while submitted < n_requests and submitted <= s:
                orch.submit(reqs[submitted])    # survives faulty peers
                submitted += 1
            for peer in inj.kills_due(s):
                idx = _label_index(orch, peer)
                if idx is not None:
                    events.append({"step": s, "event": "kill",
                                   "peer": peer})
                    orch.instances[idx].kill()
            done_rids.update(r.rid for r in orch.step()
                             if r.rid in workload_rids)
            s += 1
        soak_steps = s

        # ------------------------- wait out the supervisor's backoff
        # the killed node's replacement must come up and re-admit
        def respawned_base_labels():
            return {e["label"].split("~", 1)[0]
                    for e in orch.respawn_log
                    if e["event"] == "respawned" and e.get("label")}

        t_end = time.monotonic() + respawn_wait_s
        while (killed_peer not in respawned_base_labels()
               and time.monotonic() < t_end):
            orch.step()
            time.sleep(0.05)
        killed_respawned = killed_peer in respawned_base_labels()

        # ---------------------------- post-respawn re-admission proof
        post = _requests(cfg, 2, rid0=RID_POST, seed=seed + 1,
                         prompt_len=prompt_len, max_new=max_new)
        ref.update(_reference(cfg, params, post, max_len=max_len,
                              block_size=block_size))
        readmit_idx = None
        if killed_respawned:
            for e in orch.respawn_log:
                if (e["event"] == "respawned" and e.get("label")
                        and e["label"].split("~", 1)[0] == killed_peer):
                    readmit_idx = e["instance"]
        for k, r in enumerate(post):
            if k == 0 and readmit_idx is not None:
                # pin the first one to the replacement: finishing it
                # token-identically IS the re-admission evidence
                orch._home[r.rid] = readmit_idx
                orch.instances[readmit_idx].submit(r)
            else:
                orch.submit(r)
        orch.run_until_done()

        # ------------------------------------------------- verdicts
        scored = workload_rids | {r.rid for r in post}
        seen = {}
        for r in orch.finished:
            if r.rid in scored:
                seen.setdefault(r.rid, []).append(r.generated)
        duplicates = sorted(rid for rid, g in seen.items() if len(g) > 1)
        missing = sorted(scored - set(seen))
        mismatched = sorted(rid for rid, g in seen.items()
                            if g != [ref[rid]])
        hung_detects = [r["detect_s"] for r in orch.recoveries
                        if r["reason"] == "hung"]
        # drain expiry (<= 1x) + heartbeat probe (<= 1x) + a small
        # classification/replay slop that is wall work, not waiting
        detect_bound = 2.0 * rpc_deadline + 0.5
        stats = orch.stats()
        fault_stats = stats["faults"]
        acceptance = {
            "zero_dropped_streams": (not missing and not duplicates
                                     and orch.dropped == 0),
            "token_identical": not mismatched and not missing,
            "hung_detected_within_2x_deadline": (
                bool(hung_detects)
                and max(hung_detects) <= detect_bound),
            "killed_worker_respawned_and_readmitted": (
                killed_respawned and readmit_idx is not None
                and seen.get(post[0].rid) == [ref[post[0].rid]]),
        }
        report = {
            "smoke": is_smoke(),
            "config": {
                "arch": f"{ARCH} (reduced)", "workers": n_workers,
                "transport": "loopback TCP pod (spawned listening "
                             "servers)",
                "seed": seed, "n_requests": n_requests,
                "prompt_len": prompt_len, "max_new": max_new,
                "max_len": max_len, "block_size": block_size,
                "n_blocks": n_blocks, "rpc_deadline_s": rpc_deadline,
                "pod_bringup_s": bringup_s, "soak_steps": soak_steps},
            "fault_plan": plan.to_json(),
            "events": {
                "kills_executed": events,
                "injected": dict(inj.injected),
                "recoveries": list(orch.recoveries),
                "respawn_log": list(orch.respawn_log)},
            "streams": {
                "total": len(scored),
                "finished_once": len(seen) - len(duplicates),
                "missing_rids": missing,
                "duplicate_rids": duplicates,
                "mismatched_rids": mismatched,
                "dropped": orch.dropped,
                "token_identical": not mismatched and not missing},
            "recovery": {
                "rpc_deadline_s": rpc_deadline,
                "detect_bound_s": detect_bound,
                "hung_detect_s": hung_detects,
                "detect_p50_s": fault_stats["detect_p50_s"],
                "detect_p95_s": fault_stats["detect_p95_s"],
                "rpc_timeouts": fault_stats["rpc_timeouts"],
                "quarantines": fault_stats["quarantines"],
                "respawns": fault_stats["respawns"],
                "evictions": fault_stats["evictions"],
                "respawn_downtime_s": [
                    e["downtime_s"] for e in orch.respawn_log
                    if e["event"] == "respawned"]},
            "acceptance": acceptance,
        }
    finally:
        if inj is not None:
            FLT.uninstall()
        orch.close()
    return report


def run():
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config(ARCH).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    report = run_soak(
        cfg, params,
        n_workers=4,
        seed=int(os.environ.get("REPRO_CHAOS_SEED", "7")),
        n_requests=pick(16, 8),
        prompt_len=pick(48, 24),
        max_new=pick(24, 10),
        max_len=256, max_batch=2, block_size=16, n_blocks=32)

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    acc = report["acceptance"]
    for crit, ok in acc.items():
        assert ok, (f"chaos acceptance failed: {crit} "
                    f"(streams={report['streams']}, "
                    f"recovery={report['recovery']})")
    rec = report["recovery"]
    rows = [
        ("chaos_soak", rec["detect_p95_s"] * 1e6,
         f"seed={report['config']['seed']} "
         f"injected={sum(report['events']['injected'].values())} "
         f"quarantines={rec['quarantines']} respawns={rec['respawns']} "
         f"identical={report['streams']['token_identical']} "
         f"dropped={report['streams']['dropped']}"),
        ("chaos_respawn",
         (np.mean(rec["respawn_downtime_s"]) * 1e6
          if rec["respawn_downtime_s"] else 0.0),
         f"downtime_s={[round(d, 2) for d in rec['respawn_downtime_s']]} "
         f"readmitted={acc['killed_worker_respawned_and_readmitted']}"),
    ]
    return rows


def main(argv=None):
    import sys
    argv = sys.argv[1:] if argv is None else argv
    if "--smoke" in argv:
        os.environ[ENV] = "1"
        print("# smoke mode: toy sizes, numbers not comparable")
    run()


if __name__ == "__main__":
    main()
