"""Sanity gate over the emitted ``BENCH_*.json`` reports (the nightly
CI job runs this right after ``benchmarks/run.py --smoke``).

Three checks per file, all cheap and structural — this is a tripwire
against a bench silently emitting garbage (truncated write, renamed
key, forgotten smoke flag), not a performance regression gate:

* the file parses as a JSON object;
* it carries a boolean ``smoke`` flag, and when the run was smoke
  (``REPRO_BENCH_SMOKE=1``) that flag is True — smoke numbers must
  never masquerade as comparable measurements;
* the bench's required top-level keys are present (registry below; a
  BENCH file nobody registered still gets the parse + smoke checks).

Exit code 0 = all clean; 1 = violations (listed on stderr).

    REPRO_BENCH_SMOKE=1 python benchmarks/check_bench.py
"""

import glob
import json
import os
import sys

# required top-level keys per report — update when a bench's schema
# grows a section the acceptance criteria depend on
REQUIRED_KEYS = {
    "BENCH_chaos.json": [
        "config",
        "fault_plan",
        "events",
        "streams",
        "recovery",
        "acceptance",
    ],
    "BENCH_distributed.json": [
        "config",
        "migration_stall",
        "burst",
        "control_plane",
        "dropped_requests",
        "recoveries",
    ],
    "BENCH_ingress.json": [
        "config",
        "streaming",
        "routing",
        "elasticity",
        "token_identical",
        "dropped_requests",
    ],
    "BENCH_observe.json": [
        "config",
        "tracing_off",
        "tracing_on",
        "tokens_per_s_ratio",
        "overhead_ok",
        "traces_complete",
    ],
    "BENCH_module_scaling.json": [
        "config",
        "scale_up",
        "migration",
        "migrated_token_identical",
        "throughput_tokens_per_s",
    ],
    "BENCH_paged_engine.json": [
        "config",
        "dense",
        "paged",
        "paged_over_dense_speedup",
        "mixed_trace",
    ],
    "BENCH_slo.json": [
        "config",
        "fifo",
        "slo",
        "interactive_ttft_ratio",
        "throughput_ratio",
        "token_identical",
        "dropped_requests",
    ],
    "BENCH_prefix_sharing.json": [
        "config",
        "sharing_on",
        "sharing_off",
        "peak_block_ratio",
        "token_identical",
    ],
}


def check_report(path: str, smoke_run: bool) -> list:
    """All violations for one BENCH file (empty list = clean)."""
    name = os.path.basename(path)
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{name}: does not parse: {e}"]
    if not isinstance(report, dict):
        return [f"{name}: top level is {type(report).__name__}, not an object"]
    problems = []
    if "smoke" not in report:
        problems.append(f"{name}: missing the 'smoke' flag")
    elif not isinstance(report["smoke"], bool):
        problems.append(f"{name}: 'smoke' is {report['smoke']!r}, not a bool")
    elif smoke_run and not report["smoke"]:
        problems.append(
            f"{name}: emitted by a smoke run but flagged smoke=false - "
            "toy numbers would look comparable"
        )
    for key in REQUIRED_KEYS.get(name, []):
        if key not in report:
            problems.append(f"{name}: missing required key {key!r}")
    return problems


def main(argv=None) -> int:
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if argv:  # explicit file list (tests)
        paths = argv
    if not paths:
        print("check_bench: no BENCH_*.json found - did run.py run?", file=sys.stderr)
        return 1
    smoke_run = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    problems = []
    for path in paths:
        problems.extend(check_report(path, smoke_run))
    for p in problems:
        print(f"check_bench: {p}", file=sys.stderr)
    clean = len(paths) - len({p.split(":")[0] for p in problems})
    print(
        f"check_bench: {len(paths)} report(s), {len(problems)} problem(s), "
        f"{clean} clean"
    )
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
