"""SLO-aware scheduling through the real HTTP front door.

One mixed trace — a wave of prefill-heavy BATCH completions followed
immediately by short INTERACTIVE ones, all on raw sockets against
serving/ingress.py — served twice by the same single-instance pod:

* **fifo** — the default ``"budget"`` ``TokenBudgetScheduler``: strict
  arrival order, so every interactive request prefills behind the whole
  batch backlog;
* **slo** — the ``"slo"`` ``SloScheduler``: the per-step token budget
  is split by class in strict priority order, so interactive admissions
  jump the batch continuations the moment a slot is free.

Judged numbers (the PR-10 acceptance gates):

* interactive p95 TTFT (ENGINE-clock steps, from the per-class
  telemetry windows the /metrics histograms read) at most 0.6x the
  fifo baseline;
* throughput at least 0.9x the baseline, measured as tokens per
  ENGINE STEP (same trace, token-identical output, so the ratio is
  pure packing efficiency — class-aware packing is work-conserving,
  it reorders work instead of shedding it). Wall tok/s is reported
  raw but not gated: this container's wall clock swings >10% between
  arms, while the engine-step count is load-independent;
* every stream token-identical across the two arms (counter-based
  sampling keys travel with the request, so scheduling order can never
  change tokens);
* zero dropped requests.

The budget governor is OFF for both arms (fixed equal budgets) so the
comparison isolates the scheduling policy. Emits
``benchmarks/BENCH_slo.json``.
"""
import json
import os
import socket
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

BLOCK_SIZE = 8
TOKEN_BUDGET = 16                  # per-step packing budget (contended)
MAX_BATCH = 6                      # slots are NOT the bottleneck
N_BATCH = pick(8, 4)               # prefill-heavy background wave
BATCH_PROMPT = pick(96, 64)        # 6 (4) budget-sized chunks each
BATCH_NEW = pick(48, 16)           # decode volume drowns fixed costs
N_INT = pick(3, 2)                 # the latency-sensitive foreground
INT_PROMPT = 8
INT_NEW = pick(16, 8)
ENG_KW = dict(max_batch=MAX_BATCH, max_len=pick(192, 128),
              block_size=BLOCK_SIZE, token_budget=TOKEN_BUDGET)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_slo.json")

TTFT_GATE = 0.6                    # slo p95 TTFT <= 0.6x fifo
TPS_GATE = 0.9                     # slo tok/s >= 0.9x fifo


def _tps_gate():
    """The judged 0.9x gate applies at FULL size, where decode volume
    amortizes the extra partial-chunk steps class-aware packing takes;
    smoke only sanity-checks that reordering didn't destroy packing."""
    return TPS_GATE * 0.75 if is_smoke() else TPS_GATE


def _bodies():
    """The mixed trace, deterministic across arms: batch first, then
    interactive. Seeded sampling makes token identity a real claim."""
    rng = np.random.default_rng(7)
    trace = []
    for i in range(N_BATCH):
        trace.append({"prompt": rng.integers(2, 1000, size=BATCH_PROMPT)
                      .astype(int).tolist(),
                      "max_tokens": BATCH_NEW, "slo_class": "batch",
                      "temperature": 0.7, "top_k": 8, "seed": 100 + i})
    for i in range(N_INT):
        trace.append({"prompt": rng.integers(2, 1000, size=INT_PROMPT)
                      .astype(int).tolist(),
                      "max_tokens": INT_NEW, "slo_class": "interactive",
                      "deadline_ms": 500, "temperature": 0.7, "top_k": 8,
                      "seed": 200 + i})
    return trace


def _send(port, body):
    s = socket.create_connection(("127.0.0.1", port), timeout=120)
    payload = json.dumps(body).encode()
    s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
              b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
    return s


def _read(s):
    data = b""
    while chunk := s.recv(65536):
        data += chunk
    s.close()
    head, _, body = data.partition(b"\r\n\r\n")
    assert b"200 OK" in head.split(b"\r\n", 1)[0], head[:200]
    return json.loads(body)


def _arm(cfg, params, scheduler):
    from repro.serving.ingress import Ingress
    from repro.serving.orchestrator import Orchestrator
    orch = Orchestrator(cfg, params, n_instances=1, telemetry_every=2,
                        scheduler=scheduler, **ENG_KW)
    ing = Ingress(orch, govern_budget=False).start()
    try:
        trace = _bodies()
        tel = orch.telemetry[0]
        # a FULL unmeasured warmup, then three measured passes: the
        # packing mix (and so the set of jit shapes) depends on where
        # the interactive wave lands in engine time, which itself moves
        # as compiles disappear — by the measured passes the engine is
        # jit-clean no matter which arm ran first in the process. Wall
        # is best-of-3 (tiny smoke runs are scheduler-noise-dominated);
        # the TTFT windows come from the LAST pass only.
        walls, steps = [], []
        for measured in (False, True, True, True):
            t0 = time.perf_counter()
            c0 = orch.engines[0].clock
            socks = [_send(ing.port, b) for b in trace if
                     b["slo_class"] == "batch"]
            time.sleep(0.005)      # batch wave parsed + queued first
            socks += [_send(ing.port, b) for b in trace if
                      b["slo_class"] == "interactive"]
            outs = [_read(s) for s in socks]
            if measured:
                walls.append(time.perf_counter() - t0)
                steps.append(orch.engines[0].clock - c0)
            if measured != (len(walls) == 3):
                # every pass except the LAST is dropped from the
                # per-class windows the gates read (the engine is idle
                # between passes)
                tel.class_ttfts.clear()
                tel.class_itls.clear()
        wall = min(walls)
        n_steps = min(steps)
        tokens = sum(len(o["tokens"]) for o in outs)
        return {"scheduler": scheduler,
                "requests": len(outs),
                "tokens": tokens,
                "wall_s": wall,
                "tokens_per_s": tokens / wall,
                "engine_steps": n_steps,
                "tokens_per_step": tokens / n_steps,
                "interactive_ttft_p95_steps":
                    tel.class_ttft_quantile("interactive", 0.95),
                "batch_ttft_p95_steps":
                    tel.class_ttft_quantile("batch", 0.95),
                "interactive_itl_p95_steps":
                    tel.class_itl_quantile("interactive", 0.95),
                "streams": {str(i): o["tokens"]
                            for i, o in enumerate(outs)},
                "dropped": orch.stats()["dropped"],
                "rejected_429": ing.counters.rejected_429}
    finally:
        ing.close()
        orch.close()


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    fifo = _arm(cfg, params, "budget")
    slo = _arm(cfg, params, "slo")

    ttft_ratio = (slo["interactive_ttft_p95_steps"]
                  / max(fifo["interactive_ttft_p95_steps"], 1e-9))
    tps_ratio = (slo["tokens_per_step"]
                 / max(fifo["tokens_per_step"], 1e-9))
    wall_ratio = slo["tokens_per_s"] / max(fifo["tokens_per_s"], 1e-9)
    identical = fifo["streams"] == slo["streams"]
    dropped = fifo["dropped"] + slo["dropped"]
    report = {
        "smoke": is_smoke(),
        "config": {"arch": "tinyllama-1.1b (reduced)",
                   "token_budget": TOKEN_BUDGET, "max_batch": MAX_BATCH,
                   "block_size": BLOCK_SIZE,
                   "n_batch": N_BATCH, "batch_prompt": BATCH_PROMPT,
                   "batch_new": BATCH_NEW, "n_interactive": N_INT,
                   "interactive_prompt": INT_PROMPT,
                   "interactive_new": INT_NEW},
        "fifo": fifo,
        "slo": slo,
        "interactive_ttft_ratio": ttft_ratio,
        "meets_ttft_gate": ttft_ratio <= TTFT_GATE,
        "throughput_ratio": tps_ratio,
        "meets_throughput_gate": tps_ratio >= _tps_gate(),
        "wall_throughput_ratio": wall_ratio,
        "token_identical": identical,
        "dropped_requests": dropped,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[slo_bench] interactive p95 TTFT: "
          f"{fifo['interactive_ttft_p95_steps']:.1f} steps (fifo) -> "
          f"{slo['interactive_ttft_p95_steps']:.1f} steps (slo) = "
          f"{ttft_ratio:.2f}x (gate <= {TTFT_GATE}x: "
          f"{'PASS' if report['meets_ttft_gate'] else 'FAIL'})")
    print(f"[slo_bench] throughput: {fifo['tokens_per_step']:.2f} -> "
          f"{slo['tokens_per_step']:.2f} tok/engine-step = "
          f"{tps_ratio:.2f}x (gate >= {_tps_gate():.3g}x"
          f"{', smoke-relaxed' if is_smoke() else ''}: "
          f"{'PASS' if report['meets_throughput_gate'] else 'FAIL'}); "
          f"wall {fifo['tokens_per_s']:.0f} -> {slo['tokens_per_s']:.0f} "
          f"tok/s ({wall_ratio:.2f}x, not gated); "
          f"token_identical={identical}, dropped={dropped}")
    return [("slo_interactive_ttft", slo["wall_s"] * 1e6,
             f"{ttft_ratio:.2f}x"),
            ("slo_throughput", fifo["wall_s"] * 1e6,
             f"{tps_ratio:.2f}x")]


if __name__ == "__main__":
    run()
