"""Paper Table 1: per-module memory & compute analysis (LLaMA-13B,
batch=1, seq=256, bf16) — analytic model vs the paper's published numbers."""
import time

from repro.configs import get_config
from repro.core.cluster import module_profile

PAPER = {  # module -> (MB, GFLOPs)
    "self_attn.q/k/v/o_proj": (50, 13.42),
    "self_attn": (200, 55.02),
    "ffn.gate/up/down_proj": (135, 36.24),
    "decoder_layer": (605, 127.5),
}


def run():
    cfg = get_config("llama2-13b")
    t0 = time.perf_counter()
    prof = module_profile(cfg, batch=1, seq=256)
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    print("# Table 1 reproduction (LLaMA-13B, bs=1, seq=256, bf16)")
    print(f"{'module':28s} {'ours MB':>9s} {'paper MB':>9s} "
          f"{'ours GF':>9s} {'paper GF':>9s}")
    for mod, (pm, pf) in PAPER.items():
        mem = prof[mod]["mem"] / 1e6
        fl = (prof[mod]["flops"] + prof[mod].get("extra_flops_scores", 0.0)) / 1e9
        print(f"{mod:28s} {mem:9.1f} {pm:9.1f} {fl:9.2f} {pf:9.2f}")
        rows.append((mod, mem, pm, fl, pf))
    kv = prof["kv_cache_per_token"]["mem"] / 1e3
    print(f"{'kv_cache/token':28s} {kv:9.1f} KB (dynamic, §3.3)")
    mem_err = max(abs(m - p) / p for _, m, p, _, _ in rows)
    return [("table1_modules", us, f"max_mem_err={mem_err:.2f}")]


if __name__ == "__main__":
    run()
