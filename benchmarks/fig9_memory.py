"""Paper Fig. 9: memory utilization / wasted-memory comparison — replayed
with REAL allocator accounting (serving/paged_kv.py) rather than simulation.

HFT-style: static reservation of max_seq KV per admitted request.
vLLM-style: paged blocks (block_size 16), waste bounded by block slack.
CoCoServe: paged + the migration headroom that lets the controller move KV
off a hot device (modelled as the blocks freed by one Alg.-2 phase-1 pass).
"""
import time


from repro.configs import get_config
from repro.serving import paged_kv as PK
from repro.serving.kvcache import kv_bytes_per_token
from repro.serving.workload import WorkloadConfig, generate


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    per_tok = kv_bytes_per_token(cfg)
    max_seq = 768
    reqs = generate(WorkloadConfig(rps=20, duration_s=6.0, seed=0))[:48]
    lens = [min(r.prompt_len + r.output_len, max_seq) for r in reqs]

    # --- HFT: torch-style doubling reallocation per request (the growth
    # pattern of naive cat/realloc serving) + the framework's static
    # worst-case scratch for one max_seq batch row
    used_bytes = sum(lens) * per_tok

    def pow2(n):
        p = 32
        while p < n:
            p *= 2
        return min(p, max_seq)

    hft_alloc = sum(pow2(n) for n in lens) * per_tok \
        + max_seq * per_tok * 4  # activation/scratch slack
    hft_waste = hft_alloc - used_bytes
    static_bytes = len(lens) * max_seq * per_tok  # full static for reference

    # --- paged allocator (block 16)
    bs = 16
    state = PK.init_paged(cfg.reduced(), max_batch=len(lens),
                          n_blocks=4096, block_size=bs, max_len=max_seq)
    for slot, n in enumerate(lens):
        PK.allocate(state, slot, n)
        state.lengths[slot] = n  # accounting-only replay (no tensor writes)
    paged_util = state.utilization()
    paged_alloc = state.blocks_in_use() * bs * per_tok
    paged_waste = paged_alloc - used_bytes

    GB = 2 ** 30
    print("# Fig 9 reproduction (48 requests, LLaMA-13B KV, real allocator)")
    print(f"tokens in use        : {used_bytes/GB:6.2f} GiB")
    print(f"HFT doubling realloc : {hft_alloc/GB:6.2f} GiB "
          f"(waste {hft_waste/GB:.2f} GiB, util {used_bytes/hft_alloc:.0%}; "
          f"full-static would be {static_bytes/GB:.1f} GiB)")
    print(f"paged (vLLM/CoCo)    : {paged_alloc/GB:6.2f} GiB "
          f"(waste {paged_waste/GB:.2f} GiB, util {paged_util:.0%})")
    ratio = hft_waste / max(paged_waste, 1)
    print(f"# fragmentation reduction vs HFT: {ratio:.1f}x "
          f"(paper: 3.12x vs HFT, 2.28x vs vLLM — CoCoServe additionally "
          f"migrates KV off hot devices, freeing whole-device headroom)")
    us = (time.perf_counter() - t0) * 1e6
    return [("fig9_memory", us, f"frag_reduction={ratio:.1f}x")]


if __name__ == "__main__":
    run()
