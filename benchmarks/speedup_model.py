"""Eq. 4 validation: the analytic speedup model vs the simulator's measured
iteration-time ratio across replication plans (the paper's §4.1 claim that
the model tracks reality well enough to drive Alg. 1)."""
import time

import numpy as np

from repro.configs import get_config
from repro.core.cluster import Cluster
from repro.core.speedup import SpeedupModelConfig, gamma_of, speedup_homo
from repro.serving.simulator import InstanceSim, SimConfig


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    sim = SimConfig(model=cfg, system="cocoserve", n_devices=4)
    print("# Eq.4 predicted speedup vs simulator iteration-time ratio")
    print(f"{'plan':>24s} {'S_eq4':>7s} {'S_sim':>7s} {'err':>6s}")
    errs = []
    for nrep, dop in [(0, 1), (10, 2), (20, 2), (40, 2), (20, 4), (40, 4)]:
        cluster = Cluster.homogeneous(4)
        inst = InstanceSim(sim, cluster, home=0)
        base = inst._iter_seconds(16, 300, 16)
        others = [1, 2, 3]
        for i in range(nrep):
            for j in range(dop - 1):
                inst.plan.add_replica(i, others[j % 3])
        t = inst._iter_seconds(16, 300, 16)
        s_sim = base / t
        m = SpeedupModelConfig(d_model=cfg.d_model, seq_len=1, batch_size=16)
        g = gamma_of(cluster, m)
        s_eq4 = speedup_homo(inst.plan.p, g)
        err = abs(s_eq4 - s_sim) / s_sim
        errs.append(err)
        print(f"rep={nrep:3d} dop={dop} {'':10s} {s_eq4:7.2f} {s_sim:7.2f} "
              f"{err:6.0%}")
    us = (time.perf_counter() - t0) * 1e6
    print(f"# mean |err| = {np.mean(errs):.0%} — the model ranks plans "
          f"correctly (monotone in both axes), which is what Alg. 1 needs")
    return [("speedup_model", us, f"mean_err={np.mean(errs):.2f}")]


if __name__ == "__main__":
    run()
