"""Prefix sharing on a shared-system-prompt workload: blocks + tok/s.

The experiment the copy-on-write refcount layer is judged on: N requests
that all open with the same system prompt (the dominant shape of the
system-prompt-heavy workloads CoCoServe targets). With sharing OFF every
request pays its own copy of the prompt's KV blocks and its own prefill;
with sharing ON the first admission publishes the prompt's full blocks
into the prefix cache and every later admission aliases them, prefilling
only its private suffix. We report peak pool blocks, prefill compute
skipped (prefix hit rate), admission-to-finish throughput, and the
copy-on-write fork count — plus the vacancy headroom the §5 controller
sees, since pool vacancy is its scale-up signal.

Emits ``benchmarks/BENCH_prefix_sharing.json`` and contributes rows to
``benchmarks/run.py``'s summary CSV.
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

SYS_PROMPT_LEN = 48     # the shared span (3 full blocks at BLOCK_SIZE=16)
USER_LEN = 8            # private per-request suffix
MAX_NEW = pick(16, 6)
MAX_BATCH = 4
N_REQUESTS = pick(12, 4)
BLOCK_SIZE = 16
POOL_BLOCKS = 48
MAX_LEN = 256

OUT_PATH = os.path.join(os.path.dirname(__file__),
                        "BENCH_prefix_sharing.json")


def _workload(cfg, n, seed=0):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(2, cfg.vocab_size,
                              size=SYS_PROMPT_LEN).astype(np.int32)
    reqs = []
    for i in range(n):
        user = rng.integers(2, cfg.vocab_size, size=USER_LEN).astype(np.int32)
        reqs.append(RequestSpec(rid=i,
                                prompt=np.concatenate([sys_prompt, user]),
                                max_tokens=MAX_NEW))
    return reqs


def _bench(cfg, params, share: bool):
    from repro.serving.engine import Engine

    def make():
        return Engine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                      dtype="float32", cache_kind="paged",
                      block_size=BLOCK_SIZE, n_blocks=POOL_BLOCKS,
                      prefix_sharing=share)

    warm = make()                      # compile prefill + step shapes
    for r in _workload(cfg, MAX_BATCH, seed=1):
        warm.submit(r)
    warm.run_until_done()

    eng = make()
    for r in _workload(cfg, N_REQUESTS):
        eng.submit(r)
    peak, done = 0, []
    t0 = time.perf_counter()
    while eng.queue or eng.active:
        done += eng.step() or []
        peak = max(peak, eng.pstate.blocks_in_use())
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    stats = eng.prefix_stats()
    return {"tokens": toks, "wall_s": wall, "tokens_per_s": toks / wall,
            "peak_blocks_in_use": peak,
            "peak_pool_fraction": peak / eng.pstate.n_blocks,
            "prefix_hit_rate": stats["hit_rate"],
            "blocks_saved_total": stats["blocks_saved_total"],
            "cow_forks": stats["cow_forks"]}, done


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    res, outs = {}, {}
    for share in (False, True):
        name = "sharing_on" if share else "sharing_off"
        res[name], done = _bench(cfg, params, share)
        outs[name] = {r.rid: r.generated for r in done}
    assert outs["sharing_on"] == outs["sharing_off"], \
        "prefix sharing changed token streams"

    saved = (res["sharing_off"]["peak_blocks_in_use"]
             - res["sharing_on"]["peak_blocks_in_use"])
    report = {
        "smoke": is_smoke(),
        "config": {"arch": "tinyllama-1.1b (reduced)",
                   "sys_prompt_len": SYS_PROMPT_LEN, "user_len": USER_LEN,
                   "max_new_tokens": MAX_NEW, "max_batch": MAX_BATCH,
                   "n_requests": N_REQUESTS, "block_size": BLOCK_SIZE,
                   "pool_blocks": POOL_BLOCKS},
        "sharing_off": res["sharing_off"],
        "sharing_on": res["sharing_on"],
        "token_identical": True,
        "peak_blocks_saved": saved,
        "peak_block_ratio": (res["sharing_on"]["peak_blocks_in_use"]
                             / max(res["sharing_off"]["peak_blocks_in_use"],
                                   1)),
        # vacancy headroom handed to the §5 controller's scale-up signal
        "vacancy_gain": (res["sharing_off"]["peak_pool_fraction"]
                         - res["sharing_on"]["peak_pool_fraction"]),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    rows = []
    for name in ("sharing_off", "sharing_on"):
        r = res[name]
        rows.append((f"prefix_{name}", 1e6 / r["tokens_per_s"],
                     f"tok/s={r['tokens_per_s']:.1f} "
                     f"peak_blocks={r['peak_blocks_in_use']} "
                     f"hit_rate={r['prefix_hit_rate']:.2f}"))
    rows.append(("prefix_sharing_saving", 0.0,
                 f"peak_blocks_saved={saved} "
                 f"ratio={report['peak_block_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    run()
