"""§Roofline: three-term roofline per (arch × shape × mesh) from the dry-run
artifacts (launch/dryrun.py JSON dumps).

  compute term    = FLOPs / (chips × 197 TFLOP/s bf16)
  memory term     = bytes / (chips × 819 GB/s HBM)
  collective term = per-device collective bytes / 50 GB/s ICI

FLOPs/bytes caveat (measured, see EXPERIMENTS §Roofline): XLA's
``cost_analysis`` counts a ``lax.scan`` body ONCE, so the raw numbers
under-count the layer stack. We therefore report BOTH the raw HLO numbers
and the analytic model numbers (architecture-exact, computed in
launch/dryrun.model_flops_analytic); terms use the analytic FLOPs and a
bytes model (params + cache + activation traffic). Collective bytes use the
while-body-scaled parse from the same dry-run.
"""
import glob
import json
import os
import time

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "dryrun_artifacts")


def bytes_model(rec) -> float:
    """Per-device HBM traffic per step: args (params+opt+cache) once, plus
    activation traffic ~= 2 x analytic flops / (2 * d_model) * 2B (each MAC
    row streams activations), folded into a simple 10% adder."""
    arg = rec.get("per_device_arg_bytes", 0)
    # decode/prefill write the cache once more; train writes grads+opt
    return arg * 2.1


def load(mesh="16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def terms(rec):
    chips = rec.get("n_devices", 256)
    ana = rec.get("analytic", {})
    flops = ana.get("model_flops_global", 0.0)
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = bytes_model(rec) / HBM_BW
    coll = rec.get("collectives", {}) or {}
    cbytes = sum(v.get("bytes_scaled", v.get("bytes", 0))
                 for v in coll.values() if isinstance(v, dict))
    t_coll = cbytes / ICI_BW
    terms_ = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms_, key=terms_.get)
    ratio = (ana.get("model_flops_6nd", 0.0) /
             max(rec.get("cost_analysis", {}).get("flops", 0.0) * chips, 1.0))
    return terms_, dom, cbytes, ratio


def run():
    t0 = time.perf_counter()
    recs = load("16x16")
    if not recs:
        print("# no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --both-meshes` first")
        return [("roofline", 0.0, "no_artifacts")]
    print("# Roofline (single pod 16x16 = 256 chips; seconds per step)")
    print(f"{'arch':18s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'collect':>10s} {'bottleneck':>10s} {'6ND/HLO':>8s}")
    doms = {}
    for rec in recs:
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                print(f"{rec['arch']:18s} {rec['shape']:12s} "
                      f"{'(skipped: ' + rec.get('reason', '')[:40] + ')'}")
            continue
        t, dom, cb, ratio = terms(rec)
        doms[dom] = doms.get(dom, 0) + 1
        print(f"{rec['arch']:18s} {rec['shape']:12s} {t['compute']:10.2e} "
              f"{t['memory']:10.2e} {t['collective']:10.2e} {dom:>10s} "
              f"{min(ratio, 999):8.1f}")
    print(f"# bottleneck histogram: {doms}")
    us = (time.perf_counter() - t0) * 1e6
    return [("roofline", us, f"bottlenecks={doms}")]


if __name__ == "__main__":
    run()
