"""Tracing-overhead benchmark: the observability plane's acceptance
gate.

Runs the SAME mixed trace as benchmarks/paged_engine_bench.py (short
decode streams with long prompts landing mid-stream — the workload
where per-step bookkeeping would hurt most) through the orchestrator
twice: tracing off (``tracer=None`` — every engine span hook is a None
check, the documented zero-cost path) and tracing on (a live Tracer,
every request traced end to end, every finished tree structurally
validated). The acceptance criterion is the throughput ratio
on/off >= 0.98: full tracing may cost at most 2%.

Emits ``benchmarks/BENCH_observe.json`` (registered in check_bench.py).
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick
from benchmarks.paged_engine_bench import (BLOCK_SIZE, MAX_BATCH, MAX_LEN,
                                           MIXED_LONG_PROMPT, MIXED_N_LONG,
                                           MIXED_SHORT_NEW, POOL_BLOCKS,
                                           PROMPT_LEN, TOKEN_BUDGET)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_observe.json")

REPS = pick(3, 1)        # median over reps: wall-time noise ~10% per run
MIN_RATIO = 0.98         # tracing may cost at most 2% throughput


def _requests(cfg, seed=0):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    shorts = [RequestSpec(rid=i,
                          prompt=rng.integers(2, cfg.vocab_size,
                                              size=PROMPT_LEN)
                          .astype(np.int32),
                          max_tokens=MIXED_SHORT_NEW)
              for i in range(MAX_BATCH - 1)]
    longs = [RequestSpec(rid=100 + i,
                         prompt=rng.integers(2, cfg.vocab_size,
                                             size=MIXED_LONG_PROMPT)
                         .astype(np.int32),
                         max_tokens=8)
             for i in range(MIXED_N_LONG)]
    return shorts, longs


def _run(cfg, params, traced, seed=7):
    from repro.serving import observe as OBS
    from repro.serving.orchestrator import Orchestrator
    tracer = OBS.Tracer() if traced else None
    orch = Orchestrator(cfg, params, n_instances=1, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, block_size=BLOCK_SIZE,
                        n_blocks=POOL_BLOCKS, token_budget=TOKEN_BUDGET,
                        telemetry_every=10_000, tracer=tracer)
    shorts, longs = _requests(cfg, seed=seed)
    t0 = time.perf_counter()
    for r in shorts:
        if tracer is not None:
            tracer.begin(r.rid, prompt_tokens=len(r.prompt))
        orch.submit(r)
    orch.step()                      # shorts prefill + start decoding
    for r in longs:                  # long prompts land mid-stream
        if tracer is not None:
            tracer.begin(r.rid, prompt_tokens=len(r.prompt))
        orch.submit(r)
    done = orch.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    complete = True
    if tracer is not None:
        # the overhead number only counts if the traces it paid for are
        # actually whole: every request closed one connected span tree
        complete = (len(tracer.finished) == len(shorts) + len(longs)
                    and tracer.dropped_spans == 0
                    and all(OBS.span_tree_ok(rec["spans"]) is None
                            for rec in tracer.finished))
    out = {r.rid: list(r.generated) for r in done}
    orch.close()
    return {"tokens": toks, "wall_s": wall,
            "tokens_per_s": toks / wall}, complete, out


def _bench(cfg, params):
    _run(cfg, params, traced=False)          # warm: compile shapes
    res, outs = {}, {}
    complete = True
    for arm, traced in (("tracing_off", False), ("tracing_on", True)):
        runs = []
        for _ in range(REPS):
            r, ok, outs[arm] = _run(cfg, params, traced)
            complete = complete and ok
            runs.append(r)
        res[arm] = {k: float(np.median([r[k] for r in runs]))
                    if isinstance(runs[0][k], float) else runs[0][k]
                    for k in runs[0]}
    ratio = (res["tracing_on"]["tokens_per_s"]
             / res["tracing_off"]["tokens_per_s"])
    return {
        "config": {"long_prompt": MIXED_LONG_PROMPT,
                   "n_long": MIXED_N_LONG,
                   "short_prompt": PROMPT_LEN,
                   "short_new_tokens": MIXED_SHORT_NEW,
                   "n_short": MAX_BATCH - 1,
                   "token_budget": TOKEN_BUDGET,
                   "reps": REPS,
                   "min_ratio": MIN_RATIO},
        "tracing_off": res["tracing_off"],
        "tracing_on": res["tracing_on"],
        "tokens_per_s_ratio": ratio,
        "overhead_ok": ratio >= MIN_RATIO,
        "traces_complete": complete,
        "token_identical": outs["tracing_off"] == outs["tracing_on"],
    }


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    report = _bench(cfg, params)
    report["smoke"] = is_smoke()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    off, on = report["tracing_off"], report["tracing_on"]
    return [
        ("observe_tracing_off", 0.0, f"{off['tokens_per_s']:.1f} tok/s"),
        ("observe_tracing_on", 0.0, f"{on['tokens_per_s']:.1f} tok/s"),
        ("observe_overhead", 0.0,
         f"ratio {report['tokens_per_s_ratio']:.3f} "
         f"(>= {MIN_RATIO}: {report['overhead_ok']}, "
         f"complete: {report['traces_complete']})"),
    ]


if __name__ == "__main__":
    run()
