"""Paged vs dense engine decode throughput + per-step host-sync census.

The experiment the paged rewrite is judged on: with a serving config whose
``max_len`` is far above the mean actual context (here >= 4x), the dense
engine still pays attention/HBM traffic proportional to ``max_len`` every
step, while the paged engine's cost tracks the longest *live* context
(block-table bucket). Both engines run the same fused decode+sample step
with exactly one device->host sync, counted here with the same wrapper the
tests assert against.

Interpret-mode friendly: the paged engine uses its jnp gather attention
path (identical memory-scaling behaviour, no Pallas dependency), so the
bench runs on CPU CI and on real accelerators unchanged.

Emits ``benchmarks/BENCH_paged_engine.json`` so later PRs can track the
trajectory, and contributes rows to ``benchmarks/run.py``'s summary CSV.
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

MAX_LEN = pick(2048, 512)   # dense cache capacity per slot
PROMPT_LEN = 24
MAX_NEW = pick(24, 8)       # mean context ~= 36  ->  MAX_LEN >= 4x mean
MAX_BATCH = 4
N_REQUESTS = pick(12, 4)
BLOCK_SIZE = 16
POOL_BLOCKS = 64        # paged pool sized to the workload, not worst case

OUT_PATH = os.path.join(os.path.dirname(__file__),
                        "BENCH_paged_engine.json")


def _make_engine(cfg, params, kind):
    from repro.serving.engine import Engine
    kw = {"cache_kind": kind}
    if kind == "paged":
        kw.update(block_size=BLOCK_SIZE, n_blocks=POOL_BLOCKS)
    return Engine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                  dtype="float32", **kw)


def _workload(cfg, n, seed=0):
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=PROMPT_LEN)
                    .astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i in range(n)]


def _bench_kind(cfg, params, kind):
    from repro.serving.instrument import count_host_syncs
    # warm: compile prefill + decode step shapes on a throwaway engine
    warm = _make_engine(cfg, params, kind)
    for r in _workload(cfg, MAX_BATCH, seed=1):
        warm.submit(r)
    warm.run_until_done()

    eng = _make_engine(cfg, params, kind)
    for r in _workload(cfg, N_REQUESTS):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)

    # steady-state sync census on a fresh, fully-occupied engine
    eng2 = _make_engine(cfg, params, kind)
    for r in _workload(cfg, MAX_BATCH, seed=2):
        eng2.submit(r)
    eng2.step()  # admission
    syncs = []
    for _ in range(8):
        with count_host_syncs() as c:
            eng2.step()
        syncs.append(c.n)
    if kind == "paged":
        kv_bytes = sum(x.size * x.dtype.itemsize
                       for x in (eng.pstate.k, eng.pstate.v))
    else:
        from repro.serving.kvcache import cache_bytes
        kv_bytes = cache_bytes(eng.cache["layers"])
    return {"tokens": toks, "wall_s": wall, "tokens_per_s": toks / wall,
            "syncs_per_step": float(np.mean(syncs)),
            "max_syncs_per_step": int(np.max(syncs)),
            "kv_cache_bytes": int(kv_bytes)}


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    res = {kind: _bench_kind(cfg, params, kind)
           for kind in ("dense", "paged")}
    speedup = res["paged"]["tokens_per_s"] / res["dense"]["tokens_per_s"]
    report = {
        "smoke": is_smoke(),
        "config": {"arch": "tinyllama-1.1b (reduced)", "max_len": MAX_LEN,
                   "prompt_len": PROMPT_LEN, "max_new_tokens": MAX_NEW,
                   "max_batch": MAX_BATCH, "n_requests": N_REQUESTS,
                   "block_size": BLOCK_SIZE, "pool_blocks": POOL_BLOCKS,
                   "mean_context": PROMPT_LEN + MAX_NEW // 2},
        "dense": res["dense"], "paged": res["paged"],
        "paged_over_dense_speedup": speedup,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    rows = []
    for kind in ("dense", "paged"):
        r = res[kind]
        rows.append((f"engine_decode_{kind}",
                     1e6 / r["tokens_per_s"],
                     f"tok/s={r['tokens_per_s']:.1f} "
                     f"syncs/step={r['syncs_per_step']:.1f}"))
    rows.append(("paged_vs_dense", 0.0, f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
