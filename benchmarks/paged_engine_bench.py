"""Paged vs dense engine decode throughput + per-step host-sync census.

The experiment the paged rewrite is judged on: with a serving config whose
``max_len`` is far above the mean actual context (here >= 4x), the dense
engine still pays attention/HBM traffic proportional to ``max_len`` every
step, while the paged engine's cost tracks the longest *live* context
(block-table bucket). Both engines run the same fused decode+sample step
with exactly one device->host sync, counted here with the same wrapper the
tests assert against.

Interpret-mode friendly: the paged engine uses its jnp gather attention
path (identical memory-scaling behaviour, no Pallas dependency), so the
bench runs on CPU CI and on real accelerators unchanged.

Emits ``benchmarks/BENCH_paged_engine.json`` so later PRs can track the
trajectory, and contributes rows to ``benchmarks/run.py``'s summary CSV.
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

MAX_LEN = pick(2048, 512)   # dense cache capacity per slot
PROMPT_LEN = 24
MAX_NEW = pick(24, 8)       # mean context ~= 36  ->  MAX_LEN >= 4x mean
MAX_BATCH = 4
N_REQUESTS = pick(12, 4)
BLOCK_SIZE = 16
POOL_BLOCKS = 64        # paged pool sized to the workload, not worst case

OUT_PATH = os.path.join(os.path.dirname(__file__),
                        "BENCH_paged_engine.json")


MIXED_LONG_PROMPT = pick(256, 96)   # the prompt that stalls phase decodes
MIXED_SHORT_NEW = pick(48, 16)      # short streams measured for ITL
MIXED_N_LONG = pick(3, 1)
# 80 leaves a pow2-exact 64-token chunk after charging the 3 decode
# slots — the chunk bucket pads nothing, so chunked compute ~= monolithic
TOKEN_BUDGET = pick(80, 48)


def _make_engine(cfg, params, kind, **extra):
    from repro.serving.engine import Engine
    kw = {"cache_kind": kind}
    if kind == "paged":
        kw.update(block_size=BLOCK_SIZE, n_blocks=POOL_BLOCKS)
    kw.update(extra)
    return Engine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                  dtype="float32", **kw)


def _workload(cfg, n, seed=0):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    return [RequestSpec(rid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=PROMPT_LEN)
                        .astype(np.int32),
                        max_tokens=MAX_NEW)
            for i in range(n)]


def _bench_kind(cfg, params, kind, **engine_kw):
    from repro.serving.instrument import count_host_syncs
    # warm: compile prefill + decode step shapes on a throwaway engine
    warm = _make_engine(cfg, params, kind, **engine_kw)
    for r in _workload(cfg, MAX_BATCH, seed=1):
        warm.submit(r)
    warm.run_until_done()

    eng = _make_engine(cfg, params, kind, **engine_kw)
    for r in _workload(cfg, N_REQUESTS):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)

    # steady-state sync census on a fresh, fully-occupied engine
    eng2 = _make_engine(cfg, params, kind, **engine_kw)
    for r in _workload(cfg, MAX_BATCH, seed=2):
        eng2.submit(r)
    eng2.step()  # admission
    syncs = []
    for _ in range(8):
        with count_host_syncs() as c:
            eng2.step()
        syncs.append(c.n)
    if kind == "paged":
        kv_bytes = sum(x.size * x.dtype.itemsize
                       for x in (eng.pstate.k, eng.pstate.v))
    else:
        from repro.serving.kvcache import cache_bytes
        kv_bytes = cache_bytes(eng.cache["layers"])
    return {"tokens": toks, "wall_s": wall, "tokens_per_s": toks / wall,
            "syncs_per_step": float(np.mean(syncs)),
            "max_syncs_per_step": int(np.max(syncs)),
            "kv_cache_bytes": int(kv_bytes)}


# ------------------------------------------------- mixed-trace experiment
# The workload continuous batching is judged on (ISSUE 7 acceptance):
# short decode streams in flight while long prompts arrive. The phase
# scheduler prefills a long prompt monolithically — every decode stream
# stalls for the whole prefill, spiking inter-token latency; the
# token-budget scheduler slices it into chunks that ride along with the
# decodes, bounding the spike to one chunk's step time.


def _mixed_requests(cfg, seed=0):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    shorts = [RequestSpec(rid=i,
                          prompt=rng.integers(2, cfg.vocab_size,
                                              size=PROMPT_LEN)
                          .astype(np.int32),
                          max_tokens=MIXED_SHORT_NEW)
              for i in range(MAX_BATCH - 1)]
    longs = [RequestSpec(rid=100 + i,
                         prompt=rng.integers(2, cfg.vocab_size,
                                             size=MIXED_LONG_PROMPT)
                         .astype(np.int32),
                         max_tokens=8)
             for i in range(MIXED_N_LONG)]
    return shorts, longs


def _run_mixed(cfg, params, scheduler, seed=0):
    """Drive the mixed trace under one scheduler; ITL samples are the
    wall gaps between consecutive tokens of the SHORT streams (the
    in-flight decodes a long prefill can stall)."""
    eng = _make_engine(cfg, params, "paged", scheduler=scheduler,
                       token_budget=TOKEN_BUDGET)
    short_specs, long_specs = _mixed_requests(cfg, seed=seed)
    shorts = [eng.submit(s) for s in short_specs]
    eng.step()                       # shorts prefill + start decoding
    longs = [eng.submit(s) for s in long_specs]   # land mid-stream
    itl, last_emit, last_len = [], {}, {r.rid: len(r.generated)
                                        for r in shorts}
    t0 = time.perf_counter()
    steps = 0
    while (eng.queue or eng.active or eng.prefilling) and steps < 10_000:
        eng.step()
        steps += 1
        now = time.perf_counter()
        for r in shorts:
            if len(r.generated) > last_len[r.rid]:
                if r.rid in last_emit:
                    itl.append(now - last_emit[r.rid])
                last_emit[r.rid] = now
                last_len[r.rid] = len(r.generated)
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in shorts + longs)
    out = {r.rid: list(r.generated) for r in shorts + longs}
    itl = np.asarray(itl)
    return {"tokens": toks, "wall_s": wall, "steps": steps,
            "tokens_per_s": toks / wall,
            "itl_p50_s": float(np.quantile(itl, 0.50)),
            "itl_p99_s": float(np.quantile(itl, 0.99))}, out


def _bench_mixed(cfg, params):
    reps = pick(3, 1)   # median over reps: wall-time noise ~10% per run
    res, outs = {}, {}
    for sched in ("phase", "token_budget"):
        _run_mixed(cfg, params, sched, seed=7)   # warm: compile shapes
        runs = []
        for _ in range(reps):
            r, outs[sched] = _run_mixed(cfg, params, sched, seed=7)
            runs.append(r)
        res[sched] = {k: (float(np.median([r[k] for r in runs]))
                          if isinstance(runs[0][k], float) else runs[0][k])
                      for k in runs[0]}
    cb, ph = res["token_budget"], res["phase"]
    return {
        "config": {"long_prompt": MIXED_LONG_PROMPT,
                   "n_long": MIXED_N_LONG,
                   "short_prompt": PROMPT_LEN,
                   "short_new_tokens": MIXED_SHORT_NEW,
                   "n_short": MAX_BATCH - 1,
                   "token_budget": TOKEN_BUDGET},
        "phase": ph, "token_budget": cb,
        # acceptance ratios: ITL <= 0.5x, tok/s >= 1.0x, identical tokens
        "itl_p99_ratio": cb["itl_p99_s"] / ph["itl_p99_s"],
        "tokens_per_s_ratio": cb["tokens_per_s"] / ph["tokens_per_s"],
        "token_identical": outs["token_budget"] == outs["phase"],
    }


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    # "paged" runs the default scheduler (token_budget); "paged_phase"
    # pins the old wave/step alternation — the uniform-workload ratio of
    # the two is the <= 5% regression criterion on the easy trace
    res = {kind: _bench_kind(cfg, params, kind)
           for kind in ("dense", "paged")}
    res["paged_phase"] = _bench_kind(cfg, params, "paged",
                                     scheduler="phase")
    speedup = res["paged"]["tokens_per_s"] / res["dense"]["tokens_per_s"]
    uniform_ratio = (res["paged"]["tokens_per_s"]
                     / res["paged_phase"]["tokens_per_s"])
    mixed = _bench_mixed(cfg, params)
    report = {
        "smoke": is_smoke(),
        "config": {"arch": "tinyllama-1.1b (reduced)", "max_len": MAX_LEN,
                   "prompt_len": PROMPT_LEN, "max_new_tokens": MAX_NEW,
                   "max_batch": MAX_BATCH, "n_requests": N_REQUESTS,
                   "block_size": BLOCK_SIZE, "pool_blocks": POOL_BLOCKS,
                   "mean_context": PROMPT_LEN + MAX_NEW // 2},
        "dense": res["dense"], "paged": res["paged"],
        "paged_phase": res["paged_phase"],
        "paged_over_dense_speedup": speedup,
        "uniform_tokens_per_s_ratio": uniform_ratio,
        "mixed_trace": mixed,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    rows = []
    for kind in ("dense", "paged"):
        r = res[kind]
        rows.append((f"engine_decode_{kind}",
                     1e6 / r["tokens_per_s"],
                     f"tok/s={r['tokens_per_s']:.1f} "
                     f"syncs/step={r['syncs_per_step']:.1f}"))
    rows.append(("paged_vs_dense", 0.0, f"speedup={speedup:.2f}x"))
    rows.append(("mixed_trace_cb_vs_phase",
                 mixed["token_budget"]["itl_p99_s"] * 1e6,
                 f"itl_p99_ratio={mixed['itl_p99_ratio']:.2f}x "
                 f"tok/s_ratio={mixed['tokens_per_s_ratio']:.2f}x "
                 f"identical={mixed['token_identical']} "
                 f"uniform_ratio={uniform_ratio:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
