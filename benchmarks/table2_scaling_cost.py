"""Paper Table 2: replication & migration cost vs number of layers.

Two parts: (1) the analytic cost model (bytes/link-bw + fixed setup) against
the paper's measured seconds/MB, (2) a REAL measured re-placement of a
reduced model's layers on this host (device_put round-trip) to show the
sub-second, weakly-scaling shape of the curve.
"""
import time

import jax

from repro.configs import get_config
from repro.core.cluster import layer_weight_bytes
from repro.core.migration import estimate_cost, tree_bytes
from repro.models import transformer as T

PAPER = {  # layers -> (repl_s, mem_MB)
    1: (0.2987, 1107),
    10: (0.3581, 6579),
    20: (0.3826, 12659),
    30: (0.4947, 18739),
    40: (0.8938, 24819),
}


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    per_layer = layer_weight_bytes(cfg)
    print("# Table 2 reproduction — model (A100/NVLink-class link 64 GB/s)")
    print(f"{'layers':>7s} {'ours s':>8s} {'paper s':>8s} "
          f"{'ours MB':>9s} {'paper MB':>9s}")
    max_rel = 0.0
    for n, (ps, pm) in PAPER.items():
        est = estimate_cost(n * per_layer, 64e9)
        mem = n * per_layer / 1e6
        # paper's memory includes the KV-cache slab replicated with layers
        print(f"{n:7d} {est:8.3f} {ps:8.3f} {mem:9.0f} {pm:9.0f}")
        max_rel = max(max_rel, abs(est - ps) / ps)
    print(f"# max relative time error vs paper: {max_rel:.0%} "
          f"(sub-second, weak scaling reproduced)")

    # real measured re-placement on this host (reduced model)
    rcfg = cfg.reduced()
    params = T.init_params(rcfg, jax.random.PRNGKey(0), "float32")
    t1 = time.perf_counter()
    moved = tree_bytes(params, r"layers/")
    new = jax.device_put(params, jax.devices()[0])
    jax.block_until_ready(new)
    meas = time.perf_counter() - t1
    print(f"# measured host re-placement: {moved/1e6:.1f} MB in {meas*1e3:.1f} ms")
    us = (time.perf_counter() - t0) * 1e6
    return [("table2_scaling_cost", us, f"max_rel_err={max_rel:.2f}")]


if __name__ == "__main__":
    run()
