"""Ablations beyond the paper's tables: which CoCoServe ingredient buys
what. Controller on/off, dop cap, continuity-sorting, and bursty traffic.
"""
import time


from repro.configs import get_config
from repro.core.cluster import Cluster
from repro.core.plan import PlacementPlan
from repro.core.scale_up import scale_up
from repro.core.speedup import speedup_homo
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    wl = WorkloadConfig(rps=30, duration_s=12.0, seed=0)

    print("# Ablation 1: controller on/off (cocoserve == vllm + controller)")
    on = simulate(SimConfig(model=cfg, system="cocoserve", n_devices=4), wl)
    off = simulate(SimConfig(model=cfg, system="cocoserve", n_devices=4,
                             enable_controller=False), wl)
    print(f"controller ON : lat={on.mean_latency:.2f}s thr={on.throughput_tokens:.0f}")
    print(f"controller OFF: lat={off.mean_latency:.2f}s thr={off.throughput_tokens:.0f}")
    gain = off.mean_latency / max(on.mean_latency, 1e-9)

    print("# Ablation 2: dop cap in Alg. 1 (modeled speedup, 4 devices)")
    for dop in (1, 2, 4):
        cluster = Cluster.homogeneous(4)
        plan = scale_up(PlacementPlan.initial(40), cluster, gamma=0.05,
                        replica_size=605e6, max_degree=dop)
        print(f"dop<={dop}: S_homo={speedup_homo(plan.p, 0.05):.2f} "
              f"breaks={plan.continuity_breaks()}")

    print("# Ablation 3: continuity-sorted vs naive candidate order (δ cost)")
    from repro.core.speedup import SpeedupModelConfig, t_of
    cluster = Cluster.homogeneous(2)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    cont = PlacementPlan.initial(40)
    frag = PlacementPlan.initial(40)
    for i in range(10):
        cont.add_replica(i, 1)
        frag.add_replica(i * 4, 1)
    print(f"contiguous: breaks={cont.continuity_breaks()} "
          f"T={t_of(cont, m, cluster):.3e}")
    print(f"fragmented: breaks={frag.continuity_breaks()} "
          f"T={t_of(frag, m, cluster):.3e} "
          f"(x{t_of(frag, m, cluster)/max(t_of(cont, m, cluster),1e-12):.1f})")

    print("# Ablation 4: bursty traffic (4x spike mid-run)")
    from repro.serving.workload import generate_trace
    import repro.serving.simulator as sim_mod
    orig = sim_mod.generate
    for system in ("vllm", "cocoserve"):
        sim_mod.generate = lambda w: generate_trace(w, "burst")
        try:
            r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                         WorkloadConfig(rps=15, duration_s=12.0, seed=0))
        finally:
            sim_mod.generate = orig
        print(f"burst {system:9s}: lat={r.mean_latency:.2f}s "
              f"p95={r.p95_latency:.2f}s slo={r.slo_attainment(12.0):.2f} "
              f"ctrl_actions={len(r.controller_log)}")
    us = (time.perf_counter() - t0) * 1e6
    return [("ablations", us, f"ctrl_lat_gain={gain:.2f}x")]


if __name__ == "__main__":
    run()
