"""Distributed serving plane: engine-server PROCESSES over the RPC
wire protocol, with OVERLAPPED vs STOP-THE-WORLD migration stall and
the batched control-plane poll.

The experiments the ISSUE-4/ISSUE-5 tentpoles are judged on:

* a 2-worker multi-process deployment (spawned engine servers, framed
  RPC, no shared memory) completes a burst with a live controller
  scale-up and an overlapped scale-down drain — zero dropped requests,
  token-identical migrated streams;
* migration stall: for the same long-context stream, how long is the
  victim out of decode rotation when migration is stop-the-world
  (pause -> ship EVERYTHING -> resume) vs two-phase overlapped (bulk
  snapshot staged while the source keeps decoding; pause ships only
  the dirty-set delta)? Acceptance: median overlapped stall < 25% of
  the stop-the-world baseline;
* control plane (ISSUE-5): an N=4 TCP pod (launch/pod.py inventory
  nodes, listening engine servers, orchestrator dials in) serves with
  ONE ``selectors``-multiplexed poll per tick — the
  ``round_trips_per_tick`` gauge — and the per-tick wall time tracks
  the slow end of the instances' step times, NOT their sum (a
  sequential drain pays >= the sum; the parallel floor on a
  core-starved host is max(max_step, sum/cores)).

``REPRO_BENCH_TRANSPORT=tcp`` lifts the stall/burst sections onto
loopback TCP rendezvous too (same frames; the control-plane section is
always TCP).

Emits ``benchmarks/BENCH_distributed.json`` and contributes rows to
``benchmarks/run.py``'s summary CSV.
"""
import json
import os
import statistics
import time

import numpy as np

from benchmarks._smoke import is_smoke, pick

ARCH = "tinyllama-1.1b"
TRANSPORT = os.environ.get("REPRO_BENCH_TRANSPORT", "unix")
POLL_WORKERS = 4                  # control-plane pod size (N=4 smoke scale)
POLL_TICKS = pick(16, 8)          # measured ticks (after warm-up)
POLL_WARMUP = 3
MAX_LEN = pick(1024, 256)
MAX_BATCH = 2
BLOCK_SIZE = 16
# long context, pool sized to the workload: the full payload (~38
# blocks, several MB) is what stop-the-world must ship inside its
# stall; the overlapped path's stall carries only the 1-block delta
N_BLOCKS = pick(48, 20)
PROMPT_LEN = pick(600, 96)
MAX_NEW = pick(24, 8)
STALL_TRIALS = pick(5, 2)
BURST_REQUESTS = pick(8, 4)
BURST_PROMPT = 12
BURST_MAX_NEW = 8

OUT_PATH = os.path.join(os.path.dirname(__file__),
                        "BENCH_distributed.json")


def _requests(cfg, n, rid0=0, seed=0, prompt_len=PROMPT_LEN,
              max_new=MAX_NEW):
    from repro.serving.request import RequestSpec, SamplingParams
    rng = np.random.default_rng(seed)
    return [RequestSpec(rid=rid0 + i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=prompt_len)
                        .astype(np.int32),
                        max_tokens=max_new,
                        sampling=SamplingParams(temperature=0.7, top_k=8,
                                                seed=31 + rid0 + i))
            for i in range(n)]


def _reference(cfg, params, reqs):
    from repro.serving.engine import Engine
    from repro.serving.request import RequestSpec
    out = {}
    for r in reqs:
        e = Engine(cfg, params, max_batch=1, max_len=MAX_LEN,
                   cache_kind="paged", block_size=BLOCK_SIZE)
        e.submit(RequestSpec.from_request(r))
        out[r.rid] = e.run_until_done()[0].generated
    return out


def _one_stall_trial(orch, cfg, rid, mode):
    """Decode a long-context stream on worker 0 for a few steps, migrate
    it to worker 1 in the given mode, and return its MigrationRecord."""
    req = _requests(orch.cfg, 1, rid0=rid, seed=rid)[0]
    orch._home[req.rid] = 0
    orch.instances[0].submit(req)
    for _ in range(3):
        orch.step()
    assert orch.instances[0].active_rids(), "trial stream not admitted"
    n_before = len(orch.migrations)
    if mode == "stw":
        recs = orch.migrate_requests(0, 1, max_requests=1)
    else:
        recs = orch.migrate_requests_overlapped(0, 1, max_requests=1,
                                                overlap_steps=1)
    assert len(recs) == 1 and recs[0].resumed, recs
    orch.run_until_done()
    assert len(orch.migrations) == n_before + 1
    return recs[0]


def _control_plane_section(cfg, params):
    """N=4 TCP pod driven through the batched poll: measure RPC waits
    per tick (must be ONE multiplexed poll) and per-tick wall time
    against the sum/max of the four servers' own step times."""
    from repro.launch.pod import Node, launch_pod
    from repro.serving import transport as TR
    from repro.serving.orchestrator import Orchestrator

    nodes = [Node(host="127.0.0.1",
                  port=int(TR.free_tcp_endpoint().rsplit(":", 1)[1]))
             for _ in range(POLL_WORKERS)]
    handles = launch_pod(cfg, params, nodes, max_batch=2,
                         max_len=pick(256, 128), block_size=16,
                         n_blocks=24)
    orch = Orchestrator(cfg, params, handles=handles,
                        telemetry_every=10_000)
    try:
        # keep every worker busy for the whole measured window
        reqs = _requests(cfg, 2 * POLL_WORKERS, rid0=3000, seed=13,
                         prompt_len=pick(64, 32),
                         max_new=POLL_WARMUP + POLL_TICKS + 8)
        for k, r in enumerate(reqs):
            i = k % POLL_WORKERS
            orch._home[r.rid] = i
            orch.instances[i].submit(r)
        for _ in range(POLL_WARMUP):    # compile all step shapes
            orch.step()
        tick_walls, step_sums, step_maxes = [], [], []
        for _ in range(POLL_TICKS):
            t0 = time.perf_counter()
            orch.step()
            tick_walls.append(time.perf_counter() - t0)
            # each step reply refreshed its telemetry mirror: the last
            # entry is THIS tick's server-side step wall time
            last = [h.telemetry.step_seconds[-1] for h in orch.instances]
            step_sums.append(sum(last))
            step_maxes.append(max(last))
        orch.run_until_done()
        cp = orch.control_plane_stats()
    finally:
        orch.close()
    wall = statistics.median(tick_walls)
    ssum = statistics.median(step_sums)
    smax = statistics.median(step_maxes)
    # a CPU-contended host cannot beat max(max_step, sum/cores) however
    # good the control plane is: N worker processes share the cores, so
    # "tracks max, not sum" is asserted against that parallel floor —
    # clearly under the sum a sequential drain would pay, OR within a
    # small factor of the floor itself (core-starved CI runners)
    cores = os.cpu_count() or 1
    floor = max(smax, ssum / cores)
    return {
        "workers": POLL_WORKERS,
        "transport": "tcp (pod inventory, listening servers)",
        "measured_ticks": POLL_TICKS,
        "host_cores": cores,
        "round_trips_per_tick": cp["rpc_polls_per_tick"],
        "step_rpcs_per_tick": cp["step_rpcs_per_tick"],
        "tick_wall_s_median": wall,
        "instance_step_sum_s_median": ssum,
        "instance_step_max_s_median": smax,
        "parallel_floor_s": floor,
        "tick_wall_over_sum": wall / ssum if ssum else float("inf"),
        "tick_wall_over_max": wall / smax if smax else float("inf"),
        "acceptance_one_poll_per_tick":
            bool(cp["rpc_polls_per_tick"] == 1.0),
        "acceptance_tracks_max_not_sum":
            bool(wall < max(0.9 * ssum, 1.8 * floor)),
    }


def run():
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving.orchestrator import Orchestrator

    cfg = get_config(ARCH).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    if TRANSPORT == "tcp":
        # spawned rendezvous proxies dial loopback TCP instead of
        # AF_UNIX — same frames, same suite
        os.environ["REPRO_RPC_TRANSPORT"] = "tcp"

    t_spawn = time.perf_counter()
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, block_size=BLOCK_SIZE,
                        n_blocks=N_BLOCKS, slo_latency=40.0,
                        telemetry_every=10_000, remote=True)
    spawn_s = time.perf_counter() - t_spawn
    try:
        # ---------------------------------------------------- warm-up
        # compile every shape both migration paths touch (prefill
        # bucket, decode widths, full-import/delta-import scatters) so
        # the stall comparison measures transfer, not XLA compiles
        for mode in ("stw", "overlapped"):
            _one_stall_trial(orch, cfg, {"stw": 900, "overlapped": 901}[mode],
                             mode)
        for h in orch.instances:        # park both pools empty again
            assert not h.active_rids()

        # ------------------------------------------- stall comparison
        stw, ovl = [], []
        for t in range(STALL_TRIALS):
            stw.append(_one_stall_trial(orch, cfg, 1000 + t, "stw"))
            ovl.append(_one_stall_trial(orch, cfg, 2000 + t, "overlapped"))
        stw_stall = statistics.median(r.stall_s for r in stw)
        ovl_stall = statistics.median(r.stall_s for r in ovl)
        ratio = ovl_stall / stw_stall if stw_stall > 0 else float("inf")

        # --------------------------- burst: live scale-up + drain down
        orch.telemetry_every = 2
        burst = _requests(cfg, BURST_REQUESTS, rid0=100, seed=7,
                          prompt_len=BURST_PROMPT, max_new=BURST_MAX_NEW)
        ref = _reference(cfg, params, burst)
        for r in burst:                 # skew onto worker 0: worker 1
            orch._home[r.rid] = 0       # keeps the vacancy Alg. 1 wants
            orch.instances[0].submit(r)
        for _ in range(10):
            orch.step()
        scaled_up = any(a.startswith("scale-up")
                        for a in orch.controller.log)
        drain_recs = []
        src = max((0, 1), key=lambda i: orch.instances[i].active_count())
        if orch.instances[src].active_rids():
            drain_recs = orch.drain_instance(src)
        orch.run_until_done()

        done = {r.rid: r.generated for r in orch.finished
                if r.rid in ref}
        identical = (done == ref)
        s = orch.stats()

        report = {
            "smoke": is_smoke(),
            "config": {"arch": f"{ARCH} (reduced)", "workers": 2,
                       "transport": f"{'loopback TCP' if TRANSPORT == 'tcp' else 'AF_UNIX'} "
                                    "framed RPC (spawned processes)",
                       "max_len": MAX_LEN, "block_size": BLOCK_SIZE,
                       "n_blocks": N_BLOCKS, "prompt_len": PROMPT_LEN,
                       "stall_trials": STALL_TRIALS},
            "spawn_seconds": spawn_s,
            "migration_stall": {
                "stop_the_world_s": {
                    "median": stw_stall,
                    "all": [r.stall_s for r in stw],
                    "bytes": [r.bytes_moved for r in stw],
                    "blocks": [r.n_blocks for r in stw]},
                "overlapped_s": {
                    "median": ovl_stall,
                    "all": [r.stall_s for r in ovl],
                    "delta_blocks": [r.delta_blocks for r in ovl],
                    "delta_bytes": [r.delta_bytes for r in ovl]},
                "overlapped_over_stw": ratio,
                "acceptance_lt_0.25": bool(ratio < 0.25)},
            "burst": {"scale_up_triggered": scaled_up,
                      "plan_p": s["plan_p"],
                      "drain_migrations": len(drain_recs),
                      "drain_modes": [r.mode for r in drain_recs],
                      "token_identical": identical},
            "dropped_requests": s["dropped"],
            "recoveries": s["recoveries"],
            "controller_log": s["controller_log"],
        }
    finally:
        orch.close()

    report["control_plane"] = _control_plane_section(cfg, params)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    assert report["dropped_requests"] == 0
    assert identical, "migrated/burst streams diverged from reference"
    rows = [
        ("distributed_stall_stw", stw_stall * 1e6,
         f"median of {STALL_TRIALS}, "
         f"{stw[0].n_blocks} blocks/{stw[0].bytes_moved / 1e6:.2f}MB"),
        ("distributed_stall_overlapped", ovl_stall * 1e6,
         f"ratio={ratio:.3f}"
         + ("" if is_smoke() else " (<0.25 required)")
         + f" delta={ovl[0].delta_blocks} blocks"),
        ("distributed_burst", 0.0,
         f"scale_up={scaled_up} drain={len(drain_recs)} "
         f"identical={identical} dropped={s['dropped']}"),
        ("distributed_control_plane",
         report["control_plane"]["tick_wall_s_median"] * 1e6,
         f"tcp N={POLL_WORKERS} "
         f"polls/tick={report['control_plane']['round_trips_per_tick']:.1f} "
         f"wall/sum={report['control_plane']['tick_wall_over_sum']:.2f} "
         f"wall/max={report['control_plane']['tick_wall_over_max']:.2f}"),
    ]
    cp = report["control_plane"]
    assert cp["acceptance_one_poll_per_tick"], cp
    assert cp["acceptance_tracks_max_not_sum"], (
        f"per-tick wall {cp['tick_wall_s_median']:.4f}s does not track "
        f"max: sum={cp['instance_step_sum_s_median']:.4f}s "
        f"max={cp['instance_step_max_s_median']:.4f}s")
    return rows


if __name__ == "__main__":
    run()
