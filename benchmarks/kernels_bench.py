"""Kernel micro-bench: interpret-mode wall time (correctness harness shape;
TPU wall-times come from the same call sites on real hardware) plus the
analytic VMEM working-set check for the chosen BlockSpecs."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

VMEM_BYTES = 128 * 1024 * 1024  # v5e ~128 MiB VMEM


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # flash attention: vmem = blk_q*D + 2*blk_k*D + acc ~ fp32
    blkq = blkk = 128
    for D in (64, 128, 256):
        ws = (blkq * D + 2 * blkk * D) * 2 + (blkq * D + 2 * blkq) * 4
        assert ws < VMEM_BYTES
        q = jax.random.normal(key, (1, 256, 4, D), jnp.float32)
        k = jax.random.normal(key, (1, 256, 2, D), jnp.float32)
        us = _time(lambda a, b, c: ops.flash_attention_bshd(a, b, c), q, k, k)
        rows.append((f"flash_attention_D{D}", us,
                     f"vmem_ws={ws/1024:.0f}KiB"))
    # decode attention
    q = jax.random.normal(key, (4, 1, 8, 128), jnp.float32)
    kc = jax.random.normal(key, (4, 1024, 2, 128), jnp.float32)
    lens = jnp.full((4,), 1000, jnp.int32)
    us = _time(lambda a, b, c, l: ops.decode_attention_bshd(a, b, c, l),
               q, kc, kc, lens)
    rows.append(("decode_attention_S1024", us, "flash_decoding_grid"))
    # ssd
    x = jax.random.normal(key, (1, 512, 4, 64), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    B = jax.random.normal(key, (1, 512, 1, 64)) * 0.3
    us = _time(lambda *a: ops.ssd(*a, chunk=128), x, dt, A, B, B)
    rows.append(("ssd_scan_L512", us, "chunked_dual_form"))
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
