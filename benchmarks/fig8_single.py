"""Paper Fig. 8: single-instance throughput & latency, CoCoServe vs HFT vs
vLLM, LLaMA2-13B and LLaMA2-70B, low (3-30) and high (31-50) RPS bands."""
import time

import numpy as np

from repro.configs import get_config
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def run():
    t0 = time.perf_counter()
    out_rows = []
    for model in ("llama2-13b", "llama2-70b"):
        cfg = get_config(model)
        n_dev = 4
        print(f"# Fig 8 ({model}, single instance, 4 devices)")
        print(f"{'rps':>4s} {'system':>10s} {'thr tok/s':>10s} "
              f"{'latency s':>10s} {'slo':>5s}")
        ratios = {"hft": ([], []), "vllm": ([], [])}
        for rps in (5, 10, 20, 30, 40, 50):
            res = {}
            for system in ("hft", "vllm", "cocoserve"):
                r = simulate(SimConfig(model=cfg, system=system,
                                       n_devices=n_dev),
                             WorkloadConfig(rps=rps, duration_s=10.0, seed=0))
                res[system] = r
                print(f"{rps:4d} {system:>10s} {r.throughput_tokens:10.0f} "
                      f"{r.mean_latency:10.2f} "
                      f"{r.slo_attainment(12.0):5.2f}")
            c = res["cocoserve"]
            for base in ("hft", "vllm"):
                b = res[base]
                # average ratios only inside the baseline's operating range
                # (>=50% completion) — the paper compares functioning
                # systems; beyond the HFT cliff the ratio is unbounded.
                total = len(b.completed) + b.dropped
                operating = total > 0 and len(b.completed) >= 0.5 * total
                if not operating:
                    continue
                if np.isfinite(b.mean_latency) and b.mean_latency > 0:
                    ratios[base][0].append(1 - c.mean_latency / b.mean_latency)
                if b.throughput_tokens > 0:
                    ratios[base][1].append(
                        c.throughput_tokens / b.throughput_tokens)
        for base, (lat, thr) in ratios.items():
            if not lat:
                continue
            print(f"# {model} vs {base} (operating range): "
                  f"latency -{np.mean(lat):.0%}, throughput x{np.mean(thr):.2f}")
            out_rows.append((f"fig8_{model}_vs_{base}", 0.0,
                             f"lat-{np.mean(lat):.0%}_thr{np.mean(thr):.2f}x"))
    us = (time.perf_counter() - t0) * 1e6
    out_rows[0] = (out_rows[0][0], us, out_rows[0][2])
    return out_rows


if __name__ == "__main__":
    run()
