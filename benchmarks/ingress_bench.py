"""The serving front door end to end: streaming latency through the real
HTTP ingress, prefix-affinity routing vs a round-robin control arm, and
pod elasticity (grow under burst, zero-drop shrink).

Three arms, the first two through REAL sockets against
serving/ingress.py:

* **streaming** — one chunked completion, timing first-event latency
  against total wall (the "tokens flush as the step loop emits them"
  claim, measured);
* **routing** — T tenants, each repeating requests that share a
  per-tenant prompt prefix, against a 2-instance pod twice: once under
  the default ``PrefixAffinityRouter``, once under the affinity-blind
  ``RoundRobinRouter``. The judged number is the POD-WIDE engine prefix
  hit rate ratio (ISSUE-8 acceptance: >= 1.5x);
* **elasticity** — the same queued burst served by a pod of 1, then by
  a pod grown to 2 via ``grow_pod``. The judged number is POD-WIDE
  CAPACITY: tokens delivered per scheduling tick, which must rise on
  grow (it doubles when routing spreads the burst evenly). Wall tok/s
  is reported alongside with the host core count — on a single-core CI
  host the two spawned workers time-slice one CPU, so wall throughput
  stays flat there by physics, while on parallel hardware it tracks
  the per-tick gain. Then a shrink mid-decode through the drain path
  (zero drops, token-identical vs the solo-engine oracle).

Emits ``benchmarks/BENCH_ingress.json`` and contributes rows to
``benchmarks/run.py``'s summary CSV.
"""
import dataclasses
import json
import os
import socket
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

BLOCK_SIZE = 8
PREFIX_BLOCKS = 4                  # shared per-tenant span (full blocks)
# ODD tenant count, and few rounds: with tenants == pod size the strict
# round-robin rotation would accidentally pin each tenant to one
# instance (parity alignment = perfect affinity for free), and once
# BOTH instances have paid a tenant's duplicate prefix residency, round
# robin's hit rate converges toward affinity's — the waste it pays is
# the duplicated prefill/residency, which shows in the early rounds
N_TENANTS = pick(5, 3)
REPEATS = 3                        # requests per tenant (first is cold)
MAX_NEW = pick(8, 4)
BURST = pick(8, 4)                 # elasticity-arm queued requests
ENG_KW = dict(max_batch=2, max_len=96, block_size=BLOCK_SIZE)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_ingress.json")


# ----------------------------------------------------- raw-socket client
def _http(port, method, path, body=None):
    s = socket.create_connection(("127.0.0.1", port), timeout=120)
    payload = b"" if body is None else json.dumps(body).encode()
    head = f"{method} {path} HTTP/1.1\r\nHost: b\r\n"
    if payload:
        head += f"Content-Length: {len(payload)}\r\n"
    s.sendall(head.encode() + b"\r\n" + payload)
    data = b""
    while chunk := s.recv(65536):
        data += chunk
    s.close()
    return data


def _tenant_prompt(tenant, i):
    """Shared PREFIX_BLOCKS-block prefix per tenant, distinct suffix."""
    prefix = [5 + tenant] * (PREFIX_BLOCKS * BLOCK_SIZE)
    return prefix + [800 + i, 700 + tenant]


# ------------------------------------------------------------- the arms
def _streaming_arm(cfg, params):
    from repro.serving.ingress import Ingress
    from repro.serving.orchestrator import Orchestrator
    orch = Orchestrator(cfg, params, n_instances=1, **ENG_KW)
    ing = Ingress(orch).start()
    try:
        _http(ing.port, "POST", "/v1/completions",     # warm compile
              body={"prompt": _tenant_prompt(0, 0), "max_tokens": 2})
        body = json.dumps({"prompt": _tenant_prompt(0, 1),
                           "max_tokens": MAX_NEW, "stream": True}).encode()
        s = socket.create_connection(("127.0.0.1", ing.port), timeout=120)
        t0 = time.perf_counter()
        s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
                  b"Content-Length: %d\r\n\r\n" % len(body) + body)
        first_event, data, reads = None, b"", 0
        while chunk := s.recv(65536):
            reads += 1
            if first_event is None and b"data: {\"token\"" in data + chunk:
                first_event = time.perf_counter() - t0
            data += chunk
        wall = time.perf_counter() - t0
        s.close()
        tokens = data.count(b"\"token\"")
        return {"tokens": tokens, "first_token_s": first_event,
                "wall_s": wall, "socket_reads": reads,
                "incremental": reads > 1 and first_event is not None
                and first_event < wall}
    finally:
        ing.close()
        orch.close()


def _routing_arm(cfg, params, make_router):
    from repro.serving.ingress import Ingress
    from repro.serving.orchestrator import Orchestrator
    orch = Orchestrator(cfg, params, n_instances=2, router=make_router(),
                        **ENG_KW)
    ing = Ingress(orch).start()
    try:
        t0 = time.perf_counter()
        for i in range(REPEATS):
            for t in range(N_TENANTS):
                _http(ing.port, "POST", "/v1/completions",
                      body={"prompt": _tenant_prompt(t, i),
                            "max_tokens": MAX_NEW})
        wall = time.perf_counter() - t0
        stats = orch.stats()
        c = ing.counters
        return {"requests": c.requests,
                "wall_s": wall,
                "tokens_per_s": c.tokens_out / wall,
                # the judged number: pod-wide engine-side hit rate —
                # what fraction of looked-up prompt blocks were served
                # by aliasing a resident block instead of re-prefilling
                "prefix_hit_rate": stats["prefix_hit_rate"],
                "routed_prefix": c.routed_prefix,
                "routed_vacancy": c.routed_vacancy,
                "rejected_429": c.rejected_429,
                "dropped": stats["dropped"]}
    finally:
        ing.close()
        orch.close()


def _burst(seed):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    return [RequestSpec(rid=1000 * seed + i,
                        prompt=rng.integers(2, 1000, size=12)
                        .astype(np.int32),
                        max_tokens=MAX_NEW) for i in range(BURST)]


def _drain_all(orch):
    t0 = time.perf_counter()
    tick0 = orch._tick
    before = sum(len(r.generated) for r in orch.finished)
    orch.run_until_done()
    wall = time.perf_counter() - t0
    ticks = max(orch._tick - tick0, 1)
    toks = sum(len(r.generated) for r in orch.finished) - before
    return {"tokens": toks, "wall_s": wall, "ticks": ticks,
            "tokens_per_s": toks / wall, "tokens_per_tick": toks / ticks}


def _elasticity_arm(cfg, params):
    # the REMOTE plane: spawned engine-server processes step through
    # the batched step_async poll, so a grown pod turns its doubled
    # per-tick token capacity into wall throughput on any host with a
    # core per worker (in-process local handles, stepped serially by
    # the one orchestrator thread, never could)
    from repro.core.controller import PodElasticityConfig
    from repro.launch.pod import make_worker_factory
    from repro.serving.engine import Engine
    from repro.serving.orchestrator import Orchestrator
    orch = Orchestrator(cfg, params, n_instances=1, remote=True,
                        telemetry_every=10_000,
                        worker_factory=make_worker_factory(cfg, params,
                                                           remote=True,
                                                           **ENG_KW),
                        pod_cfg=PodElasticityConfig(max_instances=2,
                                                    flap_guard_s=0.0),
                        **ENG_KW)
    try:
        for r in _burst(0):                   # compile warmup, unmeasured
            orch.submit(r)
        orch.run_until_done()
        for r in _burst(1):                   # warm pod-of-1 baseline
            orch.submit(r)
        pod1 = _drain_all(orch)
        t0 = time.perf_counter()
        assert orch.grow_pod() == 1           # spawn a worker mid-flight
        spawn_s = time.perf_counter() - t0
        for r in _burst(9):                   # warm the newcomer's jit
            orch.submit_to(1, r)
        orch.run_until_done()
        for r in _burst(2):
            orch.submit(r)
        pod2 = _drain_all(orch)
        # shrink MID-DECODE through the drain path: zero drops, token-
        # identical hand-off
        drained = _burst(3)
        for r in drained:
            orch.submit(r)
        for _ in range(3):
            orch.step()
        t0 = time.perf_counter()
        shrunk = orch.shrink_pod(1)
        drain_s = time.perf_counter() - t0
        orch.run_until_done()
        by_rid = {r.rid: r for r in orch.finished}
        identical = True
        for r in drained:
            e = Engine(cfg, params, max_batch=1, cache_kind="paged",
                       max_len=96, block_size=BLOCK_SIZE)
            from repro.serving.request import RequestSpec
            e.submit(RequestSpec.from_request(r))
            solo = e.run_until_done()[0].generated
            identical &= list(by_rid[r.rid].generated) == list(solo)
        capacity_gain = (pod2["tokens_per_tick"]
                         / max(pod1["tokens_per_tick"], 1e-9))
        return {"burst_requests": BURST,
                "pod1": pod1,
                "pod2": pod2,
                "host_cpus": len(os.sched_getaffinity(0)),
                "grow_spawn_s": spawn_s,
                # the judged scale-out number: tokens the pod delivers
                # per scheduling tick — doubles when the grown worker
                # absorbs its share of the burst; wall tok/s (reported
                # raw in pod1/pod2 above) tracks it only when the host
                # gives each worker its own core
                "grow_capacity_gain": capacity_gain,
                "grow_wall_speedup": (pod2["tokens_per_s"]
                                      / max(pod1["tokens_per_s"], 1e-9)),
                "meets_grow_gate": capacity_gain >= 1.5,
                "shrunk_instance": shrunk,
                "drain_s": drain_s,
                "drain_token_identical": identical,
                "pod_log": list(orch.pod_log),
                "dropped": orch.dropped,
                "finished": len(orch.finished)}
    finally:
        orch.close()


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving.router import PrefixAffinityRouter, RoundRobinRouter
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    streaming = _streaming_arm(cfg, params)
    affinity = _routing_arm(cfg, params, PrefixAffinityRouter)
    rr = _routing_arm(cfg, params, RoundRobinRouter)
    elasticity = _elasticity_arm(cfg, params)

    gain = affinity["prefix_hit_rate"] / max(rr["prefix_hit_rate"], 1e-9)
    report = {
        "smoke": is_smoke(),
        "config": {"arch": "tinyllama-1.1b (reduced)",
                   "n_tenants": N_TENANTS, "repeats": REPEATS,
                   "prefix_blocks": PREFIX_BLOCKS,
                   "block_size": BLOCK_SIZE, "max_new_tokens": MAX_NEW,
                   "burst": BURST},
        "streaming": streaming,
        "routing": {"affinity": affinity, "round_robin": rr,
                    "affinity_hit_gain": gain,
                    # ISSUE-8 acceptance: >= 1.5x pod-wide hit rate
                    "meets_1p5x_gate": gain >= 1.5},
        "elasticity": elasticity,
        "token_identical": elasticity["drain_token_identical"],
        "dropped_requests": (affinity["dropped"] + rr["dropped"]
                             + elasticity["dropped"]),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[ingress_bench] streaming: first token "
          f"{streaming['first_token_s']:.3f}s of {streaming['wall_s']:.3f}s "
          f"wall ({streaming['tokens']} tokens, "
          f"{streaming['socket_reads']} reads)")
    print(f"[ingress_bench] routing: affinity hit rate "
          f"{affinity['prefix_hit_rate']:.2f} vs round-robin "
          f"{rr['prefix_hit_rate']:.2f} -> {gain:.2f}x "
          f"(gate >= 1.5x: {'PASS' if gain >= 1.5 else 'FAIL'})")
    print(f"[ingress_bench] elasticity: capacity "
          f"{elasticity['pod1']['tokens_per_tick']:.1f} -> "
          f"{elasticity['pod2']['tokens_per_tick']:.1f} tok/tick on grow "
          f"({elasticity['grow_capacity_gain']:.2f}x, gate >= 1.5x: "
          f"{'PASS' if elasticity['meets_grow_gate'] else 'FAIL'}); wall "
          f"{elasticity['pod1']['tokens_per_s']:.0f} -> "
          f"{elasticity['pod2']['tokens_per_s']:.0f} tok/s on "
          f"{elasticity['host_cpus']} cpu(s); drain "
          f"{elasticity['drain_s'] * 1e3:.0f}ms, token_identical="
          f"{elasticity['drain_token_identical']}, "
          f"dropped={report['dropped_requests']}")
    return [("ingress_stream_first_tok",
             (streaming["first_token_s"] or 0.0) * 1e6,
             f"{streaming['tokens']}tok"),
            ("ingress_affinity_gain", affinity["wall_s"] * 1e6,
             f"{gain:.2f}x"),
            ("ingress_grow_capacity", elasticity["grow_spawn_s"] * 1e6,
             f"{elasticity['grow_capacity_gain']:.2f}x")]


if __name__ == "__main__":
    run()
