"""Benchmark harness — one module per paper table/figure (+ roofline and
kernel micro-benches). Prints a final ``name,us_per_call,derived`` CSV."""
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (ablations, fig6_replication, fig8_single,
                            fig9_memory, fig10_multi, fig11_robustness,
                            kernels_bench, module_scaling_bench,
                            paged_engine_bench, prefix_sharing_bench,
                            roofline, speedup_model, table1_modules,
                            table2_scaling_cost)
    suites = [
        ("table1", table1_modules),
        ("table2", table2_scaling_cost),
        ("speedup_model", speedup_model),
        ("fig6", fig6_replication),
        ("fig8", fig8_single),
        ("fig9", fig9_memory),
        ("fig10", fig10_multi),
        ("fig11", fig11_robustness),
        ("ablations", ablations),
        ("kernels", kernels_bench),
        ("paged_engine", paged_engine_bench),
        ("prefix_sharing", prefix_sharing_bench),
        ("module_scaling", module_scaling_bench),
        ("roofline", roofline),
    ]
    rows = []
    failures = 0
    for name, mod in suites:
        print(f"\n===== {name} ({mod.__name__}) =====", flush=True)
        t0 = time.time()
        try:
            rows.extend(mod.run() or [])
        except Exception:
            failures += 1
            traceback.print_exc()
            rows.append((name, 0.0, "ERROR"))
        print(f"===== {name} done in {time.time()-t0:.1f}s =====", flush=True)

    print("\n# ===== summary CSV =====")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
