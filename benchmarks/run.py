"""Benchmark harness — one module per paper table/figure (+ roofline and
kernel micro-benches). Prints a final ``name,us_per_call,derived`` CSV.

``--smoke`` runs EVERY suite at toy sizes (sets ``REPRO_BENCH_SMOKE=1``
before any bench module loads its knobs): a CI-speed execution check of
the full harness — imports, shapes, JSON emission, summary rows — whose
numbers are flagged ``"smoke": true`` and never comparable."""
import os
import sys
import time
import traceback


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if "--smoke" in argv:
        from benchmarks._smoke import ENV
        os.environ[ENV] = "1"
        print("# smoke mode: toy sizes, numbers not comparable")
    from benchmarks import (ablations, chaos_bench, distributed_bench,
                            fig6_replication, fig8_single, fig9_memory,
                            fig10_multi, fig11_robustness, ingress_bench,
                            kernels_bench, module_scaling_bench,
                            observe_bench, paged_engine_bench,
                            prefix_sharing_bench, roofline, slo_bench,
                            speedup_model, table1_modules,
                            table2_scaling_cost)
    suites = [
        ("table1", table1_modules),
        ("table2", table2_scaling_cost),
        ("speedup_model", speedup_model),
        ("fig6", fig6_replication),
        ("fig8", fig8_single),
        ("fig9", fig9_memory),
        ("fig10", fig10_multi),
        # chaos runs BEFORE fig11 so fig11's recovery section can
        # consume the BENCH_chaos.json this same run just emitted
        ("chaos", chaos_bench),
        ("fig11", fig11_robustness),
        ("ablations", ablations),
        ("kernels", kernels_bench),
        ("paged_engine", paged_engine_bench),
        ("prefix_sharing", prefix_sharing_bench),
        ("module_scaling", module_scaling_bench),
        ("distributed", distributed_bench),
        ("ingress", ingress_bench),
        ("slo", slo_bench),
        ("observe", observe_bench),
        ("roofline", roofline),
    ]
    rows = []
    failures = 0
    for name, mod in suites:
        print(f"\n===== {name} ({mod.__name__}) =====", flush=True)
        t0 = time.time()
        try:
            rows.extend(mod.run() or [])
        except Exception:
            failures += 1
            traceback.print_exc()
            rows.append((name, 0.0, "ERROR"))
        print(f"===== {name} done in {time.time()-t0:.1f}s =====", flush=True)

    print("\n# ===== summary CSV =====")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
