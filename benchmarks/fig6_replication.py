"""Paper Fig. 6: layer-replication count and degree-of-parallelism sweeps
(LLaMA-13B on 4 devices) — simulator reproduction of the four panels.

(a/b) dop=2 fixed, replication count in {0,15,20,25,30};
(c/d) 20 layers fixed, dop in {1,2,4}.
"""
import time

from repro.configs import get_config
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def _case(nlayers, dop, rps):
    # Fig. 6's baseline is the paper's "completely unmodified serial
    # execution environment" — a compute-bound executor (HFT-class kernel
    # efficiency); replication then parallelizes that compute across
    # devices, which is where the paper's nonlinear gains come from.
    sim = SimConfig(model=get_config("llama2-13b"), system="cocoserve",
                    n_devices=4, preset_replicated_layers=nlayers,
                    preset_dop=dop, enable_controller=False,
                    efficiency_override=0.08)
    return simulate(sim, WorkloadConfig(rps=rps, duration_s=12.0, seed=0))


def run():
    t0 = time.perf_counter()
    print("# Fig 6a/b: throughput/latency vs replication count (dop=2)")
    print(f"{'layers':>7s} {'rps':>4s} {'thr tok/s':>10s} {'latency':>8s}")
    base_thr = {}
    for rps in (10, 30, 50):
        for n in (0, 15, 20, 25, 30):
            r = _case(n, 2 if n else 1, rps)
            base_thr.setdefault(rps, r.throughput_tokens if n == 0 else None)
            if n == 0 and base_thr[rps] is None:
                base_thr[rps] = r.throughput_tokens
            print(f"{n:7d} {rps:4d} {r.throughput_tokens:10.0f} "
                  f"{r.mean_latency:8.2f}")
    print("# Fig 6c/d: throughput/latency vs dop (20 layers replicated)")
    gains = []
    for rps in (10, 30, 50):
        for dop in (1, 2, 4):
            r = _case(20 if dop > 1 else 0, dop, rps)
            print(f"dop={dop} rps={rps:3d} thr={r.throughput_tokens:8.0f} "
                  f"lat={r.mean_latency:6.2f}")
            if dop == 4 and rps == 50:
                gains.append(r.throughput_tokens)
    us = (time.perf_counter() - t0) * 1e6
    r0 = _case(0, 1, 50)
    gain = gains[0] / max(r0.throughput_tokens, 1)
    print(f"# replication gain at 50 RPS (dop=4 vs baseline): {gain:.2f}x "
          f"(paper: nonlinear positive, up to 4.3x)")
    return [("fig6_replication", us, f"gain50={gain:.2f}x")]


if __name__ == "__main__":
    run()
