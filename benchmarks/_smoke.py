"""Smoke-mode switch for the bench harness (``run.py --smoke``).

CI / pre-merge wants every bench to EXECUTE (imports, shapes, JSON
emission, summary rows) without paying full measurement sizes. run.py
sets ``REPRO_BENCH_SMOKE=1`` under ``--smoke``; benches shrink their
workload knobs through ``pick(normal, smoke)``. Smoke numbers are NOT
comparable across runs — the JSON reports carry a ``"smoke": true``
flag so nobody trends them by accident.
"""
import os

ENV = "REPRO_BENCH_SMOKE"


def is_smoke() -> bool:
    return os.environ.get(ENV) == "1"


def pick(normal, smoke):
    """The workload knob selector: full size normally, toy under smoke."""
    return smoke if is_smoke() else normal
