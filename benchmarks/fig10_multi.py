"""Paper Fig. 10 + §6.3: multi-instance comparison — CoCoServe 2 instances
vs HFT 2 and 4 instances on 4 devices; memory/cost accounting."""
import time

from repro.configs import get_config
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    print("# Fig 10 (2x CoCoServe vs 2x/4x HFT, llama2-13b)")
    rows = {}
    for rps in (10, 20, 35, 50):
        for label, system, n_inst in (("coco2", "cocoserve", 2),
                                      ("hft2", "hft", 2),
                                      ("hft4", "hft", 4)):
            r = simulate(SimConfig(model=cfg, system=system, n_devices=4,
                                   n_instances=n_inst),
                         WorkloadConfig(rps=rps, duration_s=10.0, seed=0))
            rows[(rps, label)] = r
            print(f"rps={rps:3d} {label:6s} thr={r.throughput_tokens:8.0f} "
                  f"lat={r.mean_latency:7.2f} "
                  f"mem={sum(r.peak_mem_per_device)/2**30:6.1f}GiB")
    # cost claim: coco2 ~90% of hft4 performance at ~half the memory
    import numpy as np
    perf, mem = [], []
    for rps in (10, 20, 35, 50):
        c, h4 = rows[(rps, "coco2")], rows[(rps, "hft4")]
        if h4.throughput_tokens > 0:
            perf.append(c.throughput_tokens / h4.throughput_tokens)
        mem.append(sum(c.peak_mem_per_device)
                   / max(sum(h4.peak_mem_per_device), 1))
    print(f"# coco2 vs hft4: perf x{np.mean(perf):.2f} at "
          f"{np.mean(mem):.0%} of the memory "
          f"(paper: ~90% perf at 53.5% memory, cost -46%)")
    us = (time.perf_counter() - t0) * 1e6
    return [("fig10_multi", us,
             f"perf{np.mean(perf):.2f}x_mem{np.mean(mem):.0%}")]


if __name__ == "__main__":
    run()
