"""Paper Fig. 11: (a) OOM occurrence rate HFT vs CoCoServe, (b) SLO
attainment vs request rate for all three systems, (c) measured failure
recovery from the chaos soak's ``BENCH_chaos.json`` (run.py runs
chaos_bench first, so a full harness pass always has real numbers
here; standalone runs fall back gracefully when the file is absent)."""
import json
import os
import time

from repro.configs import get_config
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig

CHAOS_PATH = os.path.join(os.path.dirname(__file__), "BENCH_chaos.json")


def _chaos_recovery_section():
    """Fig 11c: REAL recovery evidence — detection latency, respawn
    downtime and the zero-drop/token-identical verdicts measured by the
    chaos soak over a live 4-instance TCP pod, not simulated."""
    if not os.path.exists(CHAOS_PATH):
        print("# Fig 11c: failure recovery — no BENCH_chaos.json yet "
              "(run benchmarks/chaos_bench.py, or the full run.py "
              "harness, to measure it)")
        return None
    try:
        with open(CHAOS_PATH) as f:
            chaos = json.load(f)
        rec = chaos["recovery"]
        acc = chaos["acceptance"]
        streams = chaos["streams"]
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"# Fig 11c: BENCH_chaos.json unreadable ({e}); rerun "
              "benchmarks/chaos_bench.py")
        return None
    print("# Fig 11c: measured failure recovery (chaos soak, "
          f"{'smoke' if chaos.get('smoke') else 'full'} sizes)")
    print(f"detect_p50={rec['detect_p50_s']:.3f}s "
          f"detect_p95={rec['detect_p95_s']:.3f}s "
          f"(deadline={rec['rpc_deadline_s']:.2f}s, "
          f"bound=2x+slop={rec['detect_bound_s']:.2f}s)")
    downs = rec.get("respawn_downtime_s", [])
    print(f"quarantines={rec['quarantines']} respawns={rec['respawns']} "
          f"respawn_downtime_s={[round(d, 2) for d in downs]}")
    print(f"streams: dropped={streams['dropped']} "
          f"token_identical={streams['token_identical']} "
          f"(paper's robustness claim: failures cost recompute, "
          f"never output)")
    ok = all(acc.values())
    print(f"# chaos acceptance: {'ALL PASS' if ok else acc}")
    return rec


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    print("# Fig 11a: OOM events per 100 requests")
    ooms = {}
    for system in ("hft", "cocoserve"):
        r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                     WorkloadConfig(rps=50, duration_s=12.0, seed=0))
        total = len(r.completed) + r.dropped
        rate = 100.0 * r.oom_events / max(total, 1)
        ooms[system] = max(rate, 0.01)
        print(f"{system:10s} oom_rate={rate:6.2f}%")
    ratio = min(ooms["hft"] / ooms["cocoserve"], 99.0)
    print(f"# OOM improvement: >= {ratio:.0f}x (paper: 17x; our CoCoServe "
          f"admission control fully prevents OOM in this workload — the "
          f"paper's residual 2% comes from real-cluster fragmentation "
          f"effects the simulator does not model)")

    print("# Fig 11b: SLO attainment vs RPS")
    print(f"{'rps':>4s} {'hft':>6s} {'vllm':>6s} {'coco':>6s}")
    knees = {}
    for rps in (5, 10, 15, 20, 25, 30, 40, 50, 55):
        row = []
        for system in ("hft", "vllm", "cocoserve"):
            r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                         WorkloadConfig(rps=rps, duration_s=10.0, seed=0))
            att = r.slo_attainment(12.0)
            row.append(att)
            if att < 0.9 and system not in knees:
                knees[system] = rps
        print(f"{rps:4d} {row[0]:6.2f} {row[1]:6.2f} {row[2]:6.2f}")
    print(f"# SLO knees (first rate with <90% attainment): {knees} "
          f"(paper: HFT ~25, CoCoServe ~50)")
    rec = _chaos_recovery_section()
    us = (time.perf_counter() - t0) * 1e6
    rows = [("fig11_robustness", us, f"oom_ratio={ratio:.0f}x")]
    if rec is not None:
        rows.append(("fig11_recovery", rec["detect_p95_s"] * 1e6,
                     f"detect_p95={rec['detect_p95_s']:.3f}s "
                     f"respawns={rec['respawns']}"))
    return rows


if __name__ == "__main__":
    run()
