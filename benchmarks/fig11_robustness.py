"""Paper Fig. 11: (a) OOM occurrence rate HFT vs CoCoServe, (b) SLO
attainment vs request rate for all three systems."""
import time

from repro.configs import get_config
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def run():
    t0 = time.perf_counter()
    cfg = get_config("llama2-13b")
    print("# Fig 11a: OOM events per 100 requests")
    ooms = {}
    for system in ("hft", "cocoserve"):
        r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                     WorkloadConfig(rps=50, duration_s=12.0, seed=0))
        total = len(r.completed) + r.dropped
        rate = 100.0 * r.oom_events / max(total, 1)
        ooms[system] = max(rate, 0.01)
        print(f"{system:10s} oom_rate={rate:6.2f}%")
    ratio = min(ooms["hft"] / ooms["cocoserve"], 99.0)
    print(f"# OOM improvement: >= {ratio:.0f}x (paper: 17x; our CoCoServe "
          f"admission control fully prevents OOM in this workload — the "
          f"paper's residual 2% comes from real-cluster fragmentation "
          f"effects the simulator does not model)")

    print("# Fig 11b: SLO attainment vs RPS")
    print(f"{'rps':>4s} {'hft':>6s} {'vllm':>6s} {'coco':>6s}")
    knees = {}
    for rps in (5, 10, 15, 20, 25, 30, 40, 50, 55):
        row = []
        for system in ("hft", "vllm", "cocoserve"):
            r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                         WorkloadConfig(rps=rps, duration_s=10.0, seed=0))
            att = r.slo_attainment(12.0)
            row.append(att)
            if att < 0.9 and system not in knees:
                knees[system] = rps
        print(f"{rps:4d} {row[0]:6.2f} {row[1]:6.2f} {row[2]:6.2f}")
    print(f"# SLO knees (first rate with <90% attainment): {knees} "
          f"(paper: HFT ~25, CoCoServe ~50)")
    us = (time.perf_counter() - t0) * 1e6
    return [("fig11_robustness", us, f"oom_ratio={ratio:.0f}x")]


if __name__ == "__main__":
    run()
