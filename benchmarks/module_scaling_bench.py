"""Live module-scaling benchmark — the paper's §5 scenario on REAL
engines: steady traffic -> burst -> controller scale-up (replication
degrees applied to the live decode step) -> drain -> scale-down
(KV-block migration off an instance), with zero dropped requests and
token-identical outputs for migrated streams.

Measures:
* tokens/s before / during / after the burst (orchestrator telemetry);
* scale-up latency — wall seconds from the controller decision to the
  first decode step running under the new plan (includes the recompile);
* migration seconds vs. the Table-2 ``estimate_cost`` model. The model's
  two constants (fixed overhead, effective bandwidth) are calibrated from
  two probe block-migrations — exactly how the paper fits Table 2 to its
  testbed — then validation migrations must land within 2x.

Emits ``benchmarks/BENCH_module_scaling.json`` and contributes rows to
``benchmarks/run.py``'s summary CSV.
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks._smoke import is_smoke, pick

ARCH = "tinyllama-1.1b"
MAX_LEN = 128
MAX_BATCH = 3
BLOCK_SIZE = 8
N_BLOCKS = 96
PROMPT_LEN = 12
MAX_NEW = pick(12, 6)
BASE_REQUESTS = pick(6, 3)
BURST_REQUESTS = pick(12, 4)
CALIB_LARGE_TOKENS = pick(64 * BLOCK_SIZE, 16 * BLOCK_SIZE)
SLO_STEPS = 40.0

OUT_PATH = os.path.join(os.path.dirname(__file__),
                        "BENCH_module_scaling.json")


def _requests(cfg, n, rid0=0, seed=0):
    from repro.serving.request import RequestSpec
    rng = np.random.default_rng(seed)
    return [RequestSpec(rid=rid0 + i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=PROMPT_LEN)
                        .astype(np.int32),
                        max_tokens=MAX_NEW)
            for i in range(n)]


def _phase_tokens_per_s(orch, n_steps):
    t0 = time.perf_counter()
    toks0 = sum(t.total_tokens for t in orch.telemetry)
    for _ in range(n_steps):
        orch.step()
    dt = time.perf_counter() - t0
    return (sum(t.total_tokens for t in orch.telemetry) - toks0) / dt


def _calibrate_migration(cfg):
    """Fit estimate_cost's (overhead, bandwidth) from two probe
    block-migrations (core.migration.fit_migration_model), then validate
    a third, mid-sized one against the 2x acceptance bound."""
    from repro.core.migration import (estimate_cost, fit_migration_model,
                                      probe_block_migration)

    fit = fit_migration_model(cfg, block_size=BLOCK_SIZE,
                              small_tokens=2 * BLOCK_SIZE,
                              large_tokens=CALIB_LARGE_TOKENS)
    t_mid, b_mid = probe_block_migration(cfg, 16 * BLOCK_SIZE,
                                         block_size=BLOCK_SIZE)
    est_mid = estimate_cost(b_mid, fit["bandwidth_Bps"],
                            fixed_overhead_s=fit["fixed_overhead_s"])
    ratio = t_mid / est_mid if est_mid > 0 else float("inf")
    fit["validate"] = {"bytes": b_mid, "measured_s": t_mid,
                       "estimated_s": est_mid, "ratio": ratio,
                       "within_2x": bool(0.5 <= ratio <= 2.0)}
    return fit


def run():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving.orchestrator import Orchestrator

    cfg = get_config(ARCH).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    # warm the compile caches so phase timings measure steady state
    warm = Orchestrator(cfg, params, n_instances=2, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, block_size=BLOCK_SIZE,
                        n_blocks=N_BLOCKS, telemetry_every=10_000)
    for r in _requests(cfg, 4, seed=9):
        warm.submit(r)
    warm.run_until_done()
    warm.engines[0].apply_plan([2] * cfg.num_layers)  # hook-path compile
    warm.engines[0].submit(_requests(cfg, 1, seed=10)[0])
    warm.engines[0].run_until_done()

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, block_size=BLOCK_SIZE,
                        n_blocks=N_BLOCKS, slo_latency=SLO_STEPS,
                        telemetry_every=4)
    # ------------------------------------------------- phase 1: steady
    for r in _requests(cfg, BASE_REQUESTS, seed=0):
        orch.submit(r)
    pre_tps = _phase_tokens_per_s(orch, 8)

    # ------------------------------------------------- phase 2: burst
    # skewed burst (sticky routing onto instance 0): instance 1 keeps
    # vacancy, which is exactly the idle capacity Alg. 1 replicates into
    for r in _requests(cfg, BURST_REQUESTS, rid0=100, seed=1):
        orch._home[r.rid] = 0
        orch.engines[0].submit(r)
    log_before = len(orch.controller.log)
    burst_tps = _phase_tokens_per_s(orch, 8)
    # scale-up latency: decision -> first step under the new plan
    scale_up_s = None
    if len(orch.controller.log) > log_before:
        t_dec = time.perf_counter()
        orch.step()
        scale_up_s = time.perf_counter() - t_dec
    scaled_up = any(a.startswith("scale-up") for a in orch.controller.log)

    # ------------------------------------------- phase 3: drain + migrate
    orch.run_until_done()
    # re-load one instance, then consolidate off the other (§5 scale-down)
    tail = _requests(cfg, 4, rid0=200, seed=2)
    for r in tail:
        orch.submit(r)
    for _ in range(3):
        orch.step()
    src = max(range(2), key=lambda i: len(orch.engines[i].active))
    orch.drain_instance(src)
    post_tps = _phase_tokens_per_s(orch, 6)   # consolidated steady state
    orch.run_until_done()

    calib = _calibrate_migration(cfg)

    # token identity for every migrated request, vs. an unmigrated engine
    from repro.serving.engine import Engine
    migrated_rids = {m.rid for m in orch.migrations}
    by_rid = {r.rid: r for r in orch.finished}
    identical = True
    for rid in migrated_rids:
        ref_eng = Engine(cfg, params, max_batch=1, max_len=MAX_LEN,
                         cache_kind="paged", block_size=BLOCK_SIZE)
        req = by_rid[rid]
        from repro.serving.request import RequestSpec
        ref_eng.submit(RequestSpec.from_request(req))
        ref = ref_eng.run_until_done()[0].generated
        identical &= (ref == req.generated)

    s = orch.stats()
    report = {
        "smoke": is_smoke(),
        "config": {"arch": f"{ARCH} (reduced)", "max_len": MAX_LEN,
                   "max_batch": MAX_BATCH, "block_size": BLOCK_SIZE,
                   "n_blocks": N_BLOCKS, "base_requests": BASE_REQUESTS,
                   "burst_requests": BURST_REQUESTS},
        "throughput_tokens_per_s": {"pre_burst": pre_tps,
                                    "burst": burst_tps,
                                    "post_burst": post_tps},
        "scale_up": {"triggered": scaled_up,
                     "first_step_under_new_plan_s": scale_up_s,
                     "plan_p": s["plan_p"]},
        "migration": {"live_records": [
            {"rid": m.rid, "blocks": m.n_blocks, "bytes": m.bytes_moved,
             "seconds": m.seconds, "est_seconds": m.est_seconds,
             "resumed": m.resumed} for m in orch.migrations],
            "cost_model": calib},
        "dropped_requests": s["dropped"],
        "migrated_token_identical": bool(identical),
        "controller_log": s["controller_log"],
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    v = calib["validate"]
    rows = [
        ("module_scaling_migration", v["measured_s"] * 1e6,
         f"est={v['estimated_s'] * 1e6:.0f}us ratio={v['ratio']:.2f}"),
        ("module_scaling_burst", 0.0,
         f"tok/s pre={pre_tps:.1f} burst={burst_tps:.1f} "
         f"post={post_tps:.1f}"),
        ("module_scaling_drops", 0.0,
         f"dropped={s['dropped']} migrations={s['migrations']} "
         f"identical={identical}"),
    ]
    return rows


if __name__ == "__main__":
    run()
