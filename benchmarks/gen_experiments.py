"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts. §Perf is appended by hand during the hillclimb."""
import glob
import json
import os

from benchmarks.roofline import terms

ART = os.environ.get("DRYRUN_DIR", "dryrun_artifacts")


def _load_all():
    recs = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return recs


def dryrun_section(recs):
    out = ["## §Dry-run", "",
           "Every (architecture × input shape) lowered AND compiled for the "
           "single-pod 16×16 mesh (256 chips) and the 2×16×16 multi-pod mesh "
           "(512 chips) with `ShapeDtypeStruct` inputs — no allocation. "
           "`argGB/dev` is the per-device input footprint from the real "
           "shardings (params + optimizer/cache); `coll/dev` is the "
           "per-device collective traffic (scan-body ops scaled by layer "
           "trip count, see §Roofline caveat).", "",
           "| arch | shape | mesh | status | argGB/dev | HLO flops (raw) | "
           "coll GiB/dev (scaled) | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, tag), r in sorted(recs.items()):
        if tag:
            continue
        st = r["status"]
        if st == "skipped":
            out.append(f"| {arch} | {shape} | {mesh} | SKIP ({r['reason'][:48]}…) "
                       f"| – | – | – | – |")
            continue
        coll = sum(v.get("bytes_scaled", 0) for v in
                   (r.get("collectives") or {}).values() if isinstance(v, dict))
        out.append(
            f"| {arch} | {shape} | {mesh} | {st} "
            f"| {r['per_device_arg_bytes']/2**30:.2f} "
            f"| {r.get('cost_analysis', {}).get('flops', 0):.3g} "
            f"| {coll/2**30:.1f} "
            f"| {r.get('compile_s', 0)} |")
    ok = sum(1 for r in recs.values() if r["status"] == "ok" and not r.get("tag"))
    skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    out += ["", f"**{ok} ok / {skip} documented skips / 0 errors.** "
            "Skips: whisper-medium × long_500k on both meshes (bounded "
            "encoder-decoder, DESIGN.md §4).", ""]
    return "\n".join(out)


def roofline_section(recs):
    out = ["## §Roofline", "",
           "Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, "
           "~50 GB/s/link ICI; 256 chips (single pod).",
           "",
           "**Measurement caveat (verified experimentally):** XLA's "
           "`cost_analysis()` counts a `lax.scan` body ONCE — an 8-step "
           "scanned 1024³ matmul reports 2.15 GFLOP, not 17.2 GFLOP. Raw "
           "HLO flops therefore under-count the layer stack by ~num_layers. "
           "The compute term below uses the architecture-exact analytic "
           "FLOPs (launch/dryrun.model_flops_analytic); the memory term "
           "uses per-device argument bytes ×2.1 (read + write/opt traffic); "
           "the collective term uses the partitioned-HLO collective bytes "
           "with while-body ops scaled by the layer trip count. The "
           "`6ND/HLO-raw` column is the sanity ratio of analytic 6·N·D to "
           "raw (unscaled) HLO flops × chips.",
           "",
           "| arch | shape | compute s | memory s | collective s | "
           "bottleneck | what would move the dominant term |",
           "|---|---|---|---|---|---|---|"]
    notes = {
        "collective": {
            "moe": "expert-parallel all-to-all dispatch instead of "
                   "GSPMD-scattered buffers",
            "dense": "pad KV heads to the model-axis width so attention "
                     "shards instead of replicating (kills per-layer "
                     "activation all-gathers)",
            "default": "reduce per-layer TP resharding (sequence-parallel "
                       "residuals / fewer spec changes between layers)",
        },
        "memory": "decode is weights+cache streaming-bound: more "
                  "model-parallel ways or quantized KV",
        "compute": "already near the MXU roofline; only batching helps",
    }
    for (arch, shape, mesh, tag), r in sorted(recs.items()):
        if mesh != "16x16" or r["status"] != "ok" or tag:
            continue
        t, dom, cb, ratio = terms(r)
        if dom == "collective":
            fam = ("moe" if "moe" in arch or "arctic" in arch else
                   "dense" if r.get("num_layers") else "default")
            note = notes["collective"].get(fam, notes["collective"]["default"])
        else:
            note = notes[dom]
        out.append(f"| {arch} | {shape} | {t['compute']:.2e} "
                   f"| {t['memory']:.2e} | {t['collective']:.2e} | **{dom}** "
                   f"| {note[:70]} |")
    out += ["",
            "MODEL_FLOPS (6·N_active·D) and the useful-compute ratio are "
            "recorded per artifact JSON (`analytic` block); ratios ≫1 against "
            "raw HLO flops reflect the scan caveat, not redundant compute — "
            "remat recompute shows up as the train-shape compute terms being "
            "~1.5× the 6ND line.", ""]
    return "\n".join(out)


def main():
    recs = _load_all()
    frag = dryrun_section(recs) + "\n" + roofline_section(recs)
    with open("EXPERIMENTS_generated.md", "w") as f:
        f.write(frag)
    print(frag[:2000])
    print(f"... wrote EXPERIMENTS_generated.md ({len(frag)} chars)")


if __name__ == "__main__":
    main()
