"""End-to-end training driver (deliverable b): train a ~100M-param model for
a few hundred steps on synthetic packed LM data, with checkpointing.

The default below instantiates gemma-7b's family at ~100M scale by training
the reduced tinyllama config scaled up via CLI; for a quick smoke use
--steps 50. A full run:

    PYTHONPATH=src python examples/train_small.py --steps 300
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "tinyllama-1.1b", "--reduce",
                            "--steps", "300", "--batch", "8", "--seq", "128",
                            "--ckpt", "/tmp/train_small.ckpt"]
    main(argv)
