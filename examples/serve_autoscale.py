"""End-to-end serving driver (deliverable b): a real continuous-batching
engine under a Poisson workload with the CoCoServe Monitor -> Controller
closed loop making live scale-up/scale-down decisions.

    PYTHONPATH=src python examples/serve_autoscale.py --requests 24 --rps 6
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])
