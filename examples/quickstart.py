"""Quickstart: build a model, prefill + decode a few tokens, serve a small
batch through the paged continuous-batching engine, then apply a CoCoServe
module operation (layer replication plan) and show the modeled speedup —
the whole public API in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.cluster import Cluster, layer_weight_bytes
from repro.core.plan import PlacementPlan
from repro.core.scale_up import scale_up
from repro.core.speedup import speedup_homo
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    args = ap.parse_args()

    # 1) model (reduced variant: CPU-friendly, same family/code path)
    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} family={cfg.family} "
          f"reduced_params={cfg.param_count()/1e6:.1f}M")
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")

    # 2) prefill + a few greedy decode steps
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    enc = (jnp.zeros((1, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
           if cfg.family == "audio" else None)
    cache = T.init_cache(cfg, 1, 64, "float32")
    logits, cache, _ = T.forward(params, cfg, prompt, mode="prefill",
                                 cache=cache, encoder_input=enc)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    for i in range(5):
        pos = jnp.full((1, 1), 8 + i, jnp.int32)
        logits, cache, _ = T.forward(params, cfg,
                                     jnp.asarray([[toks[-1]]], jnp.int32),
                                     positions=pos, mode="decode",
                                     cache=cache)
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
    print("greedy tokens:", toks)

    # 3) the serving engine on its primary (paged-KV) path: batched
    # admission, block-pool decode, on-device sampling — one host sync
    # per step. (Attention decoders only; other families run dense.)
    if cfg.supports_paged_kv:
        from repro.serving.engine import Engine
        from repro.serving.request import RequestSpec
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     cache_kind="paged", block_size=8)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(RequestSpec(rid=i,
                                   prompt=rng.integers(2, cfg.vocab_size,
                                                       size=6 + i)
                                   .astype(np.int32),
                                   max_tokens=6))
        done = eng.run_until_done()
        for r in sorted(done, key=lambda r: r.rid):
            print(f"paged engine rid={r.rid}: {r.generated}")
        print(f"pool end state: blocks={eng.pstate.n_blocks}, "
              f"in_use={eng.pstate.blocks_in_use()} (drained pool -> 0)")

    # 4) CoCoServe: plan a scale-up on an idle 4-device cluster
    full = get_config(args.arch)
    cluster = Cluster.homogeneous(4)
    plan = scale_up(PlacementPlan.initial(full.num_layers), cluster,
                    gamma=0.05, replica_size=layer_weight_bytes(full))
    print(f"scale-up: replicated {plan.replicated_layer_count()} layers, "
          f"continuity breaks={plan.continuity_breaks()}, "
          f"modeled speedup={speedup_homo(plan.p, 0.05):.2f}x")


if __name__ == "__main__":
    main()
