"""Prefix sharing demo: N chat streams over ONE system prompt.

Runs the same shared-system-prompt workload twice through the paged
engine — prefix sharing OFF, then ON — and prints, for each: peak pool
blocks in use, prefix-cache hit rate, copy-on-write forks, and tok/s.
With sharing ON the first admission prefills the system prompt once;
every later stream aliases those blocks (refcounted, copy-on-write) and
prefills only its own user suffix. Outputs are token-identical either
way — sharing changes where KV lives, not what the model computes.

    PYTHONPATH=src python examples/shared_prefix.py \
        [--streams 6] [--sys-len 32] [--max-new 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec


def make_requests(cfg, n_streams, sys_len, user_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    system_prompt = rng.integers(2, cfg.vocab_size,
                                 size=sys_len).astype(np.int32)
    reqs = []
    for i in range(n_streams):
        user = rng.integers(2, cfg.vocab_size, size=user_len).astype(np.int32)
        reqs.append(RequestSpec(rid=i,
                                prompt=np.concatenate([system_prompt, user]),
                                max_tokens=max_new))
    return reqs


def run_once(cfg, params, args, share):
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=128,
                 cache_kind="paged", block_size=args.block_size,
                 prefix_sharing=share)
    for r in make_requests(cfg, args.streams, args.sys_len, args.user_len,
                           args.max_new):
        eng.submit(r)
    peak, done = 0, []
    t0 = time.perf_counter()
    while eng.queue or eng.active:
        done += eng.step() or []
        peak = max(peak, eng.pstate.blocks_in_use())
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    stats = eng.prefix_stats()
    label = "ON " if share else "OFF"
    print(f"[sharing {label}] peak blocks in use: {peak:3d} "
          f"(pool {eng.pstate.n_blocks})  hit rate: "
          f"{stats['hit_rate']:.2f}  CoW forks: {stats['cow_forks']}  "
          f"tok/s: {toks / wall:.1f}")
    return {r.rid: r.generated for r in done}, peak


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--streams", type=int, default=6)
    ap.add_argument("--sys-len", type=int, default=32,
                    help="shared system-prompt tokens")
    ap.add_argument("--user-len", type=int, default=6,
                    help="private per-stream suffix tokens")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    print(f"{args.streams} streams sharing a {args.sys_len}-token system "
          f"prompt (+{args.user_len} private tokens each, "
          f"block_size={args.block_size})")

    off, peak_off = run_once(cfg, params, args, share=False)
    on, peak_on = run_once(cfg, params, args, share=True)

    assert on == off, "sharing must not change token streams"
    print(f"token-identical: True   peak blocks {peak_off} -> {peak_on} "
          f"({peak_off - peak_on} saved, "
          f"{100 * (1 - peak_on / max(peak_off, 1)):.0f}% less)")


if __name__ == "__main__":
    main()
