"""Module migration demo (§3.1/§3.3): move a layer's attention projections
and the KV cache to a different placement and measure the cost — the
fine-grained operation CoCoServe's scale-down Phase 1 performs.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/migrate_modules.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import migration as M  # noqa: E402
from repro.core.replication import replication_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402


def main():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    cache = T.init_cache(cfg, 2, 64, "float32")
    mesh = replication_mesh(8)

    print("== migrate attention projections (compute-intensive module) ==")
    params, cost = M.migrate_by_path(params, r"layers/attn", P(), mesh,
                                     measure=True)
    print(f"moved {cost.bytes_moved/1e6:.1f} MB, est {cost.est_seconds:.3f}s "
          f"(ICI model), measured host {cost.measured_seconds*1e3:.1f} ms")

    print("== migrate the KV cache (memory-intensive module) ==")
    cache, cost = M.migrate_kv_cache(cache, P(), mesh, measure=True)
    print(f"moved {cost.bytes_moved/1e6:.1f} MB, est {cost.est_seconds:.3f}s, "
          f"measured host {cost.measured_seconds*1e3:.1f} ms")

    print("(paper Table 2: 0.25-0.9 s per 1-40 layers at A100/NVLink scale)")


if __name__ == "__main__":
    main()
