"""Token-budget continuous batching (DESIGN.md §10): chunked prefill
must be invisible in outputs (token-identity vs the phase engine), the
budget must actually protect decodes (no stalled streams during long
prefills), and the mid-prefill cursor must survive preemption and
migration without replaying landed chunks."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.instrument import count_host_syncs
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.orchestrator import Orchestrator

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _prompts(sizes, seed=0, vocab=256):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, size=s).astype(np.int32) for s in sizes]


def _reqs(prompts, *, max_new=5, temperature=0.0, top_k=0):
    return [RequestSpec(rid=i, prompt=p, max_tokens=max_new,
                        sampling=SamplingParams(temperature=temperature,
                                                top_k=top_k,
                                                seed=100 + i))
            for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    return {r.rid: r.generated for r in engine.run_until_done()}


# --------------------------------------------------------------- scheduler


def test_plan_decode_first_fifo_and_alignment(tiny):
    """Pure-policy unit test: decode slots charged first, in-flight
    prefills continued before fresh admissions, at most one partial
    (block-aligned) fresh grant."""
    cfg, params = tiny
    e = Engine(cfg, params, max_batch=4, max_len=64, cache_kind="paged",
               block_size=8, token_budget=24, prefix_sharing=False)
    for r in _reqs(_prompts([16, 16, 16], seed=3)):
        e.submit(r)
    plan = e.sched.plan(e)
    assert plan.n_decode == 0 and plan.budget == 24
    assert [g.n_tokens for g in plan.grants] == [16, 8]
    assert plan.grants[0].final and not plan.grants[1].final
    assert plan.grants[1].n_tokens % e.pstate.block_size == 0
    assert plan.packed == 24 and plan.utilization == 1.0

    e.step()    # r0 active, r1 mid-prefill at cursor 8
    plan = e.sched.plan(e)
    assert plan.n_decode == 1                      # decode charged first
    cont = plan.grants[0]
    assert cont.slot is not None and cont.start == 8 and cont.final
    assert plan.packed <= plan.budget


def test_chunked_matches_phase_greedy_and_sampled(tiny):
    """Tentpole acceptance: the budget scheduler slices prompts across
    steps yet emits token-identical streams — greedy and sampled, with
    prefix sharing on and off (shared-prefix prompts exercise the
    cache-hit + chunked-suffix fusion)."""
    cfg, params = tiny
    base = _prompts([40, 72, 24], seed=1)
    shared = np.concatenate([base[0][:24], base[2]])  # aliases req 0
    prompts = base + [shared]
    for sharing in (False, True):
        for temp, tk in ((0.0, 0), (0.8, 8)):
            kw = dict(max_batch=3, max_len=128, cache_kind="paged",
                      block_size=8, prefix_sharing=sharing)
            ref = _run(Engine(cfg, params, scheduler="phase", **kw),
                       _reqs(prompts, temperature=temp, top_k=tk))
            got = _run(Engine(cfg, params, scheduler="token_budget",
                              token_budget=24, **kw),
                       _reqs(prompts, temperature=temp, top_k=tk))
            assert got == ref, (sharing, temp)


def test_chunked_matches_phase_sliding_window(tiny):
    """Chunked prefill under a sliding window: leading blocks die while
    the prompt is still landing; the cursor and the window-aware
    allocator must agree on which columns exist."""
    cfg, params = tiny
    swa_cfg = dataclasses.replace(cfg, sliding_window=16)
    prompts = _prompts([40, 56], seed=2)
    kw = dict(max_batch=2, max_len=96, cache_kind="paged", block_size=4,
              swa=True)
    ref = _run(Engine(swa_cfg, params, scheduler="phase", **kw),
               _reqs(prompts, max_new=6))
    eng = Engine(swa_cfg, params, scheduler="token_budget",
                 token_budget=16, **kw)
    got = _run(eng, _reqs(prompts, max_new=6))
    assert got == ref
    assert eng.pstate.blocks_in_use() == 0


# ------------------------------------------------------- decode protection


def test_decode_not_stalled_by_long_prefill(tiny):
    """The property the tentpole exists for: while a long prompt admits
    chunk by chunk, every active decode emits exactly one token per
    step — no step is ever a prefill-only wave that skips them."""
    cfg, params = tiny
    short, long = _prompts([8, 64], seed=4)
    e = Engine(cfg, params, max_batch=2, max_len=96, cache_kind="paged",
               block_size=8, token_budget=24)
    a = e.submit(RequestSpec(rid=0, prompt=short, max_tokens=24))
    e.step()                      # A prefills whole (8 <= budget)
    assert e.active and a.slot in e.active
    b = e.submit(RequestSpec(rid=1, prompt=long, max_tokens=4))
    prefill_steps = 0
    while b.first_token_time is None:
        n = len(a.generated)
        e.step()
        assert len(a.generated) == n + 1, "decode stalled by prefill"
        assert e.last_step_packed is not None
        assert e.last_step_packed <= e.token_budget
        if b.slot is not None and b.first_token_time is None:
            prefill_steps += 1
    # 64-token prompt through a 24-token budget sharing with a decode:
    # the prefill must genuinely have been sliced across steps
    assert prefill_steps >= 2
    assert b.prefill_pos == len(long)


def test_mid_prefill_preemption_replays_identically(tiny):
    """A preempted mid-prefill slot resets its cursor, frees its blocks,
    and replays to the same tokens (counter-based sampling keys)."""
    cfg, params = tiny
    (prompt,) = _prompts([40], seed=5)
    ref = _run(Engine(cfg, params, max_batch=2, max_len=64,
                      cache_kind="paged", block_size=8,
                      prefix_sharing=False, scheduler="phase"),
               _reqs([prompt], temperature=0.7, top_k=8))
    e = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
               block_size=8, prefix_sharing=False, token_budget=16)
    r = e.submit(*_reqs([prompt], temperature=0.7, top_k=8))
    e.step()
    slot = r.slot
    assert slot in e.prefilling and 0 < r.prefill_pos < len(prompt)
    e._preempt(slot)
    assert r.prefill_pos == 0 and r.slot is None and r.preemptions == 1
    assert e.pstate.blocks_in_use() == 0
    assert e.queue and e.queue[0] is r
    done = {d.rid: d.generated for d in e.run_until_done()}
    assert done == ref


# --------------------------------------------------------------- migration


def _mid_prefill(cfg, params, prompt, max_len=64):
    """An engine stepped until ``prompt`` sits mid-prefill; returns
    (engine, request, slot)."""
    e = Engine(cfg, params, max_batch=2, max_len=max_len,
               cache_kind="paged", block_size=8, prefix_sharing=False,
               token_budget=16)
    r = e.submit(*_reqs([prompt], temperature=0.6, top_k=8))
    e.step()
    slot = r.slot
    assert slot in e.prefilling and 0 < r.prefill_pos < len(prompt)
    return e, r, slot


def test_migrate_mid_prefill_without_replay(tiny):
    """Satellite 1: pause/resume of a WAITING-queue request caught mid
    prefill carries cursor + written blocks — the destination resumes
    from the cursor instead of replaying the prompt."""
    cfg, params = tiny
    (prompt,) = _prompts([40], seed=6)
    ref = _run(Engine(cfg, params, max_batch=2, max_len=64,
                      cache_kind="paged", block_size=8,
                      prefix_sharing=False, scheduler="phase"),
               _reqs([prompt], temperature=0.6, top_k=8))
    src, r, slot = _mid_prefill(cfg, params, prompt)
    cursor = r.prefill_pos
    payload = src.pause_request(slot)
    assert payload["phase"] == "prefill"
    assert src.pstate.blocks_in_use() == 0

    dst = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
                 block_size=8, prefix_sharing=False, token_budget=16)
    assert dst.resume_request(payload)
    (dslot, dreq), = dst.prefilling.items()
    assert dreq.rid == r.rid
    assert dreq.prefill_pos == cursor > 0       # no replay: cursor kept
    assert int(dst.pstate.lengths[dslot]) == cursor
    done = {d.rid: d.generated for d in dst.run_until_done()}
    assert done == ref


def test_two_phase_migration_spanning_prefill_chunks(tiny):
    """Overlapped migration of a mid-prefill request: snapshot at one
    cursor, keep stepping the source (more chunks land), pause, commit
    the delta — the destination continues from the PAUSE-time cursor."""
    cfg, params = tiny
    (prompt,) = _prompts([56], seed=7)
    ref = _run(Engine(cfg, params, max_batch=2, max_len=96,
                      cache_kind="paged", block_size=8,
                      prefix_sharing=False, scheduler="phase"),
               _reqs([prompt], temperature=0.6, top_k=8))
    src, r, slot = _mid_prefill(cfg, params, prompt, max_len=96)
    snap = src.snapshot_request(slot)
    dst = Engine(cfg, params, max_batch=2, max_len=96, cache_kind="paged",
                 block_size=8, prefix_sharing=False, token_budget=16)
    staged = dst.prepare_resume(snap)
    assert staged is not None
    src.step()                    # overlap: another chunk lands at source
    assert r.prefill_pos > snap["position"]
    payload = src.pause_request(slot, since_epoch=snap["epoch"])
    assert payload["phase"] == "prefill"
    assert dst.commit_resume(staged, payload)
    dreq = dst.prefilling[staged]
    assert dreq.prefill_pos == payload["kv"]["length"]
    done = {d.rid: d.generated for d in dst.run_until_done()}
    assert done == ref


# --------------------------------------------------------------- telemetry


def test_budget_gauges_surface_in_orchestrator(tiny):
    """Satellite 2: budget_utilization / ttft ride EngineTelemetry into
    both MetricsSnapshot and orchestrator.stats()."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, token_budget=24)
    for r in _reqs(_prompts([40, 24, 16], seed=8), max_new=4):
        orch.submit(r)
    orch.run_until_done()
    snap = orch.snapshot()
    assert 0.0 < snap.budget_utilization <= 1.0
    assert snap.ttft_p50 > 0.0 and snap.ttft_p95 >= snap.ttft_p50
    assert snap.queue_delay_p95 >= 0.0
    stats = orch.stats()
    assert 0.0 < stats["budget_utilization"] <= 1.0
    assert stats["ttft_p50"] > 0.0
    assert stats["ttft_p95"] >= stats["ttft_p50"]
    assert "queue_delay_p95" in stats


def test_budget_steady_state_single_host_sync(tiny):
    """The packing loop keeps the one-host-sync-per-step contract in
    decode steady state."""
    cfg, params = tiny
    e = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
               block_size=8, token_budget=24)
    for r in _reqs(_prompts([8, 8], seed=9), max_new=16):
        e.submit(r)
    e.step()                      # admission step (compiles + prefills)
    assert len(e.active) == 2 and not e.queue and not e.prefilling
    e.step()                      # warm the decode executable
    with count_host_syncs() as c:
        e.step()
    assert c.n <= 1, f"{c.n} host syncs in a steady-state budget step"
