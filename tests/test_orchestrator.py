"""Live module scaling: orchestrator control loop, KV-block migration
determinism, sliding-window paged reclamation, prefill bucketing.

The acceptance scenario of the ISSUE-2 tentpole: under a burst the
orchestrator scales UP (replication plan applied to live instances) and
scales DOWN by migrating KV blocks off an instance — zero dropped
requests, token-identical output for every migrated stream."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.migration import estimate_cost
from repro.core.monitor import MetricsSnapshot
from repro.models import transformer as T
from repro.serving import paged_kv as PK
from repro.serving.engine import Engine
from repro.serving.orchestrator import Orchestrator
from repro.serving.request import RequestSpec, SamplingParams

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _reference_outputs(cfg, params, requests):
    """Unmigrated oracle: each request solo on a fresh paged engine.
    Accepts specs or live Requests — the spec IS the pristine clone."""
    out = {}
    for r in requests:
        spec = (r if isinstance(r, RequestSpec)
                else RequestSpec.from_request(r))
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(spec)
        out[spec.rid] = e.run_until_done()[0].generated
    return out


# ------------------------------------------------- block export / import
def test_export_import_blocks_roundtrip(tiny):
    cfg, _ = tiny
    src = PK.init_paged(cfg, 2, 16, block_size=8, dtype="float32",
                        max_len=64)
    dst = PK.init_paged(cfg, 2, 16, block_size=8, dtype="float32",
                        max_len=64)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(L, 13, KV, hd)), jnp.float32)
    PK.allocate(src, 0, 13)
    src = PK.write_tokens(src, 0, k, k * 2)
    before_k, before_v = PK.gather_request(src, 0, 13)
    payload = PK.export_blocks(src, 0)
    assert payload["length"] == 13 and len(payload["cols"]) == 2
    PK.import_blocks(dst, 1, payload)
    PK.free_slot(src, 0)
    after_k, after_v = PK.gather_request(dst, 1, 13)
    np.testing.assert_array_equal(np.asarray(before_k), np.asarray(after_k))
    np.testing.assert_array_equal(np.asarray(before_v), np.asarray(after_v))
    assert int(dst.lengths[1]) == 13
    assert src.blocks_in_use() == 0
    # destination too small: refuses WITHOUT corrupting state
    small = PK.init_paged(cfg, 1, 1, block_size=8, dtype="float32",
                          max_len=64)
    with pytest.raises(PK.OutOfBlocks):
        PK.import_blocks(small, 0, payload)
    assert small.blocks_in_use() == 0


def test_migrate_blocks_cost_model(tiny):
    """migrate_blocks (the pool-slice extension of migrate_by_path) moves
    the right bytes and its measured time matches the calibrated
    estimate_cost (core.migration.fit_migration_model — shared with
    benchmarks/module_scaling_bench.py) within 2x: Table-2 acceptance."""
    from repro.core.migration import fit_migration_model, \
        probe_block_migration
    cfg, _ = tiny
    fit = fit_migration_model(cfg, block_size=8, small_tokens=16,
                              large_tokens=512)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    assert fit["probe_large"]["bytes"] == 2 * L * 512 * KV * hd * 4
    t_mid, b_mid = probe_block_migration(cfg, 128, block_size=8)
    est = estimate_cost(b_mid, fit["bandwidth_Bps"],
                        fixed_overhead_s=fit["fixed_overhead_s"])
    assert 0.5 * est <= t_mid <= 2.0 * est, \
        f"measured {t_mid:.6f}s vs estimate {est:.6f}s"


# --------------------------------------------------- migration determinism
@pytest.mark.parametrize("temperature,top_k", [(0.0, 0), (0.8, 16)])
def test_migration_token_identical(tiny, temperature, top_k):
    """Start decoding on instance A, migrate mid-stream to instance B:
    the full token sequence equals the unmigrated run — greedy AND
    sampled (counter-based Gumbel keys travel with the request)."""
    cfg, params = tiny
    specs = [RequestSpec(rid=i,
                         prompt=np.arange(2 + i, 12 + i, dtype=np.int32),
                         max_tokens=10,
                         sampling=SamplingParams(temperature=temperature,
                                                 top_k=top_k,
                                                 seed=7 + i))
             for i in range(2)]
    ref = _reference_outputs(cfg, params, specs)

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)  # control loop quiesced
    reqs = []
    for spec in specs:
        orch._home[spec.rid] = 0
        reqs.append(orch.engines[0].submit(spec))  # force both onto A
    for _ in range(4):                          # decode a few tokens on A
        orch.step()
    assert all(len(r.generated) >= 2 for r in reqs)
    recs = orch.migrate_requests(0, 1)
    assert len(recs) == 2 and all(r.resumed for r in recs)
    assert not orch.engines[0].active
    assert orch.engines[0].pstate.blocks_in_use() == 0   # nothing leaked
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0


def test_migration_full_destination_replays(tiny):
    """Destination pool too small for the blocks: the request is
    re-queued there (never dropped) and the replayed continuation is
    still token-identical."""
    cfg, params = tiny
    spec = RequestSpec(rid=0, prompt=np.arange(2, 18, dtype=np.int32),
                       max_tokens=8)
    ref = _reference_outputs(cfg, params, [spec])

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=1,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    orch.engines[0].submit(spec)
    for _ in range(3):
        orch.step()
    # shrink B's pool under the payload size: resume must fail cleanly
    orch.engines[1].pstate.free = orch.engines[1].pstate.free[:1]
    recs = orch.migrate_requests(0, 1)
    assert len(recs) == 1 and not recs[0].resumed
    assert len(orch.engines[1].queue) == 1
    orch.engines[1].pstate.free = list(range(24))  # pool recovers
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0


# ------------------------------------------------- end-to-end scaling demo
def test_burst_scale_up_then_drain_scale_down(tiny):
    """The ISSUE acceptance scenario: burst -> controller scale-up
    (replication degrees live on every instance) -> drain -> scale-down
    KV-block migration off an instance. Zero drops, token-identical
    outputs for every migrated request."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=30.0, telemetry_every=2)
    rng = np.random.default_rng(3)
    reqs = [RequestSpec(rid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=6 + i % 5)
                        .astype(np.int32),
                        max_tokens=8) for i in range(10)]
    for r in reqs[:6]:          # the burst wave
        orch.submit(r)
    for _ in range(12):
        orch.step()
    # scale-up happened and reached the LIVE engines
    assert any(a.startswith("scale-up") for a in orch.controller.log)
    assert sum(orch.plan.p) > cfg.num_layers
    for eng in orch.engines:
        assert eng.replication_degrees == tuple(orch.plan.p)

    for r in reqs[6:]:          # tail traffic, then consolidate
        orch.submit(r)
    for _ in range(3):
        orch.step()
    src = max(range(2), key=lambda i: len(orch.engines[i].active))
    if orch.engines[src].active:
        recs = orch.drain_instance(src)
        assert recs, "drain moved no requests"
        assert not orch.engines[src].active
    done = {r.rid: r.generated for r in orch.run_until_done()}

    assert len(done) + len({r.rid for r in orch.finished} - set(done)) \
        >= len(reqs)  # every submitted request finished somewhere
    assert orch.dropped == 0
    migrated = {m.rid for m in orch.migrations}
    assert migrated, "scenario exercised no migration"
    all_done = {r.rid: r.generated for r in orch.finished}
    ref = _reference_outputs(cfg, params,
                             [r for r in reqs if r.rid in migrated])
    for rid in migrated:
        assert all_done[rid] == ref[rid], f"rid {rid} diverged"


def test_controller_scale_down_triggers_block_migration(tiny):
    """A violation snapshot drives Controller -> ScaleDownResult
    .migrations -> orchestrator executes REAL block transfers."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=5.0, telemetry_every=10_000)
    orch.engines[0].submit(
        RequestSpec(rid=0, prompt=np.arange(2, 10, dtype=np.int32),
                    max_tokens=16))
    orch._home[0] = 0
    for _ in range(3):
        orch.step()
    # inject a violating snapshot: instance 0 hot, instance 1 idle
    orch.controller.observe(MetricsSnapshot(
        t=orch.engines[0].clock, slo_violation_rate=1.0,
        device_util=[1.0, 0.0], device_mem_frac=[0.9, 0.0],
        block_vacancy=[0.1, 1.0]))
    action = orch.controller.tick()
    assert action and action.startswith("scale-down")
    assert orch.controller.last_scale_down.migrations
    orch._execute_scale_down()
    assert orch.migrations and orch.migrations[0].src == 0
    assert len(orch.engines[1].active) == 1
    done = orch.run_until_done()
    assert {r.rid for r in done} == {0}
    assert orch.dropped == 0


# ----------------------------------------------- overlapped (two-phase)
@pytest.mark.parametrize("temperature,top_k", [(0.0, 0), (0.8, 16)])
def test_overlapped_migration_token_identical(tiny, temperature, top_k):
    """Two-phase migration: the bulk snapshot stages at the destination
    while the source KEEPS DECODING (no stall in phase 1 — asserted via
    token accounting: the victims decode on every overlap step), then
    the pause-copy-resume delta ships only the dirty set. Streams stay
    token-identical, greedy AND sampled, and the source loses at most
    the single step in which its delta is copied (phase 2 runs between
    engine steps by construction)."""
    cfg, params = tiny
    specs = [RequestSpec(rid=i,
                         prompt=np.arange(2 + i, 12 + i, dtype=np.int32),
                         max_tokens=14,
                         sampling=SamplingParams(temperature=temperature,
                                                 top_k=top_k,
                                                 seed=7 + i))
             for i in range(2)]
    ref = _reference_outputs(cfg, params, specs)

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    reqs = []
    for spec in specs:
        orch._home[spec.rid] = 0
        reqs.append(orch.engines[0].submit(spec))
    for _ in range(4):
        orch.step()
    gen_before = {r.rid: len(r.generated) for r in reqs}
    recs = orch.migrate_requests_overlapped(0, 1, overlap_steps=3)
    assert len(recs) == 2 and all(r.resumed for r in recs)
    assert all(r.mode == "overlapped" for r in recs)
    # phase 1 did not stall the source: every overlap step decoded —
    # the victims each gained exactly overlap_steps tokens in between
    for r in reqs:
        assert len(r.generated) == gen_before[r.rid] + 3, \
            (r.rid, gen_before[r.rid], len(r.generated))
    # ... and those steps are what the phase-2 delta shipped
    assert all(r.delta_blocks >= 1 for r in recs)
    assert all(r.delta_bytes < r.bytes_moved for r in recs)
    assert not orch.engines[0].active
    assert orch.engines[0].pstate.blocks_in_use() == 0   # nothing leaked
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0


def test_overlapped_migration_victim_finishes_during_overlap(tiny):
    """A victim that FINISHES at the source between phase 1 and phase 2
    aborts its staging cleanly: nothing moves, nothing leaks, nothing
    drops."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=1,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    req = orch.engines[0].submit(
        RequestSpec(rid=0, prompt=np.arange(2, 10, dtype=np.int32),
                    max_tokens=4))
    orch.step()                       # admitted (+1 admission token)
    ticket = orch.begin_migration(0, 1, req.slot)
    for _ in range(6):                # finishes at the source meanwhile
        orch.step()
    assert req.done
    assert orch.finish_migration(ticket) is None
    assert orch.engines[1].pstate.blocks_in_use() == 0   # staging freed
    assert not orch.engines[1]._staged
    assert orch.dropped == 0


def test_overlapped_migration_staging_failure_replays(tiny):
    """Destination pool too small for the phase-1 snapshot: staging
    fails, the finish falls back to pause + re-queue at the destination,
    and the replayed continuation is token-identical — zero-drop under
    pressure."""
    cfg, params = tiny
    spec = RequestSpec(rid=0, prompt=np.arange(2, 18, dtype=np.int32),
                       max_tokens=8)
    ref = _reference_outputs(cfg, params, [spec])
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=1,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    orch.engines[0].submit(spec)
    for _ in range(3):
        orch.step()
    orch.engines[1].pstate.free = orch.engines[1].pstate.free[:1]
    recs = orch.migrate_requests_overlapped(0, 1)
    assert len(recs) == 1 and not recs[0].resumed
    assert len(orch.engines[1].queue) == 1
    orch.engines[1].pstate.free = list(range(24))  # pool recovers
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0


# --------------------------------------------- controller burst feedback
def test_control_tick_iterates_scale_down_phases(tiny):
    """Alg. 2 feedback within a burst: after a scale-down remediation
    executes, control_tick re-measures (the post-action snapshot is fed
    back through Controller.observe) and lets Alg. 2 run further phases
    in the SAME call — stopping when a phase moves nothing. The monitor
    history length is the witness that post-action snapshots were
    actually observed."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=4,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=1e-9,    # everything violates
                        telemetry_every=10_000, max_phases=3)
    # two short requests finish fast (latency > 0 > SLO: the violation
    # signal) while two long ones stay mid-decode (the migrants)
    for i, max_new in enumerate((2, 2, 30, 30)):
        orch._home[i] = 0
        orch.engines[0].submit(
            RequestSpec(rid=i, prompt=np.arange(2, 10, dtype=np.int32),
                        max_tokens=max_new))
    for _ in range(5):
        orch.step()
    assert any(r.done for r in orch.finished)
    assert orch.engines[0].active
    hist0 = len(orch.monitor.history)
    log0 = len(orch.controller.log)
    action = orch.control_tick()
    assert action and action.startswith("scale-down")
    n_obs = len(orch.monitor.history) - hist0
    n_actions = len(orch.controller.log) - log0
    assert n_obs >= 2, "no post-action snapshot was fed back"
    assert n_actions == n_obs or n_obs == orch.max_phases, \
        (n_actions, n_obs)
    # burst iteration bypasses the cooldown gate but arms it ONCE
    assert orch.controller._cooldown == orch.controller.cfg.cooldown_ticks
    orch.run_until_done()
    assert {r.rid for r in orch.finished} == {0, 1, 2, 3}
    assert orch.dropped == 0


def test_control_tick_burst_stops_when_nothing_moves(tiny):
    """The feedback loop's termination: a scale-down whose execution
    migrates zero requests ends the burst after one phase."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=1e-9, telemetry_every=10_000)
    orch.submit(RequestSpec(rid=0,
                            prompt=np.arange(2, 10, dtype=np.int32),
                            max_tokens=2))
    orch.run_until_done()             # finished: nothing active anywhere
    hist0 = len(orch.monitor.history)
    action = orch.control_tick()
    if action is not None:            # violation observed, nothing to move
        assert len(orch.monitor.history) - hist0 == 1
    assert orch.dropped == 0


# ------------------------------------------------- sliding-window + paged
def test_swa_paged_matches_dense_across_window_boundary(tiny):
    """Sliding-window archs now run PAGED: ragged prompt lengths decode
    across the window boundary with outputs identical to the dense ring
    buffer, while out-of-window blocks return to the pool."""
    cfg, params = tiny
    swa_cfg = dataclasses.replace(cfg, sliding_window=16)
    rng = np.random.default_rng(4)
    # ragged lengths straddling the window: some prompts shorter than the
    # window, one longer; generation crosses the boundary for all
    prompts = [rng.integers(2, cfg.vocab_size, size=s).astype(np.int32)
               for s in (6, 11, 20)]

    def run(kind):
        e = Engine(swa_cfg, params, max_batch=2, max_len=64, swa=True,
                   cache_kind=kind,
                   **({"block_size": 4} if kind == "paged" else {}))
        for i, p in enumerate(prompts):
            e.submit(RequestSpec(rid=i, prompt=p, max_tokens=10))
        done = e.run_until_done()
        return {r.rid: r.generated for r in done}, e

    dense, _ = run("dense")
    paged, eng = run("paged")
    assert paged == dense
    assert eng.pstate.blocks_in_use() == 0    # all blocks returned
    assert eng.window == 16


def test_swa_paged_admits_prompt_longer_than_window(tiny):
    """A prompt far longer than the window fits a WINDOW-SIZED pool: only
    the live suffix is allocated/written at admission (out-of-window
    columns are skipped, not transiently resident), and the output still
    matches the dense ring buffer."""
    cfg, params = tiny
    swa_cfg = dataclasses.replace(cfg, sliding_window=16)
    prompt = np.asarray(
        np.random.default_rng(8).integers(2, cfg.vocab_size, size=40),
        np.int32)

    def run(kind, **kw):
        e = Engine(swa_cfg, params, max_batch=1, max_len=64, swa=True,
                   cache_kind=kind, **kw)
        e.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=6))
        return e.run_until_done()[0].generated, e

    # default n_blocks is window-sized (5 blocks at block_size=4): the
    # 40-token prompt only ever claims its in-window columns
    paged, eng = run("paged", block_size=4)
    assert eng.pstate.n_blocks < -(-(len(prompt) + 1) // 4)
    dense, _ = run("dense")
    assert paged == dense
    assert eng.pstate.blocks_in_use() == 0


def test_swa_paged_frees_leading_blocks(tiny):
    """The reclamation itself: with window 8 and block_size 4, a long
    generation holds a BOUNDED number of live blocks while the block
    table keeps absolute-position columns (leading holes)."""
    cfg, params = tiny
    swa_cfg = dataclasses.replace(cfg, sliding_window=8)
    e = Engine(swa_cfg, params, max_batch=1, max_len=64, swa=True,
               cache_kind="paged", block_size=4, n_blocks=16)
    e.submit(RequestSpec(rid=0, prompt=np.arange(2, 12, dtype=np.int32),
                     max_tokens=24))
    max_live = 0
    while e.queue or e.active:
        e.step()
        max_live = max(max_live, e.pstate.blocks_in_use())
    # window 8 spans <= 3 live blocks (+1 write headroom)
    assert max_live <= 4, f"held {max_live} blocks for window 8"
    assert e.pstate.blocks_in_use() == 0


# --------------------------------------------------- prefill pow2 buckets
def test_prefill_bucketing_bounds_executables(tiny):
    """Admission compiles one executable per power-of-two bucket, not one
    per (group, prompt-len) pair — and outputs still match dense."""
    cfg, params = tiny
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 6, 7, 8, 9, 11, 13, 15)]

    def run(kind):
        e = Engine(cfg, params, max_batch=8, max_len=64, cache_kind=kind,
                   **({"block_size": 8} if kind == "paged" else {}))
        for i, p in enumerate(prompts):
            e.submit(RequestSpec(rid=i, prompt=p, max_tokens=4))
        done = e.run_until_done()
        return {r.rid: r.generated for r in done}, e

    paged, eng = run("paged")
    dense, _ = run("dense")
    assert paged == dense
    # 8 distinct lengths, all admitted in one wave, collapse to exactly
    # two padded shapes: (4, 8) for lengths 5-8 and (4, 16) for 9-15
    shapes = eng._prefill_shapes
    assert len(shapes) <= 2, f"bucketing leaked shapes: {shapes}"
    assert all((S & (S - 1)) == 0 for _, S in shapes), shapes


def test_apply_plan_is_token_invariant(tiny):
    """Replication degrees change WHERE the batch computes, not WHAT:
    flipping a live engine between scan and unrolled-hook decode steps
    mid-stream leaves the token stream untouched."""
    cfg, params = tiny
    prompt = np.arange(2, 10, dtype=np.int32)
    ref_e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
    ref_e.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=10))
    ref = ref_e.run_until_done()[0].generated

    e = Engine(cfg, params, max_batch=1, max_len=64, cache_kind="paged",
               block_size=8)
    e.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=10))
    out = []
    for i in range(40):
        if i == 3:      # scale up mid-decode
            e.apply_plan([2] * cfg.num_layers)
            assert e._step_degrees is not None
        if i == 6:      # and back down
            e.apply_plan([1] * cfg.num_layers)
            assert e._step_degrees is None
        out += e.step() or []
        if not (e.queue or e.active):
            break
    assert out[0].generated == ref
    with pytest.raises(ValueError):
        Engine(cfg, params, max_batch=1, max_len=64).apply_plan(
            [2] * cfg.num_layers)
