"""Per-arch smoke tests (reduced configs) + decode-path equivalence.

Deliverable (f): every assigned architecture instantiates a reduced variant
(2 layers, d_model<=512, <=4 experts) and runs one forward/train step on CPU
asserting output shapes + no NaNs. Deeper: autoregressive decode must match
teacher-forced logits, the sliding-window ring cache must match windowed
full attention, and MoE dispatch paths must agree.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.training import optimizer as OPT
from repro.training import train as TR

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    enc = None
    if cfg.family == "audio":
        enc = jax.random.normal(KEY, (B, cfg.encoder_seq_len, cfg.d_model),
                                jnp.float32)
    return tokens, enc


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    tokens, enc = _inputs(cfg)
    logits, _, aux = T.forward(params, cfg, tokens, mode="train",
                               encoder_input=enc)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    opt = OPT.init_opt_state(params)
    step = TR.make_train_step(cfg, OPT.OptimizerConfig(lr=1e-3,
                                                       warmup_steps=1,
                                                       total_steps=10))
    tokens, enc = _inputs(cfg)
    batch = {"tokens": tokens, "labels": tokens}
    if enc is not None:
        batch["frames"] = enc
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    d0 = jax.tree_util.tree_leaves(params)[0]
    d1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    tokens, enc = _inputs(cfg)
    cache = T.init_cache(cfg, 2, 64, "float32")
    lg, cache, _ = T.forward(params, cfg, tokens, mode="prefill", cache=cache,
                             encoder_input=enc)
    assert lg.shape == (2, cfg.padded_vocab)
    pos = jnp.full((2, 1), 16, jnp.int32)
    lg2, cache, _ = T.forward(params, cfg, tokens[:, :1], positions=pos,
                              mode="decode", cache=cache)
    assert not bool(jnp.isnan(lg2).any())
    assert bool((cache["length"] == 17).all())


@pytest.mark.parametrize(
    "arch", ["tinyllama-1.1b", "minicpm3-4b", "mamba2-780m", "zamba2-7b",
             "qwen2-moe-a2.7b", "whisper-medium"])
def test_autoregressive_equivalence(arch):
    """prefill + step-by-step decode == teacher-forced forward."""
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    B, S0, N = 2, 8, 4
    tokens, enc = _inputs(cfg, B, S0 + N)
    full, _, _ = T.forward(params, cfg, tokens, mode="train",
                           encoder_input=enc)
    cache = T.init_cache(cfg, B, 64, "float32")
    lg, cache, _ = T.forward(params, cfg, tokens[:, :S0], mode="prefill",
                             cache=cache, encoder_input=enc)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S0 - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(N):
        pos = jnp.full((B, 1), S0 + i, jnp.int32)
        lg, cache, _ = T.forward(params, cfg, tokens[:, S0 + i:S0 + i + 1],
                                 positions=pos, mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, S0 + i]),
                                   rtol=3e-3, atol=3e-3)


def test_sliding_window_ring_cache():
    """Ring-buffer decode (cache smaller than history) == full cache with
    the same window mask."""
    cfg = get_config("tinyllama-1.1b").reduced()  # window=64 in reduced
    W = cfg.sliding_window
    assert W == 64
    params = T.init_params(cfg, KEY, "float32")
    B, S0 = 1, 96  # prompt longer than the window
    tokens = jax.random.randint(KEY, (B, S0 + 3), 0, cfg.vocab_size)

    # full cache, windowed attention
    big = T.init_cache(cfg, B, 128, "float32")
    lg_full, big, _ = T.forward(params, cfg, tokens[:, :S0], mode="prefill",
                                cache=big, window=W)
    # ring cache of exactly W rows
    ring = T.init_cache(cfg, B, W, "float32")
    lg_ring, ring, _ = T.forward(params, cfg, tokens[:, :S0], mode="prefill",
                                 cache=ring, window=W)
    np.testing.assert_allclose(np.asarray(lg_ring), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)
    for i in range(3):
        pos = jnp.full((B, 1), S0 + i, jnp.int32)
        lg_full, big, _ = T.forward(params, cfg, tokens[:, S0 + i:S0 + i + 1],
                                    positions=pos, mode="decode", cache=big,
                                    window=W)
        lg_ring, ring, _ = T.forward(params, cfg, tokens[:, S0 + i:S0 + i + 1],
                                     positions=pos, mode="decode", cache=ring,
                                     window=W)
        np.testing.assert_allclose(np.asarray(lg_ring), np.asarray(lg_full),
                                   rtol=2e-3, atol=2e-3)


def test_moe_dispatch_paths_agree():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    p = MOE.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32) * 0.5
    w, idx, aux = MOE.route(p, x, cfg)
    dense = MOE._moe_dense(p, x, w, idx, cfg)
    scat = MOE._moe_scatter(p, x, w, idx, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(scat),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) >= 1.0 - 1e-6  # balance loss lower bound at k-routing


def test_moe_padding_experts_never_selected():
    cfg = get_config("qwen2-moe-a2.7b").reduced()  # 4 experts padded to 16
    p = MOE.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(KEY, (4, 32, cfg.d_model), jnp.float32)
    _, idx, _ = MOE.route(p, x, cfg)
    assert int(idx.max()) < cfg.num_experts


def test_unroll_matches_scan():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    tokens, _ = _inputs(cfg)
    a, _, _ = T.forward(params, cfg, tokens, mode="train")
    b, _, _ = T.forward(params, cfg, tokens, mode="train", unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_remat_matches_no_remat():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    tokens, _ = _inputs(cfg)
    batch = {"tokens": tokens, "labels": tokens}
    for remat in (False, True):
        loss_fn = TR.make_loss_fn(cfg, remat=remat)
        val, _ = loss_fn(params, batch)
        if remat:
            np.testing.assert_allclose(float(val), first, rtol=1e-6)
        else:
            first = float(val)
