"""Property tests for the token-budget scheduler's invariants
(serving/scheduler.py) over GENERATED engine states and multi-step
traces, for both the FIFO ``TokenBudgetScheduler`` and the class-aware
``SloScheduler``:

* decode-never-stalled — every active slot is charged exactly one token
  before any prefill work, no matter the queue pressure;
* budget never exceeded — grants fit in ``budget - n_decode`` (decode
  itself may exceed a tiny budget by design: running streams never skip);
* block-aligned chunks — a non-final grant is a multiple of the block
  size, so a persisted prefill cursor always sits on a block boundary;
* FIFO admission, slot accounting, and liveness (a trace drains).

The scheduler is pure policy over a narrow engine surface, so the tests
drive it with a fake engine — no JAX, no pools. Runs under the real
``hypothesis`` package when importable (the nightly CI job) and under
tests/_hypothesis_stub.py otherwise (tier-1): only ``given``/
``settings`` and the integers/floats/lists/tuples strategies are used.
"""
from hypothesis import given, settings
from hypothesis import strategies as st


class FakeReq:
    _n = 0

    def __init__(self, total):
        FakeReq._n += 1
        self.rid = FakeReq._n
        self.total = total
        self.prefill_pos = 0


class FakeEngine:
    """The exact surface TokenBudgetScheduler.plan reads."""

    def __init__(self, max_batch, n_active, prefilling, queue):
        self.max_batch = max_batch
        self.active = {s: FakeReq(1) for s in range(n_active)}
        self.prefilling = {}
        self._admit_order = list(self.active)
        slot = n_active
        for total, pos in prefilling:
            r = FakeReq(total)
            r.prefill_pos = pos
            self.prefilling[slot] = r
            self._admit_order.append(slot)
            slot += 1
        self.queue = [FakeReq(t) for t in queue]

    def _free_slots(self):
        used = len(self.active) + len(self.prefilling)
        return list(range(max(0, self.max_batch - used)))

    def prefill_total(self, req):
        return req.total


def _mk(budget, align, n_active, prefill_totals, queue_totals):
    from repro.serving.scheduler import TokenBudgetScheduler
    # mid-prefill cursors sit on block boundaries (the invariant under
    # test preserves it; the generator must establish it)
    prefilling = []
    for i, t in enumerate(prefill_totals):
        pos = min((i % 3) * align, max(t - 1, 0))
        pos -= pos % align
        prefilling.append((t, pos))
    eng = FakeEngine(n_active + len(prefilling) + 2, n_active,
                     prefilling, queue_totals)
    return TokenBudgetScheduler(budget, chunk_align=align), eng


WORKLOADS = dict(
    budget=st.integers(1, 256),
    align=st.integers(1, 32),
    n_active=st.integers(0, 12),
    prefill_totals=st.lists(st.integers(1, 300), min_size=0, max_size=6),
    queue_totals=st.lists(st.integers(1, 300), min_size=0, max_size=8),
)


@settings(max_examples=60, deadline=None)
@given(**WORKLOADS)
def test_single_step_invariants(budget, align, n_active, prefill_totals,
                                queue_totals):
    sched, eng = _mk(budget, align, n_active, prefill_totals,
                     queue_totals)
    plan = sched.plan(eng)

    # decode never stalled: one token per active slot, charged first
    assert plan.n_decode == len(eng.active)
    # budget never exceeded by grants (decode itself may overflow a tiny
    # budget — by design)
    granted = sum(g.n_tokens for g in plan.grants)
    assert granted <= max(0, budget - plan.n_decode)
    if plan.n_decode <= budget:
        assert plan.packed <= budget

    fresh = [g for g in plan.grants if g.slot is None]
    for g in plan.grants:
        assert g.n_tokens >= 1
        total = eng.prefill_total(g.req)
        assert g.start + g.n_tokens <= total
        assert g.final == (g.start + g.n_tokens == total)
        # block-aligned chunks: a NON-final grant ends on a boundary
        if not g.final:
            assert g.n_tokens % align == 0
            assert (g.start + g.n_tokens) % align == 0
        if g.slot is None:
            assert g.start == 0
        else:
            assert g.start == eng.prefilling[g.slot].prefill_pos

    # fresh admissions: FIFO prefix of the queue, never past free slots,
    # at most the LAST one partial
    assert [g.req.rid for g in fresh] == \
        [r.rid for r in eng.queue[:len(fresh)]]
    assert len(fresh) <= len(eng._free_slots())
    assert sum(1 for g in fresh if not g.final) <= 1
    if fresh and not fresh[-1].final:
        assert all(g.final for g in fresh[:-1])

    # continuations come oldest-first, before any fresh admission
    cont_slots = [g.slot for g in plan.grants if g.slot is not None]
    order = [s for s in eng._admit_order if s in eng.prefilling]
    assert cont_slots == [s for s in order if s in cont_slots]
    assert plan.grants[:len(cont_slots)] == \
        [g for g in plan.grants if g.slot is not None]


@settings(max_examples=25, deadline=None)
@given(budget=st.integers(8, 128), align=st.integers(1, 16),
       prefill_totals=st.lists(st.integers(1, 200), min_size=1,
                               max_size=5),
       queue_totals=st.lists(st.integers(1, 200), min_size=0,
                             max_size=5))
def test_trace_drains_with_invariants_held(budget, align, prefill_totals,
                                           queue_totals):
    """Liveness: executing plans step after step (decodes retire after 4
    tokens, finals enter decode) drains every request, with the cursor
    staying block-aligned the whole way. Budget >= align, as in any real
    engine (token_budget >= block_size) — a sub-block budget cannot
    grant a first chunk at all."""
    budget = max(budget, align)
    sched, eng = _mk(budget, align, 0, [],
                     prefill_totals + queue_totals)
    decoded = {}
    done = set()
    next_slot = 1000
    for step in range(10_000):
        if not (eng.active or eng.prefilling or eng.queue):
            break
        plan = sched.plan(eng)
        assert plan.n_decode == len(eng.active)
        for slot, r in list(eng.active.items()):
            decoded[r.rid] = decoded.get(r.rid, 0) + 1
            if decoded[r.rid] >= 4:
                done.add(r.rid)
                del eng.active[slot]
                eng._admit_order.remove(slot)
        progressed = bool(plan.n_decode)
        for g in plan.grants:
            slot = g.slot
            if slot is None:                  # engine pops the head
                assert eng.queue and eng.queue[0] is g.req
                eng.queue.pop(0)
                slot = next_slot = next_slot + 1
                eng.prefilling[slot] = g.req
                eng._admit_order.append(slot)
            assert g.req.prefill_pos == g.start
            g.req.prefill_pos += g.n_tokens
            if not g.final:
                assert g.req.prefill_pos % align == 0
            else:
                assert g.req.prefill_pos == g.req.total
                del eng.prefilling[slot]
                eng.active[slot] = g.req
            progressed = True
        assert progressed, "scheduler stalled with work outstanding"
    assert not (eng.active or eng.prefilling or eng.queue)
    assert len(done) == len(prefill_totals) + len(queue_totals)


# ===================================================== SLO scheduler
# The SloScheduler shares the budget packer's mechanics, so everything
# above still holds for it; these tests pin the CLASS-aware invariants:
# strict-priority splits that sum to the granted prefill, interactive
# never stalled behind batch admissions, stable deadline ordering, and
# batch-first preemption.

CLASSES = ("interactive", "standard", "batch")


def _slo_req(total, cls_i, dl):
    r = FakeReq(total)
    r.slo_class = CLASSES[cls_i]
    r.deadline_ms = None if dl == 0 else float(dl * 100)
    return r


def _mk_slo(budget, align, actives, prefilling, queue):
    """actives: [cls_i]; prefilling: [(total, cls_i)]; queue:
    [(total, cls_i, dl)] — dl 0 means deadline-less."""
    from repro.serving.scheduler import SloScheduler
    eng = FakeEngine(len(actives) + len(prefilling) + 2, 0, [], [])
    for s, cls_i in enumerate(actives):
        eng.active[s] = _slo_req(1, cls_i, 0)
        eng._admit_order.append(s)
    slot = len(actives)
    for i, (total, cls_i) in enumerate(prefilling):
        r = _slo_req(total, cls_i, 0)
        pos = min((i % 3) * align, max(total - 1, 0))
        r.prefill_pos = pos - pos % align
        eng.prefilling[slot] = r
        eng._admit_order.append(slot)
        slot += 1
    eng.queue = [_slo_req(t, c, d) for t, c, d in queue]
    return SloScheduler(budget, chunk_align=align), eng


SLO_WORKLOADS = dict(
    budget=st.integers(1, 256),
    align=st.integers(1, 32),
    actives=st.lists(st.integers(0, 2), min_size=0, max_size=8),
    prefilling=st.lists(
        st.tuples(st.integers(1, 300), st.integers(0, 2)),
        min_size=0, max_size=6),
    queue=st.lists(
        st.tuples(st.integers(1, 300), st.integers(0, 2),
                  st.integers(0, 5)),
        min_size=0, max_size=8),
)


@settings(max_examples=60, deadline=None)
@given(**SLO_WORKLOADS)
def test_slo_single_step_invariants(budget, align, actives, prefilling,
                                    queue):
    sched, eng = _mk_slo(budget, align, actives, prefilling, queue)
    plan = sched.plan(eng)

    # decode never stalled — regardless of class mix or queue pressure
    assert plan.n_decode == len(eng.active)
    granted = sum(g.n_tokens for g in plan.grants)
    assert granted <= max(0, budget - plan.n_decode)

    # the class split is an exact account of the granted prefill
    assert sum(plan.class_tokens.values()) == granted
    assert all(v >= 0 for v in plan.class_tokens.values())

    # chunk mechanics carry over from the budget packer
    fresh = [g for g in plan.grants if g.slot is None]
    for g in plan.grants:
        assert g.n_tokens >= 1
        total = eng.prefill_total(g.req)
        assert g.start + g.n_tokens <= total
        assert g.final == (g.start + g.n_tokens == total)
        if not g.final:
            assert g.n_tokens % align == 0
    assert sum(1 for g in fresh if not g.final) <= 1
    assert len(fresh) <= len(eng._free_slots())

    # strict priority: a fresh grant for a class means every waiting
    # request of every HIGHER class was admitted this step — batch can
    # never jump an interactive request stuck at the head of its class
    fresh_rids = {g.req.rid for g in fresh}
    for i, cls in enumerate(CLASSES):
        if any(g.slot is None and g.req.slo_class == cls
               for g in plan.grants):
            for higher in CLASSES[:i]:
                assert all(r.rid in fresh_rids for r in eng.queue
                           if r.slo_class == higher), \
                    f"{cls} admitted past waiting {higher} work"

    # deadline ordering within a class is stable: granted fresh
    # requests appear earliest-deadline first, deadline-less last,
    # FIFO among ties
    for cls in CLASSES:
        cls_fresh = [g.req for g in fresh if g.req.slo_class == cls]
        keys = [sched._deadline_key(r) for r in cls_fresh]
        assert keys == sorted(keys), f"{cls} fresh grants out of order"

    # preemption: the tail of victims() is always the youngest batch
    # work; an interactive slot never outranks any batch slot
    vs = sched.victims(eng)

    def cls_of(s):
        r = eng.active.get(s) or eng.prefilling.get(s)
        return CLASSES.index(r.slo_class)

    assert [cls_of(s) for s in vs] == sorted(cls_of(s) for s in vs)
    for i, cls in enumerate(CLASSES):
        same = [s for s in vs if cls_of(s) == i]
        order = [s for s in eng._admit_order if s in same]
        assert same == order, "admit order not preserved within class"


def test_slo_interactive_decode_never_stalled_by_batch_backlog():
    """Deterministic pin of the headline invariant: interactive decodes
    get their token even when a batch prefill backlog could absorb the
    whole budget many times over."""
    sched, eng = _mk_slo(
        16, 8,
        actives=[0, 0, 0],                       # 3 interactive decodes
        prefilling=[(300, 2), (300, 2)],         # huge batch backlog
        queue=[(300, 2, 0)] * 4)
    plan = sched.plan(eng)
    assert plan.n_decode == 3
    assert sum(g.n_tokens for g in plan.grants) <= 16 - 3
    assert plan.class_tokens["interactive"] == 0


def test_slo_batch_spill_is_work_conserving():
    """An idle interactive class donates its whole share down: with no
    interactive/standard work at all, batch gets the full leftover."""
    sched, eng = _mk_slo(64, 8, actives=[], prefilling=[],
                         queue=[(24, 2, 0), (24, 2, 0)])
    plan = sched.plan(eng)
    assert plan.class_tokens["batch"] == 48
    assert all(g.final for g in plan.grants)


@settings(max_examples=25, deadline=None)
@given(budget=st.integers(8, 128), align=st.integers(1, 16),
       queue=st.lists(
           st.tuples(st.integers(1, 200), st.integers(0, 2),
                     st.integers(0, 5)),
           min_size=1, max_size=8))
def test_slo_trace_drains(budget, align, queue):
    """Liveness under the class-aware packer: mixed-class traces drain
    completely — strict priority starves nothing forever because
    admitted work always finishes and frees its slot."""
    budget = max(budget, align)
    sched, eng = _mk_slo(budget, align, [], [], queue)
    decoded = {}
    next_slot = 1000
    for step in range(10_000):
        if not (eng.active or eng.prefilling or eng.queue):
            break
        plan = sched.plan(eng)
        assert plan.n_decode == len(eng.active)
        for slot, r in list(eng.active.items()):
            decoded[r.rid] = decoded.get(r.rid, 0) + 1
            if decoded[r.rid] >= 4:
                del eng.active[slot]
                eng._admit_order.remove(slot)
        progressed = bool(plan.n_decode)
        for g in plan.grants:
            slot = g.slot
            if slot is None:
                eng.queue.remove(g.req)
                slot = next_slot = next_slot + 1
                eng.prefilling[slot] = g.req
                eng._admit_order.append(slot)
            g.req.prefill_pos += g.n_tokens
            if g.final:
                del eng.prefilling[slot]
                eng.active[slot] = g.req
            progressed = True
        assert progressed, "slo scheduler stalled with work outstanding"
    assert not (eng.active or eng.prefilling or eng.queue)
