"""Property tests for the token-budget scheduler's invariants
(serving/scheduler.py) over GENERATED engine states and multi-step
traces:

* decode-never-stalled — every active slot is charged exactly one token
  before any prefill work, no matter the queue pressure;
* budget never exceeded — grants fit in ``budget - n_decode`` (decode
  itself may exceed a tiny budget by design: running streams never skip);
* block-aligned chunks — a non-final grant is a multiple of the block
  size, so a persisted prefill cursor always sits on a block boundary;
* FIFO admission, slot accounting, and liveness (a trace drains).

The scheduler is pure policy over a narrow engine surface, so the tests
drive it with a fake engine — no JAX, no pools. Runs under the real
``hypothesis`` package when importable (the nightly CI job) and under
tests/_hypothesis_stub.py otherwise (tier-1): only ``given``/
``settings`` and the integers/floats/lists strategies are used.
"""
from hypothesis import given, settings
from hypothesis import strategies as st


class FakeReq:
    _n = 0

    def __init__(self, total):
        FakeReq._n += 1
        self.rid = FakeReq._n
        self.total = total
        self.prefill_pos = 0


class FakeEngine:
    """The exact surface TokenBudgetScheduler.plan reads."""

    def __init__(self, max_batch, n_active, prefilling, queue):
        self.max_batch = max_batch
        self.active = {s: FakeReq(1) for s in range(n_active)}
        self.prefilling = {}
        self._admit_order = list(self.active)
        slot = n_active
        for total, pos in prefilling:
            r = FakeReq(total)
            r.prefill_pos = pos
            self.prefilling[slot] = r
            self._admit_order.append(slot)
            slot += 1
        self.queue = [FakeReq(t) for t in queue]

    def _free_slots(self):
        used = len(self.active) + len(self.prefilling)
        return list(range(max(0, self.max_batch - used)))

    def prefill_total(self, req):
        return req.total


def _mk(budget, align, n_active, prefill_totals, queue_totals):
    from repro.serving.scheduler import TokenBudgetScheduler
    # mid-prefill cursors sit on block boundaries (the invariant under
    # test preserves it; the generator must establish it)
    prefilling = []
    for i, t in enumerate(prefill_totals):
        pos = min((i % 3) * align, max(t - 1, 0))
        pos -= pos % align
        prefilling.append((t, pos))
    eng = FakeEngine(n_active + len(prefilling) + 2, n_active,
                     prefilling, queue_totals)
    return TokenBudgetScheduler(budget, chunk_align=align), eng


WORKLOADS = dict(
    budget=st.integers(1, 256),
    align=st.integers(1, 32),
    n_active=st.integers(0, 12),
    prefill_totals=st.lists(st.integers(1, 300), min_size=0, max_size=6),
    queue_totals=st.lists(st.integers(1, 300), min_size=0, max_size=8),
)


@settings(max_examples=60, deadline=None)
@given(**WORKLOADS)
def test_single_step_invariants(budget, align, n_active, prefill_totals,
                                queue_totals):
    sched, eng = _mk(budget, align, n_active, prefill_totals,
                     queue_totals)
    plan = sched.plan(eng)

    # decode never stalled: one token per active slot, charged first
    assert plan.n_decode == len(eng.active)
    # budget never exceeded by grants (decode itself may overflow a tiny
    # budget — by design)
    granted = sum(g.n_tokens for g in plan.grants)
    assert granted <= max(0, budget - plan.n_decode)
    if plan.n_decode <= budget:
        assert plan.packed <= budget

    fresh = [g for g in plan.grants if g.slot is None]
    for g in plan.grants:
        assert g.n_tokens >= 1
        total = eng.prefill_total(g.req)
        assert g.start + g.n_tokens <= total
        assert g.final == (g.start + g.n_tokens == total)
        # block-aligned chunks: a NON-final grant ends on a boundary
        if not g.final:
            assert g.n_tokens % align == 0
            assert (g.start + g.n_tokens) % align == 0
        if g.slot is None:
            assert g.start == 0
        else:
            assert g.start == eng.prefilling[g.slot].prefill_pos

    # fresh admissions: FIFO prefix of the queue, never past free slots,
    # at most the LAST one partial
    assert [g.req.rid for g in fresh] == \
        [r.rid for r in eng.queue[:len(fresh)]]
    assert len(fresh) <= len(eng._free_slots())
    assert sum(1 for g in fresh if not g.final) <= 1
    if fresh and not fresh[-1].final:
        assert all(g.final for g in fresh[:-1])

    # continuations come oldest-first, before any fresh admission
    cont_slots = [g.slot for g in plan.grants if g.slot is not None]
    order = [s for s in eng._admit_order if s in eng.prefilling]
    assert cont_slots == [s for s in order if s in cont_slots]
    assert plan.grants[:len(cont_slots)] == \
        [g for g in plan.grants if g.slot is not None]


@settings(max_examples=25, deadline=None)
@given(budget=st.integers(8, 128), align=st.integers(1, 16),
       prefill_totals=st.lists(st.integers(1, 200), min_size=1,
                               max_size=5),
       queue_totals=st.lists(st.integers(1, 200), min_size=0,
                             max_size=5))
def test_trace_drains_with_invariants_held(budget, align, prefill_totals,
                                           queue_totals):
    """Liveness: executing plans step after step (decodes retire after 4
    tokens, finals enter decode) drains every request, with the cursor
    staying block-aligned the whole way. Budget >= align, as in any real
    engine (token_budget >= block_size) — a sub-block budget cannot
    grant a first chunk at all."""
    budget = max(budget, align)
    sched, eng = _mk(budget, align, 0, [],
                     prefill_totals + queue_totals)
    decoded = {}
    done = set()
    next_slot = 1000
    for step in range(10_000):
        if not (eng.active or eng.prefilling or eng.queue):
            break
        plan = sched.plan(eng)
        assert plan.n_decode == len(eng.active)
        for slot, r in list(eng.active.items()):
            decoded[r.rid] = decoded.get(r.rid, 0) + 1
            if decoded[r.rid] >= 4:
                done.add(r.rid)
                del eng.active[slot]
                eng._admit_order.remove(slot)
        progressed = bool(plan.n_decode)
        for g in plan.grants:
            slot = g.slot
            if slot is None:                  # engine pops the head
                assert eng.queue and eng.queue[0] is g.req
                eng.queue.pop(0)
                slot = next_slot = next_slot + 1
                eng.prefilling[slot] = g.req
                eng._admit_order.append(slot)
            assert g.req.prefill_pos == g.start
            g.req.prefill_pos += g.n_tokens
            if not g.final:
                assert g.req.prefill_pos % align == 0
            else:
                assert g.req.prefill_pos == g.req.total
                del eng.prefilling[slot]
                eng.active[slot] = g.req
            progressed = True
        assert progressed, "scheduler stalled with work outstanding"
    assert not (eng.active or eng.prefilling or eng.queue)
    assert len(done) == len(prefill_totals) + len(queue_totals)
