"""CoCoServe core: plan invariants, speedup model (Eqs. 1-4), Algorithm 1/2,
controller loop — with hypothesis property tests on the key invariants."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.cluster import Cluster
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.core.scale_down import scale_down, sort_evictees
from repro.core.scale_up import scale_up, sort_candidates_by_continuity
from repro.core.speedup import (SpeedupModelConfig, gamma_of, speedup,
                                speedup_homo, t_of)


# --------------------------------------------------------------------- plan
def test_plan_basics():
    p = PlacementPlan.initial(8)
    assert p.p == [1] * 8
    assert p.continuity_breaks() == 0
    p.add_replica(2, 1)
    p.add_replica(3, 1)
    assert p.p[2] == p.p[3] == 2
    assert p.continuity_breaks() == 2      # enter at 2, leave after 3
    p.add_replica(5, 1)
    assert p.continuity_breaks() == 4      # two separate runs
    assert p.evict_replica(5, 1)
    assert p.continuity_breaks() == 2


def test_plan_migration_tracking():
    p = PlacementPlan.initial(4)
    p.migrate(1, "kv_cache", 2)
    p.migrate(2, "layer", 3)
    assert p.device_set(2) == (3,)
    assert 2 in p.layers_on_device(3)
    assert set(p.devices_used()) == {0, 2, 3}


# ------------------------------------------------------------------ speedup
@given(st.lists(st.integers(1, 8), min_size=1, max_size=64),
       st.floats(0.0, 0.5))
@settings(max_examples=60, deadline=None)
def test_speedup_homo_bounds(p, gamma):
    """1 <= S_homo(P) <= 1/gamma-ish and S(P0) == 1."""
    s = speedup_homo(p, gamma)
    assert s >= 1.0 - 1e-9 or max(p) == 1
    assert speedup_homo([1] * len(p), gamma) == pytest.approx(1.0)


@given(st.lists(st.integers(1, 8), min_size=2, max_size=32),
       st.integers(0, 31), st.floats(0.01, 0.3))
@settings(max_examples=60, deadline=None)
def test_speedup_homo_monotone_in_p(p, idx, gamma):
    """Increasing any p_i never decreases S_homo (Eq. 4 monotonicity)."""
    idx = idx % len(p)
    s0 = speedup_homo(p, gamma)
    p2 = list(p)
    p2[idx] += 1
    assert speedup_homo(p2, gamma) >= s0 - 1e-12


def test_eq3_vs_eq4_consistency():
    """For contiguous full replication the exact Eq. 3 speedup and the
    homogeneous Eq. 4 closed form should roughly agree."""
    cluster = Cluster.homogeneous(4)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    g = gamma_of(cluster, m)
    plan = PlacementPlan.initial(40)
    for i in range(40):
        for d in (1, 2, 3):
            plan.add_replica(i, d)
    s3 = speedup(plan, m, cluster)
    s4 = speedup_homo(plan.p, g)
    assert s3 > 2.0 and s4 > 2.0
    assert abs(s3 - s4) / s3 < 0.5


def test_t_of_rewards_continuity():
    """Fragmented plans must pay more communication than contiguous ones
    with the same replica count (the paper's continuity principle)."""
    cluster = Cluster.homogeneous(2)
    m = SpeedupModelConfig(d_model=4096, seq_len=256, batch_size=16)
    contiguous = PlacementPlan.initial(16)
    fragmented = PlacementPlan.initial(16)
    for i in range(4):
        contiguous.add_replica(i, 1)        # layers 0-3
        fragmented.add_replica(i * 4, 1)    # layers 0,4,8,12
    assert contiguous.continuity_breaks() < fragmented.continuity_breaks()
    assert t_of(contiguous, m, cluster) < t_of(fragmented, m, cluster)


# ------------------------------------------------------------------- Alg. 1
def test_scale_up_monotone_improvement():
    cluster = Cluster.homogeneous(4)
    plan = PlacementPlan.initial(40)
    out = scale_up(plan, cluster, gamma=0.05, replica_size=605e6)
    assert speedup_homo(out.p, 0.05) >= speedup_homo(plan.p, 0.05)
    assert max(out.p) <= 2  # default dop cap


def test_scale_up_respects_capacity():
    cluster = Cluster.homogeneous(4, mem_gb=2.0)  # room for ~3 layers
    plan = PlacementPlan.initial(40)
    out = scale_up(plan, cluster, gamma=0.05, replica_size=605e6)
    per_dev = {}
    for layer, reps in out.replicas.items():
        for d in reps:
            per_dev[d] = per_dev.get(d, 0) + 1
    for d, n in per_dev.items():
        assert n <= int(2.0 * 1024**3 // 605e6)


def test_scale_up_skips_home_device():
    cluster = Cluster.homogeneous(2)
    out = scale_up(PlacementPlan.initial(8), cluster, gamma=0.05,
                   replica_size=1e6)
    for reps in out.replicas.values():
        assert 0 not in reps


def test_continuity_sort_prefers_run_extension():
    plan = PlacementPlan.initial(16)
    for i in (4, 5, 6):
        plan.add_replica(i, 1)
    cands = sort_candidates_by_continuity(plan, 1, 4)
    assert set(cands[:2]) == {3, 7}  # extend the 4-6 run first


@given(st.integers(2, 6), st.integers(8, 48))
@settings(max_examples=20, deadline=None)
def test_scale_up_never_worsens(n_dev, n_layers):
    cluster = Cluster.homogeneous(n_dev)
    plan = PlacementPlan.initial(n_layers)
    g = 0.05
    out = scale_up(plan, cluster, gamma=g, replica_size=605e6)
    assert speedup_homo(out.p, g) >= 1.0


# ------------------------------------------------------------------- Alg. 2
def test_scale_down_phases_in_order():
    cluster = Cluster.homogeneous(4)
    plan = PlacementPlan.initial(8)
    plan.add_replica(0, 0)   # a replica on the hot device to evict
    calls = {"n": 0}

    def is_violating(p, bs):
        calls["n"] += 1
        return calls["n"] < 3  # resolves on the 3rd check

    res = scale_down(plan, cluster, src_device=0, is_violating=is_violating,
                     batch_size=16)
    assert res.resolved
    assert any(a.startswith("migrate") for a in res.actions)


def test_scale_down_batch_reduction_last_resort():
    cluster = Cluster.homogeneous(1)   # nowhere to migrate
    plan = PlacementPlan.initial(4)
    state = {"bs": None}

    def is_violating(p, bs):
        state["bs"] = bs
        return bs > 6

    res = scale_down(plan, cluster, src_device=0, is_violating=is_violating,
                     batch_size=16, delta_bs=5)
    assert res.resolved
    assert res.batch_size <= 6
    assert any("reduce batch" in a for a in res.actions)


def test_sort_evictees_prefers_isolated_replicas():
    plan = PlacementPlan.initial(16)
    for i in (2, 3, 4, 10):
        plan.add_replica(i, 1)
    order = sort_evictees(plan, 1)
    assert order[0] == 10  # the isolated replica goes first


# --------------------------------------------------------------- controller
def _mk_controller(viol=0.0, util=0.1):
    cluster = Cluster.homogeneous(4)
    plan = PlacementPlan.initial(16)
    mon = Monitor()
    mon.record(MetricsSnapshot(
        t=0.0, slo_violation_rate=viol,
        device_util=[util] * 4, device_mem_frac=[0.3, 0.1, 0.1, 0.1]))
    ctrl = Controller(ControllerConfig(replica_size=605e6), cluster, plan,
                      mon, is_violating=lambda p, bs: False)
    return ctrl


def test_controller_scales_up_when_vacant():
    ctrl = _mk_controller(viol=0.0, util=0.1)
    action = ctrl.tick()
    assert action and action.startswith("scale-up")
    assert sum(ctrl.plan.p) > 16


def test_controller_scales_down_on_violation():
    ctrl = _mk_controller(viol=0.5, util=0.95)
    action = ctrl.tick()
    assert action and action.startswith("scale-down")


def test_controller_cooldown():
    ctrl = _mk_controller(viol=0.0, util=0.1)
    assert ctrl.tick() is not None
    assert ctrl.tick() is None  # cooling down
