"""Training substrate: optimizer properties, overfit, checkpoint roundtrip."""
import os
import tempfile

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import transformer as T
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training import train as TR

KEY = jax.random.PRNGKey(0)


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_lr_schedule_bounds(step):
    cfg = OPT.OptimizerConfig(lr=1e-3, warmup_steps=100, total_steps=10_000,
                              min_lr_ratio=0.1)
    lr = float(OPT.lr_at(cfg, step))
    assert 0.0 <= lr <= cfg.lr + 1e-12
    if step >= cfg.warmup_steps:
        assert lr >= cfg.lr * cfg.min_lr_ratio - 1e-9


def test_lr_warmup_monotone():
    cfg = OPT.OptimizerConfig(lr=1e-3, warmup_steps=50, total_steps=1000)
    lrs = [float(OPT.lr_at(cfg, s)) for s in range(0, 51, 5)]
    assert all(b >= a for a, b in zip(lrs, lrs[1:]))


def test_grad_clipping():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = OPT.init_opt_state(params)
    cfg = OPT.OptimizerConfig(clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = OPT.apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)


def test_weight_decay_skips_vectors():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    state = OPT.init_opt_state(params)
    cfg = OPT.OptimizerConfig(weight_decay=0.1, clip_norm=None)
    new, _, _ = OPT.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(new["scale"] - 1.0).max()) == 0.0   # no decay
    assert float(jnp.abs(new["w"] - 1.0).max()) > 0.0        # decayed


def test_overfit_single_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    ocfg = OPT.OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    opt = OPT.init_opt_state(params)
    step = jax.jit(TR.make_train_step(cfg, ocfg))
    batch = synth_batch(cfg, DataConfig(seq_len=32, global_batch=4), 0)
    first = None
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first - 2.0


def test_checkpoint_roundtrip():
    cfg = get_config("mamba2-780m").reduced()
    params = T.init_params(cfg, KEY, "float32")
    path = tempfile.mktemp(suffix=".ckpt")
    try:
        CKPT.save(path, params, {"arch": cfg.name})
        restored, meta = CKPT.load(path, like=params)
        assert meta["arch"] == cfg.name
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        if os.path.exists(path):
            os.remove(path)


def test_pipeline_packing():
    cfg = get_config("tinyllama-1.1b").reduced()
    d = DataConfig(seq_len=64, global_batch=2, seed=1)
    b0 = synth_batch(cfg, d, 0)
    b0b = synth_batch(cfg, d, 0)
    b1 = synth_batch(cfg, d, 1)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0b["tokens"]))  # deterministic
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))
    # labels are next-token shifted
    assert b0["tokens"].shape == b0["labels"].shape == (2, 64)
    assert float(b0["mask"].min()) in (0.0, 1.0)


def test_train_driver_end_to_end():
    from repro.launch.train import main
    loss = main(["--arch", "tinyllama-1.1b", "--reduce", "--steps", "6",
                 "--batch", "2", "--seq", "32", "--log-every", "5"])
    assert np.isfinite(loss)
