"""Chaos soak gate (tier-2: spawns real engine-server processes; run
with ``pytest -m slow``).

The ISSUE-6 acceptance scenario, asserted on the exact code path the
nightly bench runs: ``benchmarks/chaos_bench.run_soak`` drives a
4-instance TCP pod through a seeded fault plan (one kill, one hang, one
partition, sprinkled delays) and must come out with zero dropped
streams, token-identical survivors, hung-peer detection within 2x the
RPC deadline, and the killed spawn-node respawned + re-admitted.

Plus the migration rollback-hardening window with an INJECTED hang
(rather than the process death tests/test_distributed_plane.py already
covers): a destination that goes half-open between ``pause_request``
and ``commit_resume`` is quarantined, and the source stays
authoritative — the paused stream replays token-identically with no
duplication."""
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import faults as FLT
from repro.serving.engine import Engine
from repro.serving.instance import LocalInstance
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.orchestrator import Orchestrator

# benchmarks/ is a root-level namespace package, not on src/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.chaos_bench import run_soak  # noqa: E402

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


@pytest.fixture(autouse=True)
def _clean_faults():
    yield
    FLT.uninstall()


def test_chaos_soak_meets_all_acceptance_criteria(tiny):
    """The tentpole gate at smoke sizes: same seeded plan shape, same
    pod, same verdict computation as the nightly BENCH_chaos run."""
    cfg, params = tiny
    report = run_soak(cfg, params, n_workers=4, seed=7, n_requests=6,
                      prompt_len=16, max_new=8, max_len=128,
                      max_batch=2, block_size=16, n_blocks=32)
    acc = report["acceptance"]
    assert acc["zero_dropped_streams"], report["streams"]
    assert acc["token_identical"], report["streams"]
    assert acc["hung_detected_within_2x_deadline"], report["recovery"]
    assert acc["killed_worker_respawned_and_readmitted"], \
        report["events"]["respawn_log"]
    # the plan really fired on the wire, and the report proves it
    assert sum(report["events"]["injected"].values()) > 0
    assert report["events"]["kills_executed"]
    assert report["recovery"]["quarantines"] >= 1
    assert report["recovery"]["respawns"] >= 1
    d = report["recovery"]
    assert all(s <= d["detect_bound_s"] for s in d["hung_detect_s"])


def test_hung_destination_between_pause_and_commit_rolls_back(tiny):
    """Rollback hardening: the destination goes HALF-OPEN (socket open,
    frames blackholed — injected on the real wire) after phase 1 staged
    and before the phase-2 commit lands. The commit misses its
    deadline, the destination is quarantined (killed, so a half-landed
    commit can never decode), and the paused payload — the stream's
    only copy — goes back to the alive source for deterministic
    replay."""
    from repro.serving.remote_engine import EngineProxy
    cfg, params = tiny
    reqs = [RequestSpec(rid=i,
                        prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                        max_tokens=10,
                        sampling=SamplingParams(temperature=0.8, top_k=16,
                                                seed=7 + i))
            for i in range(2)]
    ref = {}
    for r in reqs:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(r)
        ref[r.rid] = e.run_until_done()[0].generated

    local = LocalInstance(Engine(cfg, params, max_batch=2, max_len=64,
                                 cache_kind="paged", block_size=8,
                                 n_blocks=32))
    remote = EngineProxy(cfg, params, max_batch=2, max_len=64,
                         block_size=8, n_blocks=32, peer_label="w1")
    orch = Orchestrator(cfg, params, handles=[local, remote],
                        telemetry_every=10_000)
    try:
        for r in reqs:
            orch._home[r.rid] = 0
            orch.instances[0].submit(r)
        for _ in range(3):           # decode a bit; compiles are paid
            orch.step()
        victim_slot = sorted(orch.instances[0].active_rids())[0]

        ticket = orch.begin_migration(0, 1, victim_slot)
        # staging request is already on the remote's wire; NOW blackhole
        # the peer and arm the deadline the commit will miss
        inj = FLT.install(FLT.FaultPlan())
        inj.arm("w1", "half_open")
        orch.set_rpc_deadline(0.5)
        rec = orch.finish_migration(ticket)
        assert rec is None
        assert inj.injected["half_open"] >= 1    # the commit frame died
        # the destination was classified hung and quarantined; the
        # paused stream went BACK to the source's queue
        assert orch.faults.quarantines == 1
        assert orch.recoveries[0]["reason"] == "hung"
        assert not orch.instances[1].alive()
        assert len(local.engine.queue) == 1
        assert local.engine.queue[0].rid == ticket["rid"]

        FLT.uninstall()
        orch.set_rpc_deadline(None)
        orch.run_until_done()
        done = {}
        for r in orch.finished:
            assert r.rid not in done, f"rid {r.rid} decoded twice"
            done[r.rid] = r.generated
        assert done == ref
        assert orch.dropped == 0
    finally:
        FLT.uninstall()
        orch.close()
