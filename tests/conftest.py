"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 host device;
multi-device behaviour is exercised via subprocesses (test_distributed.py)
and the dry-run (launch/dryrun.py sets its own flag)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
