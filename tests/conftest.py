"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 host device;
multi-device behaviour is exercised via subprocesses (test_distributed.py)
and the dry-run (launch/dryrun.py sets its own flag).

If the real ``hypothesis`` package is absent (the CI container does not
bake it in), a deterministic stub is installed so the property-test modules
still collect and run — see tests/_hypothesis_stub.py.
"""
import sys

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, __file__.rsplit("/", 1)[0])
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
