"""Sharding rule engine: per-arch fallbacks, param/cache specs, divisibility.

Uses a fake mesh object (axis names + shape only) — no devices needed to
check the PartitionSpec logic.
"""
import types

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.parallel import sharding as SH


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    return types.SimpleNamespace(axis_names=axes,
                                 devices=np.empty(shape, dtype=object))


MESH = fake_mesh()
MESH3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_fallback_non_divisible():
    """minicpm3 (40H) and arctic (56H) cannot shard heads on a 16-way axis —
    the rule engine must fall back to replicated attention (DESIGN.md §5)."""
    assert SH.rules_for(get_config("minicpm3-4b"), MESH)["heads"] is None
    assert SH.rules_for(get_config("arctic-480b"), MESH)["heads"] is None
    assert SH.rules_for(get_config("gemma-7b"), MESH)["heads"] == "model"
    assert SH.rules_for(get_config("chameleon-34b"), MESH)["heads"] == "model"


def test_kv_cache_seq_fallback():
    """Archs whose KV heads can't shard must seq-shard the cache."""
    r = SH.rules_for(get_config("arctic-480b"), MESH)
    assert r["kv_heads"] is None and r["cache_seq"] == "model"
    r = SH.rules_for(get_config("gemma-7b"), MESH)
    assert r["kv_heads"] == "model" and r["cache_seq"] is None


def test_expert_rules():
    assert SH.rules_for(get_config("arctic-480b"), MESH)["experts"] == "data"
    assert SH.rules_for(get_config("qwen2-moe-a2.7b"), MESH)["experts"] == "data"


def test_batch_axes_multi_pod():
    r = SH.rules_for(get_config("tinyllama-1.1b"), MESH3)
    assert r["batch"] == ("pod", "data")


def test_long_context_rules():
    r = SH.long_context_rules(get_config("gemma-7b"), MESH)
    assert r["batch"] is None and r["cache_seq"] == "data"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch):
    """Every sharded param dim must divide by the axis size (16)."""
    cfg = get_config(arch)
    rules = SH.rules_for(cfg, MESH)
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), "bfloat16"))
    specs = SH.param_specs(cfg, shapes, rules, MESH)

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            size = 16  # both data and model are 16-way
            assert dim % size == 0, (SH._path_str(path), leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs)


@pytest.mark.parametrize("arch", ["gemma-7b", "zamba2-7b", "arctic-480b",
                                  "minicpm3-4b", "whisper-medium"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    rules = SH.rules_for(cfg, MESH)
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024, "bfloat16"))
    specs = SH.cache_specs(shapes, rules)

    def check(path, leaf, spec):
        axes = tuple(spec)
        for i, ax in enumerate(axes[:leaf.ndim]):
            if ax is None:
                continue
            sizes = {"data": 16, "model": 16, ("pod", "data"): 32}
            sz = sizes.get(ax, 16)
            assert leaf.shape[i] % sz == 0, (SH._path_str(path), leaf.shape,
                                             spec)

    jax.tree_util.tree_map_with_path(check, shapes, specs)


def test_mamba_tp_rules():
    cfg = get_config("mamba2-780m")
    rules = SH.rules_for(cfg, MESH)
    assert rules["ssm_heads"] == "model"  # 48 % 16 == 0
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), "bfloat16"))
    specs = SH.param_specs(cfg, shapes, rules, MESH)
    wx = specs["layers"]["mixer"]["w_x"]
    assert tuple(wx) == (None, None, "model")
    out = specs["layers"]["mixer"]["out_proj"]
    assert tuple(out) == (None, "model", None)


def test_lshard_noop_without_rules():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert SH.lshard(x, "batch", None) is x
