"""RPC wire protocol (serving/transport.py): frame codec roundtrips,
framed request/reply over a real socketpair, error propagation, hangup
detection, and pipelining — the tier-1 (no process spawn) coverage of
the distributed serving plane's transport layer."""
import threading

import numpy as np
import pytest

from repro.serving import transport as TR
from repro.serving.engine import Request


# ---------------------------------------------------------------- codec
def test_codec_roundtrips_numpy_payloads():
    payload = {
        "cols": np.asarray([0, 1, 5], np.int32),
        "k": np.random.default_rng(0).normal(size=(2, 3, 1, 8, 4))
        .astype(np.float32),
        "length": 42,
        "keys": {0: "ab12", 1: "cd34"},
        "nested": {"empty": np.zeros((2, 0, 4), np.int64)},
    }
    out = TR.decode(TR.encode(payload))
    assert out["length"] == 42
    assert out["keys"] == {0: "ab12", 1: "cd34"}
    for key, want in (("cols", payload["cols"]), ("k", payload["k"])):
        got = out[key]
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(got, want)
    assert out["nested"]["empty"].shape == (2, 0, 4)


def test_codec_roundtrips_requests():
    req = Request(rid=7, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=3, eos_id=None, temperature=0.8,
                  top_k=16, seed=9, generated=[4, 5])
    out = TR.decode(TR.encode({"request": req, "op": "submit"}))
    got = out["request"]
    assert isinstance(got, Request)
    assert (got.rid, got.seed, got.top_k) == (7, 9, 16)
    assert got.generated == [4, 5]
    np.testing.assert_array_equal(got.prompt, req.prompt)


def test_codec_pickle_fallback_for_arbitrary_objects():
    # objects msgpack can't express (configs, pytrees with odd leaves)
    # ride a pickle-tagged frame; the receiver dispatches on the tag
    from repro.configs import get_config
    cfg = get_config("tinyllama-1.1b").reduced()
    frame = TR.encode({"cfg": cfg})
    assert frame[:1] == TR.TAG_PICKLE
    assert TR.decode(frame)["cfg"] == cfg


def test_unknown_codec_tag_rejected():
    with pytest.raises(TR.TransportError):
        TR.decode(b"Zgarbage")


# ------------------------------------------------------------ rpc layer
def _boom():
    raise ValueError("no such block")


def _echo_server(conn):
    TR.serve(conn, {
        "echo": lambda x: x,
        "add": lambda a, b=0: a + b,
        "boom": _boom,
    })
    conn.close()   # a real engine server's process exit does this


def test_rpc_over_socketpair_roundtrip_and_errors():
    a, b = TR.socketpair()
    t = threading.Thread(target=_echo_server, args=(b,), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    assert rpc.call("add", 2, b=3) == 5
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    np.testing.assert_array_equal(rpc.call("echo", arr), arr)
    # a handler exception crosses the wire as a typed RemoteError and
    # the server SURVIVES it (next call still works)
    with pytest.raises(TR.RemoteError) as ei:
        rpc.call("boom")
    assert ei.value.kind == "ValueError"
    assert rpc.call("echo", "still alive") == "still alive"
    # unknown ops are errors, not hangups
    with pytest.raises(TR.RemoteError):
        rpc.call("nope")
    rpc.call("shutdown")
    t.join(timeout=5)
    # peer is gone: the next call observes TransportClosed
    with pytest.raises(TR.TransportClosed):
        rpc.call("echo", 1)


def test_rpc_pipelining_preserves_reply_matching():
    a, b = TR.socketpair()
    t = threading.Thread(target=_echo_server, args=(b,), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    pends = [rpc.call_async("add", i, b=100) for i in range(5)]
    # wait out of order: reply matching is by call id, not arrival order
    assert pends[3].wait() == 103
    assert pends[0].wait() == 100
    assert [p.wait() for p in pends[1:3]] == [101, 102]
    assert pends[4].wait() == 104
    rpc.call("shutdown")
    t.join(timeout=5)


def test_frame_stats_and_hangup_mid_frame():
    a, b = TR.socketpair()
    a.send({"x": 1})
    assert a.tx_frames == 1 and a.tx_bytes > 4
    assert b.recv() == {"x": 1}
    assert b.rx_frames == 1
    a.close()
    with pytest.raises(TR.TransportClosed):
        b.recv()
