"""RPC wire protocol (serving/transport.py): frame codec roundtrips,
framed request/reply over a real socketpair, error propagation, hangup
detection, pipelining, TCP endpoints (framing parity with AF_UNIX,
connect-retry, disconnect-mid-call), and the batched multiplexed poll —
the tier-1 (no process spawn) coverage of the distributed serving
plane's transport layer."""
import threading
import time

import numpy as np
import pytest

from repro.serving import transport as TR
from repro.serving.engine import Request


# ---------------------------------------------------------------- codec
def test_codec_roundtrips_numpy_payloads():
    payload = {
        "cols": np.asarray([0, 1, 5], np.int32),
        "k": np.random.default_rng(0).normal(size=(2, 3, 1, 8, 4))
        .astype(np.float32),
        "length": 42,
        "keys": {0: "ab12", 1: "cd34"},
        "nested": {"empty": np.zeros((2, 0, 4), np.int64)},
    }
    out = TR.decode(TR.encode(payload))
    assert out["length"] == 42
    assert out["keys"] == {0: "ab12", 1: "cd34"}
    for key, want in (("cols", payload["cols"]), ("k", payload["k"])):
        got = out[key]
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(got, want)
    assert out["nested"]["empty"].shape == (2, 0, 4)


def test_codec_roundtrips_requests():
    req = Request(rid=7, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=3, eos_id=None, temperature=0.8,
                  top_k=16, seed=9, generated=[4, 5])
    out = TR.decode(TR.encode({"request": req, "op": "submit"}))
    got = out["request"]
    assert isinstance(got, Request)
    assert (got.rid, got.seed, got.top_k) == (7, 9, 16)
    assert got.generated == [4, 5]
    np.testing.assert_array_equal(got.prompt, req.prompt)


def test_codec_pickle_fallback_for_arbitrary_objects():
    # objects msgpack can't express (configs, pytrees with odd leaves)
    # ride a pickle-tagged frame; the receiver dispatches on the tag
    from repro.configs import get_config
    cfg = get_config("tinyllama-1.1b").reduced()
    frame = TR.encode({"cfg": cfg})
    assert frame[:1] == TR.TAG_PICKLE
    assert TR.decode(frame)["cfg"] == cfg


def test_unknown_codec_tag_rejected():
    with pytest.raises(TR.TransportError):
        TR.decode(b"Zgarbage")


# ------------------------------------------------------------ rpc layer
def _boom():
    raise ValueError("no such block")


def _echo_server(conn):
    TR.serve(conn, {
        "echo": lambda x: x,
        "add": lambda a, b=0: a + b,
        "boom": _boom,
    })
    conn.close()   # a real engine server's process exit does this


def test_rpc_over_socketpair_roundtrip_and_errors():
    a, b = TR.socketpair()
    t = threading.Thread(target=_echo_server, args=(b,), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    assert rpc.call("add", 2, b=3) == 5
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    np.testing.assert_array_equal(rpc.call("echo", arr), arr)
    # a handler exception crosses the wire as a typed RemoteError and
    # the server SURVIVES it (next call still works)
    with pytest.raises(TR.RemoteError) as ei:
        rpc.call("boom")
    assert ei.value.kind == "ValueError"
    assert rpc.call("echo", "still alive") == "still alive"
    # unknown ops are errors, not hangups
    with pytest.raises(TR.RemoteError):
        rpc.call("nope")
    rpc.call("shutdown")
    t.join(timeout=5)
    # peer is gone: the next call observes TransportClosed
    with pytest.raises(TR.TransportClosed):
        rpc.call("echo", 1)


def test_rpc_pipelining_preserves_reply_matching():
    a, b = TR.socketpair()
    t = threading.Thread(target=_echo_server, args=(b,), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    pends = [rpc.call_async("add", i, b=100) for i in range(5)]
    # wait out of order: reply matching is by call id, not arrival order
    assert pends[3].wait() == 103
    assert pends[0].wait() == 100
    assert [p.wait() for p in pends[1:3]] == [101, 102]
    assert pends[4].wait() == 104
    rpc.call("shutdown")
    t.join(timeout=5)


def test_frame_stats_and_hangup_mid_frame():
    a, b = TR.socketpair()
    a.send({"x": 1})
    assert a.tx_frames == 1 and a.tx_bytes > 4
    assert b.recv() == {"x": 1}
    assert b.rx_frames == 1
    a.close()
    with pytest.raises(TR.TransportClosed):
        b.recv()


# ------------------------------------------------------------- tcp layer
def test_endpoint_parsing():
    assert TR.parse_endpoint("tcp://127.0.0.1:7101") == \
        ("tcp", ("127.0.0.1", 7101))
    assert TR.parse_endpoint("unix:///tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert TR.parse_endpoint("/tmp/x.sock") == ("unix", "/tmp/x.sock")
    for bad in ("tcp://nohost", "tcp://:7101", "tcp://h:port"):
        with pytest.raises(ValueError):
            TR.parse_endpoint(bad)


def _tcp_echo_listener():
    """Listen on an ephemeral TCP port; a thread serves ONE connection
    with the same dispatch as the AF_UNIX tests."""
    srv = TR.listen("tcp://127.0.0.1:0")
    endpoint = TR.bound_endpoint(srv)

    def run():
        conn = TR.accept(srv, timeout=10)
        srv.close()
        _echo_server(conn)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return endpoint, t


def test_tcp_framing_parity_with_af_unix():
    """The same frames over a real TCP connection: payload roundtrips
    byte-identical to the AF_UNIX path, a handler exception crosses as
    the same typed RemoteError, and the server survives it."""
    endpoint, t = _tcp_echo_listener()
    rpc = TR.Rpc(TR.connect(endpoint, timeout=10))
    payload = {
        "cols": np.asarray([0, 1, 5], np.int32),
        "k": np.random.default_rng(0).normal(size=(2, 3, 1, 8, 4))
        .astype(np.float32),
        "keys": {0: "ab12"},
    }
    out = rpc.call("echo", payload)
    for key in ("cols", "k"):
        assert out[key].dtype == payload[key].dtype
        np.testing.assert_array_equal(out[key], payload[key])
    assert out["keys"] == {0: "ab12"}
    with pytest.raises(TR.RemoteError) as ei:
        rpc.call("boom")
    assert ei.value.kind == "ValueError"
    assert rpc.call("add", 2, b=3) == 5
    rpc.call("shutdown")
    t.join(timeout=5)
    with pytest.raises(TR.TransportClosed):
        rpc.call("echo", 1)


def test_tcp_connect_retries_until_listener_appears():
    """A pod launcher connects to an endpoint whose server is still
    booting: every refused attempt retries with backoff until the bind
    lands. Nobody listens at ``endpoint`` for the first ~0.3s."""
    endpoint = TR.free_tcp_endpoint()

    def late_listener():
        time.sleep(0.3)
        srv = TR.listen(endpoint)
        conn = TR.accept(srv, timeout=10)
        srv.close()
        _echo_server(conn)

    t = threading.Thread(target=late_listener, daemon=True)
    t.start()
    rpc = TR.Rpc(TR.connect(endpoint, timeout=10))
    assert rpc.call("add", 20, b=3) == 23
    rpc.call("shutdown")
    t.join(timeout=5)


def test_tcp_connect_gives_up_at_deadline():
    endpoint = TR.free_tcp_endpoint()  # nobody will ever listen here
    t0 = time.perf_counter()
    with pytest.raises(TR.TransportError):
        TR.connect(endpoint, timeout=0.4)
    assert time.perf_counter() - t0 < 5.0


def test_tcp_connect_fails_fast_on_permanent_errors():
    """A typo'd hostname (DNS failure) is not transient: no retry loop,
    the misconfiguration surfaces immediately instead of eating the
    whole connect deadline."""
    t0 = time.perf_counter()
    with pytest.raises(TR.TransportError, match="not retrying"):
        TR.connect("tcp://no-such-host.invalid:7101", timeout=30.0)
    assert time.perf_counter() - t0 < 10.0


def test_tcp_disconnect_mid_call_surfaces_transport_closed():
    """The peer accepts the request frame, then dies without replying —
    the blocked caller must observe TransportClosed (the crash signal),
    not hang or see a framing error."""
    srv = TR.listen("tcp://127.0.0.1:0")
    endpoint = TR.bound_endpoint(srv)

    def one_request_then_die():
        conn = TR.accept(srv, timeout=10)
        srv.close()
        conn.recv()          # swallow the request...
        conn.close()         # ...and hang up instead of replying

    t = threading.Thread(target=one_request_then_die, daemon=True)
    t.start()
    rpc = TR.Rpc(TR.connect(endpoint, timeout=10))
    with pytest.raises(TR.TransportClosed):
        rpc.call("echo", {"big": np.zeros(1024, np.float32)})
    t.join(timeout=5)


# --------------------------------------------------------- batched poll
class _Resolved:
    """Local stand-in mixing into the poll (instance.Completed shape)."""

    def __init__(self, value):
        self._value = value

    def wait(self):
        return self._value


def _sleepy_server(conn, delay):
    TR.serve(conn, {"work": lambda x: (time.sleep(delay), x)[1]})
    conn.close()


def test_drain_pendings_waits_on_the_slowest_not_the_sum():
    """Fan out to two peers (one TCP, one AF_UNIX — the poll is
    transport-blind) that each take ~0.3s: one multiplexed drain
    resolves both in ~max, clearly under the ~sum a sequential wait
    would pay, and preserves input order."""
    srv = TR.listen("tcp://127.0.0.1:0")
    endpoint = TR.bound_endpoint(srv)
    threads = []

    def tcp_side():
        conn = TR.accept(srv, timeout=10)
        srv.close()
        _sleepy_server(conn, 0.3)

    threads.append(threading.Thread(target=tcp_side, daemon=True))
    a, b = TR.socketpair()
    threads.append(threading.Thread(target=_sleepy_server, args=(b, 0.3),
                                    daemon=True))
    for t in threads:
        t.start()
    rpc_tcp = TR.Rpc(TR.connect(endpoint, timeout=10))
    rpc_unix = TR.Rpc(a)

    t0 = time.perf_counter()
    pendings = [rpc_tcp.call_async("work", "tcp"),
                _Resolved("local"),
                rpc_unix.call_async("work", "unix")]
    results = TR.drain_pendings(pendings)
    wall = time.perf_counter() - t0
    assert results == [("ok", "tcp"), ("ok", "local"), ("ok", "unix")]
    assert wall < 0.5, f"poll took {wall:.2f}s: waits look sequential"
    for rpc in (rpc_tcp, rpc_unix):
        rpc.call("shutdown")
    for t in threads:
        t.join(timeout=5)


def test_drain_pendings_folds_peer_death_into_the_poll():
    """A peer that dies with replies outstanding resolves ITS entries
    to ("closed", TransportClosed) without disturbing the other peers'
    results — crash detection rides the same poll as collection."""
    a, b = TR.socketpair()          # peer that will die
    c, d = TR.socketpair()          # healthy peer

    def flaky(conn):
        conn.recv()                 # first request: reply normally
        conn.send({"id": 1, "ok": True, "result": "one"})
        conn.recv()                 # second request: die instead
        conn.close()

    threads = [threading.Thread(target=flaky, args=(b,), daemon=True),
               threading.Thread(target=_sleepy_server, args=(d, 0.05),
                                daemon=True)]
    for t in threads:
        t.start()
    flaky_rpc, ok_rpc = TR.Rpc(a), TR.Rpc(c)
    pendings = [flaky_rpc.call_async("first"),
                flaky_rpc.call_async("second"),
                ok_rpc.call_async("work", 42)]
    results = TR.drain_pendings(pendings)
    assert results[0] == ("ok", "one")
    assert results[1][0] == "closed"
    assert isinstance(results[1][1], TR.TransportClosed)
    assert results[2] == ("ok", 42)
    ok_rpc.call("shutdown")
    for t in threads:
        t.join(timeout=5)


def test_drain_pendings_resolves_error_replies_per_entry():
    a, b = TR.socketpair()
    t = threading.Thread(target=_echo_server, args=(b,), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    results = TR.drain_pendings([rpc.call_async("boom"),
                                 rpc.call_async("add", 1, b=2)])
    assert results[0][0] == "error"
    assert isinstance(results[0][1], TR.RemoteError)
    assert results[0][1].kind == "ValueError"
    assert results[1] == ("ok", 3)
    rpc.call("shutdown")
    t.join(timeout=5)


# ------------------------------------------- deadlines & receive bounds
def _silent_server(conn):
    """Accepts requests forever, never replies — a half-open peer: the
    socket stays open, so the only detection signal is the deadline."""
    try:
        while True:
            conn.recv()
    except TR.TransportClosed:
        pass


def test_rpc_timeout_is_hung_not_dead_and_connection_survives():
    """A missed deadline raises RpcTimeout (socket still OPEN) — and
    because the server processes in order, the connection is still
    usable afterwards: the late reply lands in the stale-reply stash
    and the next call matches its own id."""
    a, b = TR.socketpair()
    t = threading.Thread(target=_sleepy_server, args=(b, 0.5), daemon=True)
    t.start()
    rpc = TR.Rpc(a)
    t0 = time.perf_counter()
    with pytest.raises(TR.RpcTimeout, match="socket still open"):
        rpc.call_timed("work", 0.15, "late")
    assert time.perf_counter() - t0 < 0.4
    # the peer was merely slow, not dead: the SAME connection completes
    # the next call (pumping the stale reply for call 1 on the way)
    assert rpc.call("work", "next") == "next"
    rpc.call("shutdown")
    t.join(timeout=5)


def test_drain_pendings_hung_entry_does_not_stall_healthy_peers():
    """One blackholed worker must cost its own deadline, not the tick:
    the poll clips its sleep to the earliest outstanding deadline and
    resolves that entry to ("hung", RpcTimeout) while the healthy
    peer's reply still lands as ("ok", ...)."""
    a, b = TR.socketpair()
    c, d = TR.socketpair()
    threads = [threading.Thread(target=_silent_server, args=(b,),
                                daemon=True),
               threading.Thread(target=_sleepy_server, args=(d, 0.05),
                                daemon=True)]
    for t in threads:
        t.start()
    hung_rpc = TR.Rpc(a, call_timeout=0.3)
    ok_rpc = TR.Rpc(c)
    t0 = time.perf_counter()
    results = TR.drain_pendings([hung_rpc.call_async("work", "void"),
                                 ok_rpc.call_async("work", 7)])
    wall = time.perf_counter() - t0
    assert results[0][0] == "hung"
    assert isinstance(results[0][1], TR.RpcTimeout)
    assert results[1] == ("ok", 7)
    # bounded by the deadline, not by any longer poll default
    assert 0.25 <= wall < 1.0
    a.close()                      # unblocks the silent server's recv
    ok_rpc.call("shutdown")
    for t in threads:
        t.join(timeout=5)


def test_frame_too_large_is_typed_and_fails_the_connection():
    """Satellite: the receive path bounds frame size BEFORE allocating.
    An oversized length prefix surfaces as FrameTooLarge (a typed
    TransportError) and the connection is failed — the stream is
    unsynchronized, so further reads must not see garbage."""
    a, b = TR.socketpair()
    b.max_frame = 4096
    a.send({"small": 1})
    assert b.recv() == {"small": 1}          # under the bound: fine
    # big enough to break the 4 KiB bound, small enough to fit the
    # kernel socket buffer (this thread is both sender and receiver)
    a.send({"big": np.zeros(2048, np.float32)})
    with pytest.raises(TR.FrameTooLarge, match="receive bound"):
        b.recv()
    assert issubclass(TR.FrameTooLarge, TR.TransportError)
    with pytest.raises(TR.TransportError):   # connection is dead now
        b.recv()
    a.close()


def test_backoff_delays_monotone_and_capped():
    gen = TR.backoff_delays(0.02, cap=0.5)
    seq = [next(gen) for _ in range(10)]
    assert seq[0] == 0.02
    assert all(b >= a for a, b in zip(seq, seq[1:]))
    assert max(seq) == 0.5
    assert seq[-1] == 0.5          # stays pinned at the cap


def test_connect_backoff_schedule_gives_up(monkeypatch):
    """Satellite: the retry schedule itself — doubling from 20ms, and
    giving up once the NEXT delay would overshoot the deadline. Sleeps
    are recorded instead of slept, so the asserted schedule is exact."""
    slept = []
    monkeypatch.setattr(TR.time, "sleep", slept.append)
    endpoint = TR.free_tcp_endpoint()  # nobody will ever listen here
    with pytest.raises(TR.TransportError, match="failed within"):
        TR.connect(endpoint, timeout=0.4)
    assert slept == [0.02, 0.04, 0.08, 0.16, 0.32]
